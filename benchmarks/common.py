"""Shared helpers for the paper-figure benchmarks.

Every ``fig*.py`` module exposes ``run() -> List[Row]``; a Row is
``(name, value, derived)`` where ``name`` identifies the measurement,
``value`` is the primary number, and ``derived`` carries the comparison
against the paper's claim (or context). ``benchmarks.run`` aggregates all
figures into one CSV.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path
from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

from repro.sim import JobSpec, faults
from repro.sim.runner import slowdown

Row = Tuple[str, float, str]

_ROOT = Path(__file__).resolve().parent.parent
BENCH_JSON = _ROOT / "BENCH_scale.json"

# Shared shape of the perf_scale / perf_shuffle sweeps: both run the same
# proportionally-sized job (so their BENCH_scale.json payloads compare),
# differing only in what they measure.
SCALE_SIZES_QUICK = (20, 100, 500)
SCALE_SIZES_FULL = (20, 100, 500, 1000)
# The kernelized-drain tier (ISSUE 7): batch vs kernel only — the rescan
# and event substrates are structurally unusable at this size, and the
# job cannot finish inside any tractable sim window (reduces cap at 32),
# so the tier runs the same capped observation window as the main sweep.
SCALE_SIZE_XL = 10_000
SCALE_N_CONTAINERS = 8
SCALE_SPLITS_PER_WORKER = 4    # job size scales with the cluster
SCALE_SIM_SECONDS_QUICK = 120.0
SCALE_SIM_SECONDS_FULL = 240.0


def drain_seconds(reg) -> float:
    """Drain wall accumulated by ``repro.obs.instrument_drain`` (which
    retired PR 7's local ``attach_drain_timer``); 0.0 when the substrate
    has no calendar lane."""
    return float(reg.snapshot().get("drain_s", 0.0))


def bench_quick() -> bool:
    return os.environ.get("REPRO_BENCH_QUICK", "") not in ("", "0")


def bench_json_update(name: str, payload: Dict, *, mode: str) -> Path:
    """Merge one benchmark's latest payload into ``BENCH_scale.json``.

    Schema 2 is a per-benchmark map with a shared bounded history:
    ``{"schema": 2, "benchmarks": {name: payload}, "history": [...]}``.
    The previous payload for ``name`` is pushed into history; a schema-1
    file (PR 1's single perf_scale payload) is migrated in place."""
    payload = dict(payload)
    payload.update({"benchmark": name, "generated_unix": int(time.time()),
                    "cpu_count": os.cpu_count(), "mode": mode})
    doc = {"schema": 2, "benchmarks": {}, "history": []}
    if BENCH_JSON.exists():
        try:
            prev = json.loads(BENCH_JSON.read_text())
            if prev.get("schema") == 2:
                doc["benchmarks"] = prev.get("benchmarks", {})
                doc["history"] = prev.get("history", [])
            else:  # schema 1: one perf_scale payload with embedded history
                hist = prev.pop("history", [])
                prev.setdefault("benchmark", "perf_scale")
                doc["history"] = hist + [prev]
        except (json.JSONDecodeError, OSError):
            pass
    old = doc["benchmarks"].get(name)
    if old is not None:
        doc["history"].append(old)
    doc["history"] = doc["history"][-20:]
    doc["benchmarks"][name] = payload
    BENCH_JSON.write_text(json.dumps(doc, indent=2) + "\n")
    return BENCH_JSON


# Process fan-out for the sweep grids (benches × fracs × seeds). Each cell
# is an independent deterministic simulation, so they parallelize
# perfectly; per-process LRU caches keep the fault-free baselines shared
# within a worker. REPRO_BENCH_PROCS=1 forces the serial path.
_BENCH_PROCS = int(os.environ.get("REPRO_BENCH_PROCS",
                                  str(os.cpu_count() or 1)))

# Small representative subset of the suite for the heavier sweeps; the
# overall figures use more benches. Chosen to span the MOF-ratio axis.
FAST_BENCHES = ("terasort", "wordcount", "grep", "aggregation")
SUITE = ("terasort", "wordcount", "secondarysort", "grep", "aggregation",
         "join", "kmeans", "pagerank", "scan", "sort")

CRASH_FRACS = (0.1, 0.4, 0.7, 1.0)   # paper: 10 %..100 % of map progress
SEEDS = (1, 2)


def crash_fault(frac: float) -> Callable:
    def f(sim, job):
        faults.crash_busiest_node_at_map_progress(sim, job, frac)
    return f


def mof_fault(frac: float) -> Callable:
    def f(sim, job):
        faults.lose_mof_at_map_progress(sim, job, frac)
    return f


def delay_fault(at: float, factor: float = 0.05,
                duration: float = 180.0) -> Callable:
    # factor strictly below GlanceConfig.threshold_slowdown (0.1): Eq. 3
    # is a strict inequality, so a slowdown exactly AT the threshold is
    # by definition not a straggler.
    def f(sim, job):
        # slow the node hosting the most of the job's work
        def fire():
            counts = {}
            for t in job.maps:
                for a in t.running_attempts():
                    counts[a.node_id] = counts.get(a.node_id, 0) + 1
            victim = max(sorted(counts), key=lambda n: counts[n]) \
                if counts else sim.cluster.node_ids[0]
            sim.set_node_speed(victim, factor)
            sim.engine.after(duration, sim.set_node_speed, victim, 1.0)
        sim.engine.at(at, fire)
    return f


def _slowdown_cell(cell) -> float:
    """One grid cell, executed in a worker process. ``fault_for`` must be a
    module-level factory (crash_fault/mof_fault/...) so it pickles by
    reference; the fault closure itself is built inside the worker."""
    policy, bench, input_gb, frac, seed, fault_for, policy_kwargs = cell
    sd, _ = slowdown(policy, JobSpec("j0", bench, input_gb),
                     fault_for(frac), seed=seed, **policy_kwargs)
    return sd


def avg_slowdown(policy: str, input_gb: float, fault_for,
                 benches: Sequence[str] = FAST_BENCHES,
                 fracs: Sequence[float] = CRASH_FRACS,
                 seeds: Sequence[int] = SEEDS,
                 **policy_kwargs) -> Tuple[float, List[float]]:
    """Average slowdown over benches × fault-points × seeds.

    The grid fans out over a process pool (bench-major result order is
    preserved); anything unpicklable in the request — a closure fault
    factory, a ``policy_factory`` — falls back to the serial path.
    """
    grid = [(policy, bench, input_gb, frac, seed, fault_for, policy_kwargs)
            for bench in benches for frac in fracs for seed in seeds]
    sds = _run_grid(grid)
    return float(np.mean(sds)), sds


def _run_grid(grid) -> List[float]:
    workers = min(_BENCH_PROCS, len(grid))
    if workers > 1 and _grid_picklable(grid):
        import concurrent.futures as cf
        try:
            with cf.ProcessPoolExecutor(max_workers=workers) as ex:
                return list(ex.map(_slowdown_cell, grid))
        except (OSError, cf.process.BrokenProcessPool):
            pass  # restricted environment: fall through to serial
    return [_slowdown_cell(cell) for cell in grid]


def _grid_picklable(grid) -> bool:
    import pickle
    try:
        pickle.dumps(grid[0])
        return True
    except Exception:
        return False


def vs_paper(measured: float, paper: float) -> str:
    return f"paper={paper:g} measured={measured:.2f}"
