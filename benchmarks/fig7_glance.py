"""Fig. 7: understanding neighborhood glance.

(a) per-assessment ablation (spatial / temporal / failure only) against
    node delay and node failure, small and large jobs;
(b) Eq. 4 failure-assessment accuracy vs window length L under mixed
    crash/transient-delay injections (the failure ratio sweep);
(c) SIZE_NEIGHBOR sensitivity: job slowdown and #speculative tasks.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.glance import GlanceConfig
from repro.core.speculator import BinoConfig, BinocularSpeculator
from repro.sim import JobSpec, Simulation, faults
from repro.sim.runner import slowdown

from benchmarks.common import Row, crash_fault, delay_fault


def _bino_factory(glance: GlanceConfig):
    cfg = BinoConfig(glance=glance)
    return lambda node_ids: BinocularSpeculator(node_ids, cfg)


def _ablation() -> List[Row]:
    rows: List[Row] = []
    variants = {
        "spatial": GlanceConfig(enable_temporal=False, enable_failure=False),
        "temporal": GlanceConfig(enable_spatial=False, enable_failure=False),
        "failure": GlanceConfig(enable_spatial=False, enable_temporal=False),
        "all": GlanceConfig(),
    }
    for gb in (1.0, 10.0):
        for fname, fault in (("fail", crash_fault(0.5)),
                             ("delay", delay_fault(20.0))):
            yarn_sd, _ = slowdown("yarn", JobSpec("j0", "terasort", gb),
                                  fault, seed=1)
            for vname, g in variants.items():
                sd, _ = slowdown("bino", JobSpec("j0", "terasort", gb),
                                 fault, seed=1,
                                 policy_factory=_bino_factory(g))
                rows.append((
                    f"fig7a/{vname}_only_{fname}_{gb:g}GB",
                    yarn_sd / sd,
                    f"improvement over yarn (yarn {yarn_sd:.2f}x "
                    f"-> bino {sd:.2f}x)"))
    return rows


def _accuracy() -> List[Row]:
    """Eq. 4 window sweep: classify crashes vs transient outages.

    Protocol: six victim nodes each experience three TEACHING transient
    outages (lognormal durations — Eq. 4 learns each node's loss pattern),
    then one TEST event: a permanent crash with probability
    ``failure_ratio``, else one more transient. Accuracy = fraction of
    test events classified correctly (crash ⇔ the policy declared the
    node failed). Longer windows L smooth the outage-duration estimate;
    higher failure ratios offer fewer confusable transients."""
    rows: List[Row] = []
    for L in (1, 2, 4, 8):
        for ratio in (0.25, 0.5, 0.75, 1.0):
            correct = total = 0
            for seed in (1, 2, 3):
                rng = np.random.default_rng(1000 * L + seed)
                g = GlanceConfig(failure_window=L)
                sim = Simulation(
                    policy="bino", seed=seed,
                    policy_factory=_bino_factory(g))
                # staggered jobs keep the control plane alive through the
                # whole injection schedule (the sim stops when idle)
                for j in range(4):
                    sim.submit(JobSpec(f"j{j}", "aggregation", 20.0,
                                       submit_time=100.0 * j))
                tests = []  # (node, t_test, is_crash)
                victims = sim.cluster.node_ids[8:14]
                for vi, nid in enumerate(victims):
                    t = 20.0 + vi * 7.0
                    for k in range(3):  # teaching outages
                        dur = float(np.clip(rng.lognormal(2.0, 0.6),
                                            2.0, 25.0))
                        faults.heartbeat_outage_at(sim, nid, t, dur)
                        t += 50.0
                    if rng.uniform() < ratio:
                        faults.crash_node_at(sim, nid, t)
                        tests.append((nid, t, True))
                    else:
                        dur = float(np.clip(rng.lognormal(2.0, 0.6),
                                            2.0, 25.0))
                        faults.heartbeat_outage_at(sim, nid, t, dur)
                        tests.append((nid, t, False))
                sim.run()
                calls = sim.policy_failed_calls
                for nid, t, is_crash in tests:
                    flagged = any(n == nid and ct >= t
                                  for ct, n in calls)
                    correct += int(flagged == is_crash)
                    total += 1
            acc = correct / max(total, 1)
            rows.append((f"fig7b/accuracy_L{L}_ratio{ratio:g}", acc,
                         "higher L and ratio -> higher accuracy"))
    return rows


def _neighborhood() -> List[Row]:
    rows: List[Row] = []
    for k in (2, 4, 6, 8):
        g = GlanceConfig(size_neighbor=k)
        sd, res = slowdown("bino", JobSpec("j0", "terasort", 10.0),
                           delay_fault(20.0), seed=1,
                           policy_factory=_bino_factory(g))
        rows.append((f"fig7c/slowdown_k{k}", sd,
                     f"n_spec={res.n_spec_attempts} (small k ⇒ limited "
                     "spatial capacity; flat beyond)"))
    return rows


def run() -> List[Row]:
    return _ablation() + _accuracy() + _neighborhood()
