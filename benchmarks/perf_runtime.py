"""Live-runtime load harness (ISSUE 6; DESIGN.md §16.8).

Measures the chaos-hardened coordinator end-to-end on real threads and a
real clock: p50/p99 committed-step latency fault-free, then the recovery
cost of one pinned crash script under each recovery policy. The fault is
released *after* the JIT warm-up steps (``ChaosController(defer_arm=
True)``), so the crash lands at a known instant inside the measured
window and the recovery metric is not polluted by compile time.

Metrics (merged into ``BENCH_scale.json`` under ``perf_runtime``):

- ``p50_ms`` / ``p99_ms`` — fault-free committed-step latency;
- ``recovery_s`` per policy — the disturbed step's excess wall over the
  fault-free p50 (detection + re-execution, everything the fault cost);
- the correctness rider: every policy's final parameters must be
  BIT-identical to the fault-free run's (the exactly-once invariant,
  measured here under load, pinned down in tests/test_runtime.py).

Acceptance gate (asserted, not just printed): under the crash script,
bino's recovery beats gang-restart — bino pays adaptive detection plus
re-execution of only the dead host's *missing* microbatches; restart
pays its conservative silence timeout plus a full step re-run.

Usage:
    PYTHONPATH=src python -m benchmarks.perf_runtime [--quick]
    PYTHONPATH=src python -m benchmarks.run --only perf_runtime --quick
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from benchmarks.common import Row, bench_json_update, bench_quick
from repro.configs import get_config, reduced_config
from repro.runtime import ChaosController, RuntimeConfig, TrainerRuntime
from repro.train.loop import TrainConfig

HOSTS = 4
MICROBATCHES = 4
COMPUTE_DELAY = 0.08          # per-microbatch work floor: makes the
                              # re-execution cost visible above JIT noise
WARMUP_STEPS = 2
SEQ_LEN = 32

# One crash script (the fault vocabulary shared with sim/faults.py and
# the test corpus): permanent loss of host index 1, fired ~0.1 s after
# release — i.e. inside the first measured step.
CRASH_SCRIPT = [("crash", 1, 0.02, 0.0)]
CHAOS_HORIZON = 5.0

# Detection knobs, policy-faithful: restart keeps its conservative
# silence timeout; bino detects via Eq. 4 assessment + coverage-hole
# repair. This asymmetry IS the paper's claim being measured.
RESTART_TIMEOUT = 2.5
REPAIR_TIMEOUT = 0.6


def _measure(policy: str, script, n_meas: int,
             seed: int = 0) -> Tuple[List[float], Dict, np.ndarray]:
    """Run WARMUP_STEPS fault-free, release the script, run ``n_meas``
    measured steps. Returns (measured walls, counters, final params)."""
    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    chaos = (ChaosController(script, horizon=CHAOS_HORIZON, seed=seed,
                             defer_arm=True)
             if script is not None else None)
    rt = RuntimeConfig(
        n_hosts=HOSTS, microbatches_per_shard=MICROBATCHES,
        recovery=policy, compute_delay=COMPUTE_DELAY,
        restart_timeout=RESTART_TIMEOUT, repair_timeout=REPAIR_TIMEOUT)
    t = TrainerRuntime(cfg, TrainConfig(), rt, seq_len=SEQ_LEN,
                       per_shard_batch=2, seed=seed, chaos=chaos)
    try:
        reports = []
        for i in range(WARMUP_STEPS):
            reports.append(t.coord.run_step(i))
        if chaos is not None:
            chaos.release()
        for i in range(WARMUP_STEPS, WARMUP_STEPS + n_meas):
            reports.append(t.coord.run_step(i))
        meas = [r.wall_s for r in reports[WARMUP_STEPS:]]
        # Recovery-work accounting now comes off the coordinator's
        # metrics registry (repro.obs, DESIGN.md §18.3) instead of
        # ad-hoc report scraping; ``mb_needed`` stays report-derived
        # (it is a per-step target, not an event count).
        snap = t.coord.metrics.snapshot()
        counters = {
            "recoveries": int(snap.get("recoveries", 0)),
            "detections": int(snap.get("detections", 0)),
            "expiry_declares": int(snap.get("expiry_declares", 0)),
            "restarts": int(snap.get("restarts", 0)),
            "wedges": int(snap.get("wedges", 0)),
            "mb_executed": int(snap.get("mb_executed", 0)),
            "mb_needed": sum(r.mb_needed for r in reports),
            "resends": int(snap.get("resends", 0)),
        }
        vec = np.concatenate([np.asarray(l, np.float32).ravel()
                              for l in jax.tree.leaves(t.state["params"])])
        return meas, counters, vec
    finally:
        t.shutdown()


def run() -> List[Row]:
    quick = bench_quick()
    n_meas = 4 if quick else 8
    rows: List[Row] = []

    base_walls, base_ctr, base_vec = _measure("bino", None, n_meas)
    p50 = float(np.percentile(base_walls, 50))
    p99 = float(np.percentile(base_walls, 99))
    rows.append(("perf_runtime/p50_ms", p50 * 1e3,
                 f"fault-free committed-step latency over {n_meas} steps"))
    rows.append(("perf_runtime/p99_ms", p99 * 1e3,
                 f"hosts={HOSTS} mb/shard={MICROBATCHES}"))

    policies: Dict[str, Dict] = {}
    for policy in ("bino", "restart"):
        walls, ctr, vec = _measure(policy, CRASH_SCRIPT, n_meas)
        recovery = max(walls) - p50
        exact = bool(np.array_equal(base_vec, vec))
        policies[policy] = {
            "walls_s": [round(w, 4) for w in walls],
            "recovery_s": round(recovery, 4),
            "bit_identical": exact,
            **ctr,
        }
        rows.append((f"perf_runtime/{policy}_recovery_s", recovery,
                     f"recoveries={ctr['recoveries']} "
                     f"restarts={ctr['restarts']} "
                     f"waste_mb={ctr['mb_executed'] - ctr['mb_needed']}"))
        if not exact:
            raise AssertionError(
                f"{policy}: faulted params diverged from fault-free "
                f"(exactly-once invariant broken under load)")
    b, r = policies["bino"]["recovery_s"], policies["restart"]["recovery_s"]
    rows.append(("perf_runtime/restart_over_bino_recovery",
                 r / max(b, 1e-9),
                 f"bino={b:.2f}s restart={r:.2f}s (gate: bino < restart)"))
    if b >= r:
        raise AssertionError(
            f"recovery gate failed: bino {b:.2f}s >= restart {r:.2f}s "
            f"under crash script {CRASH_SCRIPT}")

    payload = {
        "hosts": HOSTS,
        "microbatches_per_shard": MICROBATCHES,
        "compute_delay_s": COMPUTE_DELAY,
        "warmup_steps": WARMUP_STEPS,
        "measured_steps": n_meas,
        "crash_script": [list(s) for s in CRASH_SCRIPT],
        "restart_timeout_s": RESTART_TIMEOUT,
        "repair_timeout_s": REPAIR_TIMEOUT,
        "baseline": {"walls_s": [round(w, 4) for w in base_walls],
                     "p50_ms": round(p50 * 1e3, 2),
                     "p99_ms": round(p99 * 1e3, 2),
                     **base_ctr},
        "policies": policies,
        "gate": {"bino_recovery_s": b, "restart_recovery_s": r,
                 "ok": b < r},
    }
    path = bench_json_update("perf_runtime", payload,
                             mode="quick" if quick else "full")
    rows.append(("perf_runtime/json", 1.0, str(path)))
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer measured steps")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.quick and not args.full:
        os.environ["REPRO_BENCH_QUICK"] = "1"
    for name, value, derived in run():
        print(f"{name},{value:.4g},{derived}")


if __name__ == "__main__":
    main()
