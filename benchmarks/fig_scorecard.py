"""Speculation scorecards: yarn vs bino detection quality from flight-
recorder traces (ISSUE 8; DESIGN.md §18.5).

Runs pinned declarative fault scripts through the simulator under both
policies with a :class:`~repro.obs.TraceRecorder` wired in, joins each
trace's fault ground truth (``K_FAULT``) against its detection verdicts
(``K_DETECT``), and reports per-policy precision / recall / mean
time-to-detect / wasted backup work. The same scripts then run against
the *live* runtime (ChaosController on a FakeClock) and the cross-world
gate asserts the comparable core — victims / tp / fp / fn / precision /
recall — is identical between a script's sim trace and its runtime
trace (time-to-detect is clock-relative and reported per world).

Acceptance gates (asserted, not just printed):
- bino recall is 1.0 on every script (every injected node fault caught);
- bino never detects slower than yarn's fixed-expiry baseline
  (mean time-to-detect, per script);
- sim and runtime scorecards agree on the comparable core per script.

Usage:
    PYTHONPATH=src python -m benchmarks.fig_scorecard [--quick]
    PYTHONPATH=src python -m benchmarks.run --only fig_scorecard --quick
"""
from __future__ import annotations

import os
from typing import Dict, List

from benchmarks.common import Row, bench_json_update, bench_quick
from repro.obs import TraceRecorder, comparable_core, scorecard
from repro.sim import JobSpec, faults
from repro.sim.mapreduce import Simulation

N_WORKERS = 4    # matches the runtime's host count, so node indices and
#                  therefore scorecard victim sets align across worlds

SCRIPTS = {
    "one_crash": [("crash", 1, 0.2, 0.0)],
    "two_crashes": [("crash", 1, 0.2, 0.0), ("crash", 2, 0.3, 0.0)],
}


def sim_card(policy: str, script, seed: int = 1) -> Dict:
    rec = TraceRecorder()
    sim = Simulation(policy=policy, seed=seed, n_workers=N_WORKERS,
                     obs=rec)
    job = sim.submit(JobSpec("j0", "terasort", 2.0))
    faults.apply_script(sim, job, script)
    sim.run()
    return scorecard(rec, policy=policy)


def runtime_card(recovery: str, script) -> Dict:
    """Live coordinator/host threads on a FakeClock under the same
    script, interpreted by the ChaosController."""
    from repro.configs import get_config, reduced_config
    from repro.runtime import (
        ChaosController,
        FakeClock,
        RuntimeConfig,
        TrainerRuntime,
    )
    from repro.train.loop import TrainConfig

    rec = TraceRecorder(thread_safe=True)
    rt = RuntimeConfig(n_hosts=N_WORKERS, microbatches_per_shard=4,
                       recovery=recovery, compute_delay=0.02)
    t = TrainerRuntime(
        reduced_config(get_config("qwen1.5-0.5b")), TrainConfig(), rt,
        seq_len=32, per_shard_batch=2, seed=0,
        clock=FakeClock(auto_advance=True),
        chaos=ChaosController(script, horizon=6.0, seed=7), obs=rec)
    try:
        t.run(3)
    finally:
        t.shutdown()
    return scorecard(rec, policy=recovery)


def run() -> List[Row]:
    quick = bench_quick()
    rows: List[Row] = []
    per_script: Dict[str, Dict] = {}
    for name, script in SCRIPTS.items():
        cards = {"sim": {p: sim_card(p, script) for p in ("yarn", "bino")},
                 "runtime": {"bino": runtime_card("bino", script)}}
        sim_bino = cards["sim"]["bino"]
        sim_yarn = cards["sim"]["yarn"]
        rt_bino = cards["runtime"]["bino"]
        cross_ok = comparable_core(sim_bino) == comparable_core(rt_bino)
        per_script[name] = {
            "script": [list(s) for s in script],
            "cards": cards,
            "cross_world_ok": cross_ok,
        }
        for policy, card in cards["sim"].items():
            rows.append((
                f"fig_scorecard/{name}_{policy}_recall", card["recall"],
                f"precision={card['precision']} ttd={card['ttd']} "
                f"wasted={card['wasted_backup_work']}"))
        rows.append((
            f"fig_scorecard/{name}_cross_world", float(cross_ok),
            f"sim={comparable_core(sim_bino)} "
            f"runtime_ttd={rt_bino['ttd']}"))
        if not cross_ok:
            raise AssertionError(
                f"{name}: sim vs runtime scorecard diverged: "
                f"{comparable_core(sim_bino)} != "
                f"{comparable_core(rt_bino)}")
        if sim_bino["recall"] != 1.0:
            raise AssertionError(
                f"{name}: bino missed an injected fault: {sim_bino}")
        if sim_yarn["mean_ttd"] is not None \
                and sim_bino["mean_ttd"] is not None \
                and sim_bino["mean_ttd"] > sim_yarn["mean_ttd"] + 1e-9:
            raise AssertionError(
                f"{name}: bino detected slower than the yarn baseline: "
                f"{sim_bino['mean_ttd']} > {sim_yarn['mean_ttd']}")
    payload = {"n_workers": N_WORKERS, "scripts": per_script}
    path = bench_json_update("fig_scorecard", payload,
                             mode="quick" if quick else "full")
    rows.append(("fig_scorecard/json", 1.0, str(path)))
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.quick and not args.full:
        os.environ["REPRO_BENCH_QUICK"] = "1"
    for name, value, derived in run():
        print(f"{name},{value:.4g},{derived}")


if __name__ == "__main__":
    main()
