"""Fig. 6: system efficiency under stress — PACMan-mix workload (85 % 1 GB,
8 % 10 GB, 5 % 50 GB, 2 % 100 GB), Poisson arrivals, injected task
failures, node crashes (with later restore) and transient network delays.
Paper: Bino decreases mean JCT of the whole workload by 30 %."""
from __future__ import annotations

from typing import List

import numpy as np

from repro.sim import faults
from repro.sim.runner import run_workload
from repro.sim.workload import pacman_workload

from benchmarks.common import Row, vs_paper

N_JOBS = 36
MEAN_INTERARRIVAL = 25.0


def _fault_script(sim) -> None:
    """Deterministic background fault load over the workload window."""
    rng = np.random.default_rng(99)
    horizon = N_JOBS * MEAN_INTERARRIVAL
    nodes = sim.cluster.node_ids
    # node crashes, restored after a few minutes (capacity returns)
    for t in rng.uniform(60.0, horizon, size=9):
        nid = nodes[int(rng.integers(len(nodes)))]
        faults.crash_node_at(sim, nid, float(t), restore_after=180.0)
    # transient slowdowns (below the Eq. 3 threshold so Bino can see them)
    for t in rng.uniform(30.0, horizon, size=12):
        nid = nodes[int(rng.integers(len(nodes)))]
        faults.slow_node_at(sim, nid, float(t), 0.05,
                            duration=float(rng.uniform(90, 240)))
    # heartbeat outages (network delays)
    for t in rng.uniform(30.0, horizon, size=10):
        nid = nodes[int(rng.integers(len(nodes)))]
        faults.heartbeat_outage_at(sim, nid, float(t),
                                   float(rng.uniform(4, 15)))


def run() -> List[Row]:
    specs = pacman_workload(N_JOBS, mean_interarrival=MEAN_INTERARRIVAL,
                            seed=7)
    jcts = {}
    for pol in ("yarn", "bino"):
        results = run_workload(pol, specs, _fault_script, seed=11)
        jcts[pol] = np.asarray([r.jct for r in results])
    rows: List[Row] = []
    for pol in ("yarn", "bino"):
        rows.append((f"fig6/{pol}_mean_jct_s", float(jcts[pol].mean()), ""))
        rows.append((f"fig6/{pol}_p50_jct_s",
                     float(np.percentile(jcts[pol], 50)), ""))
        rows.append((f"fig6/{pol}_p90_jct_s",
                     float(np.percentile(jcts[pol], 90)), ""))
    reduction = 1.0 - jcts["bino"].mean() / jcts["yarn"].mean()
    rows.append(("fig6/mean_jct_reduction", reduction,
                 vs_paper(reduction, 0.30)))
    return rows
