"""Fig. 5: distribution of job slowdown across the benchmark suite under a
node failure. Paper: YARN mean ≈ 2.8 with σ = 0.61; Bino cuts the variance
to σ = 0.107 (and the mean with it)."""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import (
    Row, SUITE, avg_slowdown, crash_fault, vs_paper)


def run() -> List[Row]:
    rows: List[Row] = []
    stats = {}
    for pol in ("yarn", "bino"):
        _, sds = avg_slowdown(pol, 10.0, crash_fault, benches=SUITE,
                              fracs=(0.4, 0.8), seeds=(1,))
        # per-bench mean slowdown → distribution over applications
        per_bench = np.asarray(sds).reshape(len(SUITE), -1).mean(axis=1)
        stats[pol] = (float(per_bench.mean()), float(per_bench.std()))
    rows.append(("fig5/yarn_mean_slowdown", stats["yarn"][0],
                 vs_paper(stats["yarn"][0], 2.8)))
    rows.append(("fig5/yarn_sigma", stats["yarn"][1],
                 vs_paper(stats["yarn"][1], 0.61)))
    rows.append(("fig5/bino_mean_slowdown", stats["bino"][0], ""))
    rows.append(("fig5/bino_sigma", stats["bino"][1],
                 vs_paper(stats["bino"][1], 0.107)))
    rows.append(("fig5/sigma_reduction", stats["yarn"][1] / max(
        stats["bino"][1], 1e-9), "paper: 0.61 -> 0.107 (5.7x)"))
    return rows
