"""Fig. 9: speculative rollback — inject a disk-write exception into one
map task after k spills; measure the recovery time of that task (failure →
task re-completion). Paper: recovery after 4 spills is 73 % shorter than
after 1 spill (progress is preserved)."""
from __future__ import annotations

from typing import Dict, List

from repro.core.types import AttemptState
from repro.sim import JobSpec, Simulation, faults

from benchmarks.common import Row, vs_paper


def _recovery_time(policy: str, k: int, seed: int = 2) -> float:
    sim = Simulation(policy=policy, seed=seed)
    job = sim.submit(JobSpec("j0", "wordcount", 1.0))
    faults.disk_exception_on_map(sim, job, 0, k)
    sim.run()
    task = job.maps[0]
    failed = [a for a in task.attempts if a.state == AttemptState.FAILED]
    assert failed, "injected disk exception never fired"
    fail_t = failed[0].end_time
    return task.completed_at - fail_t


def run() -> List[Row]:
    rows: List[Row] = []
    rec: Dict[str, Dict[int, float]] = {"yarn": {}, "bino": {}}
    for pol in ("yarn", "bino"):
        for k in (1, 2, 3, 4):
            rec[pol][k] = _recovery_time(pol, k)
            rows.append((f"fig9/{pol}_recovery_s_spill{k}", rec[pol][k],
                         "bino resumes from the spill log"))
    shorter = 1.0 - rec["bino"][4] / rec["bino"][1]
    rows.append(("fig9/bino_spill4_vs_spill1_shorter", shorter,
                 vs_paper(shorter, 0.73)))
    # YARN re-executes from scratch: recovery time roughly flat in k.
    flat = rec["yarn"][4] / rec["yarn"][1]
    rows.append(("fig9/yarn_spill4_vs_spill1_ratio", flat,
                 "≈1 expected (from-scratch re-execution)"))
    return rows
