"""Shuffle-substrate scale benchmark: end-to-end simulation wall-clock vs
cluster size, event-driven vs poll-and-rescan fetch selection.

PR 1 made the assessment path columnar; the measured wall after that was
the simulator's own shuffle bookkeeping (``_fetch_candidates`` rescanned a
reducer's full dependency list per free fetch slot — O(n_maps) per slot,
~2/3 of a 500-node run). This harness runs the same proportionally-sized
job (4 map splits per worker) to *completion or the sim cap* under all
three shuffle engines and records whole-run wall-clock — the rescan row
is the PR 1 baseline (gate: ``event_speedup_500 ≥ 3``), the event row is
the PR 2 baseline for the macro-event fetch plane (ISSUE 4 gate:
``batch`` ≥ 2× over ``event`` at 1000 nodes in the full sweep, with a
softer 500-node smoke gate on the quick budget).

Results land in ``BENCH_scale.json`` next to the ``perf_scale`` rows (the
file is a per-benchmark map with a shared history; see ``_bench_json``).

Usage:
    PYTHONPATH=src python -m benchmarks.perf_shuffle [--quick] [--full]
    PYTHONPATH=src python -m benchmarks.run --only perf_shuffle --quick
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, List

from benchmarks.common import (
    SCALE_N_CONTAINERS,
    SCALE_SIM_SECONDS_FULL,
    SCALE_SIM_SECONDS_QUICK,
    SCALE_SIZES_FULL,
    SCALE_SIZES_QUICK,
    SCALE_SPLITS_PER_WORKER,
    Row,
    bench_json_update,
    bench_quick,
)
from repro.sim.job import JobSpec
from repro.sim.mapreduce import BINO_PARAMS, SimParams, Simulation

# Acceptance gate (ISSUE 2): end-to-end 500-node wall-clock at least this
# much faster than the PR 1 rescan substrate. Asserted, not just printed.
GATE_SPEEDUP_500 = 3.0
# Acceptance gate (ISSUE 4): the batch fetch plane's end-to-end wall vs
# the PR 2 event substrate — 2x at 1000 nodes (full sweep); the quick
# sweep tops out at 500 nodes where the fetch plane is a smaller share
# of total wall, so its smoke gate is softer.
GATE_BATCH_SPEEDUP_1000 = 2.0
GATE_BATCH_SMOKE_500 = 1.3


def measure(policy: str, n_workers: int, *, mode: str,
            sim_seconds: float, seed: int = 0) -> Dict:
    """One proportionally-sized job for ``sim_seconds`` of simulated time;
    report whole-run wall-clock and the shuffle work counters."""
    n_maps = SCALE_SPLITS_PER_WORKER * n_workers
    spec = JobSpec("scale", "terasort", n_maps / 8.0)  # 8 splits per GB
    base = BINO_PARAMS if policy == "bino" else SimParams()
    params = dataclasses.replace(base, sim_time_cap=sim_seconds)
    sim = Simulation(policy=policy, seed=seed, n_workers=n_workers,
                     n_containers=SCALE_N_CONTAINERS, params=params,
                     shuffle=mode)
    sim.submit(spec)
    t0 = time.perf_counter()
    sim.run()
    wall = time.perf_counter() - t0
    prof = sim.shuffle.profile
    return {
        "policy": policy,
        "n_workers": n_workers,
        "n_tasks": spec.n_maps + spec.reduces,
        "mode": mode,
        "sim_seconds": sim_seconds,
        "wall_s": round(wall, 3),
        "slots_filled": prof.slots_filled,
        "selection_work": prof.selection_work,
        "notifies": prof.notifies,
        "slots_per_kwork": round(prof.slots_per_kwork(), 3),
    }


def run() -> List[Row]:
    quick = bench_quick()
    sizes = SCALE_SIZES_QUICK if quick else SCALE_SIZES_FULL
    sim_seconds = SCALE_SIM_SECONDS_QUICK if quick \
        else SCALE_SIM_SECONDS_FULL
    results: List[Dict] = []
    rows: List[Row] = []
    speedup_at = {}
    batch_speedup_at: Dict[int, Dict[str, float]] = {}
    for n in sizes:
        for policy in ("yarn", "bino"):
            ev = measure(policy, n, mode="event", sim_seconds=sim_seconds)
            rs = measure(policy, n, mode="rescan", sim_seconds=sim_seconds)
            ba = measure(policy, n, mode="batch", sim_seconds=sim_seconds)
            results.extend([ev, rs, ba])
            if not (ev["slots_filled"] == rs["slots_filled"]
                    == ba["slots_filled"]):
                raise AssertionError(
                    f"engines diverged at {policy}/{n}n: "
                    f"event filled {ev['slots_filled']} fetch slots, "
                    f"rescan {rs['slots_filled']}, "
                    f"batch {ba['slots_filled']}")
            speedup = rs["wall_s"] / max(ev["wall_s"], 1e-9)
            b_speedup = ev["wall_s"] / max(ba["wall_s"], 1e-9)
            rows.append((
                f"perf_shuffle/{policy}_{n}n_event_wall_s", ev["wall_s"],
                f"rescan={rs['wall_s']:.2f}s speedup={speedup:.1f}x"))
            rows.append((
                f"perf_shuffle/{policy}_{n}n_batch_wall_s", ba["wall_s"],
                f"event={ev['wall_s']:.2f}s speedup={b_speedup:.1f}x"))
            if n == 500:
                speedup_at[policy] = round(speedup, 2)
                rows.append((
                    f"perf_shuffle/{policy}_500n_speedup", speedup,
                    f"gate: >={GATE_SPEEDUP_500:g}x over PR1 rescan "
                    f"substrate"))
            if n in (500, 1000):
                batch_speedup_at.setdefault(n, {})[policy] = \
                    round(b_speedup, 2)
                if n == 1000:
                    rows.append((
                        f"perf_shuffle/{policy}_1000n_batch_speedup",
                        b_speedup,
                        f"gate: >={GATE_BATCH_SPEEDUP_1000:g}x over PR2 "
                        f"event substrate"))
    if speedup_at and max(speedup_at.values()) < GATE_SPEEDUP_500:
        raise AssertionError(
            f"event-shuffle 500-node speedup gate failed: {speedup_at} "
            f"all below {GATE_SPEEDUP_500}x")
    at_1000 = batch_speedup_at.get(1000)
    if at_1000 and max(at_1000.values()) < GATE_BATCH_SPEEDUP_1000:
        raise AssertionError(
            f"batch fetch-plane 1000-node speedup gate failed: {at_1000} "
            f"all below {GATE_BATCH_SPEEDUP_1000}x")
    at_500 = batch_speedup_at.get(500)
    if quick and at_500 and max(at_500.values()) < GATE_BATCH_SMOKE_500:
        # Quick budget only: the full sweep's acceptance gate is the
        # 1000-node assertion above.
        raise AssertionError(
            f"batch fetch-plane 500-node smoke gate failed: {at_500} "
            f"all below {GATE_BATCH_SMOKE_500}x")
    payload = {
        "sim_seconds": sim_seconds,
        "splits_per_worker": SCALE_SPLITS_PER_WORKER,
        "results": results,
        "speedup_at_500": speedup_at,
        "batch_speedup_at": {str(k): v
                             for k, v in batch_speedup_at.items()},
    }
    path = bench_json_update("perf_shuffle", payload,
                             mode="quick" if quick else "full")
    rows.append(("perf_shuffle/json", 1.0, str(path)))
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small sweep (20/100/500 nodes, shorter sim cap)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.quick and not args.full:
        os.environ["REPRO_BENCH_QUICK"] = "1"
    for name, value, derived in run():
        print(f"{name},{value:.4g},{derived}")


if __name__ == "__main__":
    main()
