"""Shuffle-substrate scale benchmark: end-to-end simulation wall-clock vs
cluster size, event-driven vs poll-and-rescan fetch selection.

PR 1 made the assessment path columnar; the measured wall after that was
the simulator's own shuffle bookkeeping (``_fetch_candidates`` rescanned a
reducer's full dependency list per free fetch slot — O(n_maps) per slot,
~2/3 of a 500-node run). This harness runs the same proportionally-sized
job (4 map splits per worker) to *completion or the sim cap* under all
three shuffle engines and records whole-run wall-clock — the rescan row
is the PR 1 baseline (gate: ``event_speedup_500 ≥ 3``), the event row is
the PR 2 baseline for the macro-event fetch plane (ISSUE 4 gate:
``batch`` ≥ 2× over ``event`` at 1000 nodes in the full sweep, with a
softer 500-node smoke gate on the quick budget).

Results land in ``BENCH_scale.json`` next to the ``perf_scale`` rows (the
file is a per-benchmark map with a shared history; see ``_bench_json``).

Usage:
    PYTHONPATH=src python -m benchmarks.perf_shuffle [--quick] [--full]
    PYTHONPATH=src python -m benchmarks.run --only perf_shuffle --quick
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List, Optional

from benchmarks.common import (
    BENCH_JSON,
    SCALE_N_CONTAINERS,
    SCALE_SIM_SECONDS_FULL,
    SCALE_SIM_SECONDS_QUICK,
    SCALE_SIZE_XL,
    SCALE_SIZES_FULL,
    SCALE_SIZES_QUICK,
    SCALE_SPLITS_PER_WORKER,
    Row,
    bench_json_update,
    bench_quick,
    drain_seconds,
)
from repro.obs import TraceRecorder, instrument_drain
from repro.sim.job import JobSpec
from repro.sim.mapreduce import BINO_PARAMS, SimParams, Simulation

# Acceptance gate (ISSUE 2): end-to-end 500-node wall-clock at least this
# much faster than the PR 1 rescan substrate. Asserted, not just printed.
GATE_SPEEDUP_500 = 3.0
# Acceptance gate (ISSUE 4): the batch fetch plane's end-to-end wall vs
# the PR 2 event substrate — 2x at 1000 nodes (full sweep); the quick
# sweep tops out at 500 nodes where the fetch plane is a smaller share
# of total wall, so its smoke gate is softer.
GATE_BATCH_SPEEDUP_1000 = 2.0
GATE_BATCH_SMOKE_500 = 1.3
# Acceptance floor (ISSUE 7): the kernelized bulk-launch drain vs the
# PR 4 batch plane, end-to-end at the 10 000-node tier, on the seed-
# compat flat network where the two are byte-identical (slots_filled
# equality is asserted at every size). Flat has no per-drain recompute
# brackets to amortize, so the kernel's end-to-end win here is just the
# heap-to-lane absorption of milestones and ticks — the drain-cost prize
# gate lives in perf_net's ε-fair tier where the brackets dominate.
GATE_KERNEL_E2E_10K = 1.0
# Acceptance gates (ISSUE 8): the flight recorder's cost discipline at
# the gate size (1000 nodes full / 500 quick), batch engine, min-of-N
# walls on the same seed. obs-enabled is gated in-process against the
# obs-disabled run; obs-disabled (one dead ``is not None`` branch per
# emit site) is gated against the stored pre-PR baseline — but only
# when the stored payload ran the same mode on the same machine shape
# (cpu_count), since cross-machine wall comparisons are meaningless.
GATE_OBS_ENABLED = 1.10
GATE_OBS_DISABLED_VS_BASE = 1.02
OBS_GATE_REPS = 3


def _baseline_wall(n_workers: int, mode: str) -> Optional[float]:
    """The stored (pre-update) perf_shuffle batch wall at ``n_workers``,
    or None when absent or not comparable (different sweep mode or
    machine shape)."""
    if not BENCH_JSON.exists():
        return None
    try:
        doc = json.loads(BENCH_JSON.read_text())
    except (json.JSONDecodeError, OSError):
        return None
    payload = doc.get("benchmarks", {}).get("perf_shuffle")
    if not payload or payload.get("mode") != mode \
            or payload.get("cpu_count") != os.cpu_count():
        return None
    walls = [r["wall_s"] for r in payload.get("results", [])
             if r.get("mode") == "batch" and r.get("policy") == "yarn"
             and r.get("n_workers") == n_workers]
    return min(walls) if walls else None


def _obs_overhead_gate(sim_seconds: float, quick: bool,
                       rows: List[Row]) -> Dict:
    """Measure and assert the recorder's overhead envelope."""
    n = 500 if quick else 1000
    mode = "quick" if quick else "full"
    base_wall = _baseline_wall(n, mode)  # read BEFORE the json update
    off = on = float("inf")
    n_records = 0
    for _ in range(OBS_GATE_REPS):
        off = min(off, measure("yarn", n, mode="batch",
                               sim_seconds=sim_seconds)["wall_s"])
        rec = TraceRecorder()
        on = min(on, measure("yarn", n, mode="batch",
                             sim_seconds=sim_seconds, obs=rec)["wall_s"])
        n_records = len(rec) + rec.dropped
    ratio = on / max(off, 1e-9)
    base_ratio = off / base_wall if base_wall else None
    info = {
        "n_workers": n,
        "reps": OBS_GATE_REPS,
        "disabled_wall_s": round(off, 3),
        "enabled_wall_s": round(on, 3),
        "enabled_ratio": round(ratio, 4),
        "records": n_records,
        "baseline_wall_s": base_wall,
        "disabled_vs_baseline": (round(base_ratio, 4)
                                 if base_ratio is not None else None),
        "baseline_waived": base_wall is None,
    }
    rows.append((
        f"perf_shuffle/obs_overhead_{n}n", ratio,
        f"enabled={on:.2f}s disabled={off:.2f}s "
        f"(gate: <={GATE_OBS_ENABLED:g}x; {n_records} records) "
        + (f"baseline={base_wall:.2f}s ratio={base_ratio:.3f} "
           f"(gate: <={GATE_OBS_DISABLED_VS_BASE:g}x)"
           if base_wall else "baseline: waived (not comparable)")))
    if ratio > GATE_OBS_ENABLED:
        raise AssertionError(
            f"obs-enabled overhead gate failed at {n}n: {ratio:.3f}x "
            f"> {GATE_OBS_ENABLED}x over obs-disabled")
    if base_ratio is not None and base_ratio > GATE_OBS_DISABLED_VS_BASE:
        raise AssertionError(
            f"obs-disabled regression gate failed at {n}n: "
            f"{off:.3f}s is {base_ratio:.3f}x the stored baseline "
            f"{base_wall:.3f}s (gate {GATE_OBS_DISABLED_VS_BASE}x)")
    return info


def _kernel_gates(ba: Dict, ke: Dict, policy: str, n: int) -> None:
    if ke["slots_filled"] != ba["slots_filled"]:
        raise AssertionError(
            f"kernel drain diverged from batch at {policy}/{n}n: "
            f"batch filled {ba['slots_filled']} fetch slots, "
            f"kernel {ke['slots_filled']}")


def measure(policy: str, n_workers: int, *, mode: str,
            sim_seconds: float, seed: int = 0,
            obs: Optional[TraceRecorder] = None) -> Dict:
    """One proportionally-sized job for ``sim_seconds`` of simulated time;
    report whole-run wall-clock and the shuffle work counters. Pass an
    ``obs`` recorder to measure the fully-wired flight-recorder cost."""
    n_maps = SCALE_SPLITS_PER_WORKER * n_workers
    spec = JobSpec("scale", "terasort", n_maps / 8.0)  # 8 splits per GB
    base = BINO_PARAMS if policy == "bino" else SimParams()
    params = dataclasses.replace(base, sim_time_cap=sim_seconds)
    sim = Simulation(policy=policy, seed=seed, n_workers=n_workers,
                     n_containers=SCALE_N_CONTAINERS, params=params,
                     shuffle=mode, obs=obs)
    sim.submit(spec)
    reg = instrument_drain(sim)
    t0 = time.perf_counter()
    sim.run()
    wall = time.perf_counter() - t0
    drain_s = drain_seconds(reg)
    prof = sim.shuffle.profile
    lane = getattr(sim.shuffle, "batches", None)
    recs = lane.applied if lane is not None else 0
    return {
        "policy": policy,
        "n_workers": n_workers,
        "n_tasks": spec.n_maps + spec.reduces,
        "mode": mode,
        "sim_seconds": sim_seconds,
        "wall_s": round(wall, 3),
        "drain_s": round(drain_s, 3),
        "drain_records": recs,
        "drain_us_per_record": round(1e6 * drain_s / max(recs, 1), 2),
        "slots_filled": prof.slots_filled,
        "selection_work": prof.selection_work,
        "notifies": prof.notifies,
        "slots_per_kwork": round(prof.slots_per_kwork(), 3),
    }


def run() -> List[Row]:
    quick = bench_quick()
    sizes = SCALE_SIZES_QUICK if quick else SCALE_SIZES_FULL
    sim_seconds = SCALE_SIM_SECONDS_QUICK if quick \
        else SCALE_SIM_SECONDS_FULL
    results: List[Dict] = []
    rows: List[Row] = []
    speedup_at = {}
    batch_speedup_at: Dict[int, Dict[str, float]] = {}
    kernel_e2e_at: Dict[int, Dict[str, float]] = {}
    for n in sizes:
        for policy in ("yarn", "bino"):
            ev = measure(policy, n, mode="event", sim_seconds=sim_seconds)
            rs = measure(policy, n, mode="rescan", sim_seconds=sim_seconds)
            ba = measure(policy, n, mode="batch", sim_seconds=sim_seconds)
            ke = measure(policy, n, mode="kernel", sim_seconds=sim_seconds)
            results.extend([ev, rs, ba, ke])
            if not (ev["slots_filled"] == rs["slots_filled"]
                    == ba["slots_filled"]):
                raise AssertionError(
                    f"engines diverged at {policy}/{n}n: "
                    f"event filled {ev['slots_filled']} fetch slots, "
                    f"rescan {rs['slots_filled']}, "
                    f"batch {ba['slots_filled']}")
            _kernel_gates(ba, ke, policy, n)
            speedup = rs["wall_s"] / max(ev["wall_s"], 1e-9)
            b_speedup = ev["wall_s"] / max(ba["wall_s"], 1e-9)
            k_speedup = ba["wall_s"] / max(ke["wall_s"], 1e-9)
            kernel_e2e_at.setdefault(n, {})[policy] = round(k_speedup, 2)
            rows.append((
                f"perf_shuffle/{policy}_{n}n_event_wall_s", ev["wall_s"],
                f"rescan={rs['wall_s']:.2f}s speedup={speedup:.1f}x"))
            rows.append((
                f"perf_shuffle/{policy}_{n}n_batch_wall_s", ba["wall_s"],
                f"event={ev['wall_s']:.2f}s speedup={b_speedup:.1f}x"))
            rows.append((
                f"perf_shuffle/{policy}_{n}n_kernel_wall_s", ke["wall_s"],
                f"batch={ba['wall_s']:.2f}s speedup={k_speedup:.2f}x "
                f"lane_records={ke['drain_records']} "
                f"(batch={ba['drain_records']})"))
            if n == 500:
                speedup_at[policy] = round(speedup, 2)
                rows.append((
                    f"perf_shuffle/{policy}_500n_speedup", speedup,
                    f"gate: >={GATE_SPEEDUP_500:g}x over PR1 rescan "
                    f"substrate"))
            if n in (500, 1000):
                batch_speedup_at.setdefault(n, {})[policy] = \
                    round(b_speedup, 2)
                if n == 1000:
                    rows.append((
                        f"perf_shuffle/{policy}_1000n_batch_speedup",
                        b_speedup,
                        f"gate: >={GATE_BATCH_SPEEDUP_1000:g}x over PR2 "
                        f"event substrate"))
    if speedup_at and max(speedup_at.values()) < GATE_SPEEDUP_500:
        raise AssertionError(
            f"event-shuffle 500-node speedup gate failed: {speedup_at} "
            f"all below {GATE_SPEEDUP_500}x")
    at_1000 = batch_speedup_at.get(1000)
    if at_1000 and max(at_1000.values()) < GATE_BATCH_SPEEDUP_1000:
        raise AssertionError(
            f"batch fetch-plane 1000-node speedup gate failed: {at_1000} "
            f"all below {GATE_BATCH_SPEEDUP_1000}x")
    at_500 = batch_speedup_at.get(500)
    if quick and at_500 and max(at_500.values()) < GATE_BATCH_SMOKE_500:
        # Quick budget only: the full sweep's acceptance gate is the
        # 1000-node assertion above.
        raise AssertionError(
            f"batch fetch-plane 500-node smoke gate failed: {at_500} "
            f"all below {GATE_BATCH_SMOKE_500}x")
    kernel_10k = {}
    if not quick:
        # The 10 000-node tier (ISSUE 7): batch vs kernel only — rescan
        # and event are structurally unusable at this size. One policy
        # bounds the tier's runtime; the byte-identity gate makes the
        # policy choice immaterial for correctness.
        n = SCALE_SIZE_XL
        ba = measure("yarn", n, mode="batch", sim_seconds=sim_seconds)
        ke = measure("yarn", n, mode="kernel", sim_seconds=sim_seconds)
        results.extend([ba, ke])
        _kernel_gates(ba, ke, "yarn", n)
        k_speedup = ba["wall_s"] / max(ke["wall_s"], 1e-9)
        kernel_10k = {
            "batch_wall_s": ba["wall_s"],
            "kernel_wall_s": ke["wall_s"],
            "e2e_speedup": round(k_speedup, 2),
            "batch_drain_records": ba["drain_records"],
            "kernel_drain_records": ke["drain_records"],
        }
        rows.append((
            f"perf_shuffle/yarn_{n}n_kernel_speedup", k_speedup,
            f"batch={ba['wall_s']:.2f}s kernel={ke['wall_s']:.2f}s "
            f"(gate: >={GATE_KERNEL_E2E_10K:g}x; drain-cost prize gate "
            f"is perf_net's fair tier)"))
        if k_speedup < GATE_KERNEL_E2E_10K:
            raise AssertionError(
                f"kernel drain 10k-node end-to-end gate failed: "
                f"{k_speedup:.2f} < {GATE_KERNEL_E2E_10K}x over batch")
    obs_overhead = _obs_overhead_gate(sim_seconds, quick, rows)
    payload = {
        "sim_seconds": sim_seconds,
        "splits_per_worker": SCALE_SPLITS_PER_WORKER,
        "obs_overhead": obs_overhead,
        "results": results,
        "speedup_at_500": speedup_at,
        "batch_speedup_at": {str(k): v
                             for k, v in batch_speedup_at.items()},
        "kernel_e2e_speedup_at": {str(k): v
                                  for k, v in kernel_e2e_at.items()},
        "kernel_10k": kernel_10k,
    }
    path = bench_json_update("perf_shuffle", payload,
                             mode="quick" if quick else "full")
    rows.append(("perf_shuffle/json", 1.0, str(path)))
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small sweep (20/100/500 nodes, shorter sim cap)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.quick and not args.full:
        os.environ["REPRO_BENCH_QUICK"] = "1"
    for name, value, derived in run():
        print(f"{name},{value:.4g},{derived}")


if __name__ == "__main__":
    main()
