"""Learned straggler prediction vs LATE/bino (ISSUE 10; DESIGN.md §20).

Trains the §20 predictor end-to-end inside the benchmark — corpus from
the pinned fault scripts, sweep-trained MLP, threshold calibrated on the
train split — then races the trained ``PredictorPolicy`` against the
``yarn`` (LATE-style) and ``bino`` policies on held-out scenario
scripts: the fig1/fig6 crash shapes plus a rack-degrade topology run.
Per scenario it reports finish-time slowdown against each policy's own
fault-free baseline, detection recall (scorecard ``mode="any"``), and
wasted backup launches.

Acceptance gates (asserted, not just printed):
- predictor recall >= bino recall on every scenario with victims;
- predictor false-positive rate (wasted backup launches per true
  straggler, aggregated over the scenario set) <= yarn's;
- the training corpus and threshold calibration are recorded in the
  payload (train/eval split sizes, eval precision/recall) so the
  BENCH_scale.json entry documents exactly which model was measured.

Usage:
    PYTHONPATH=src python -m benchmarks.fig_predictor [--quick]
    PYTHONPATH=src python -m benchmarks.run --only fig_predictor --quick
"""
from __future__ import annotations

import os
import tempfile
from typing import Dict, List

from benchmarks.common import Row, bench_json_update, bench_quick
from repro.obs import TraceRecorder, attempt_outcomes, scorecard
from repro.obs.trace import END_COMPLETED
from repro.sim import JobSpec, faults
from repro.sim.mapreduce import Simulation

# Held-out scenario scripts: fig_scorecard's crash shapes plus a
# rack-degrade run on the topology net. Seeds differ from the corpus
# runs (dataset.CORPUS_RUNS), so the evaluation never replays a
# trajectory the model trained on.
SCENARIOS = {
    "clean": ([], {}),
    "one_crash": ([("crash", 1, 0.2, 0.0)], {}),
    "two_crashes": ([("crash", 1, 0.2, 0.0), ("crash", 2, 0.3, 0.0)], {}),
    "rack_degrade": ([("degrade", 0, 0.25, 0.1), ("slow", 2, 0.3, 0.4)],
                     {"net": "topo", "racks": 4}),
}
SEED = 1
POLICIES = ("yarn", "bino", "predictor")


def _train_model(tmp: str) -> Dict:
    """Corpus + sweep-trained checkpoint under ``tmp``; returns train
    metadata (threshold, split sizes, eval metrics). The full pipeline
    is seconds-scale (corpus ~3 s, 400 full-batch steps ~6 s), so quick
    mode trains the same model as full — thinning the corpus or the
    step count demonstrably under-trains past the gates."""
    from repro.predict.dataset import generate_corpus
    from repro.predict.train import train
    corpus = os.path.join(tmp, "corpus.npz")
    ckpt = os.path.join(tmp, "ckpt")
    generate_corpus(corpus, seed=0)
    return train(corpus, ckpt, seed=0)


def _run_scenario(policy: str, script, kw: Dict, ckpt: str) -> Dict:
    rec = TraceRecorder()
    sim = Simulation(policy=policy, seed=SEED, obs=rec, **kw)
    if policy == "predictor":
        sim.speculator.load_checkpoint(ckpt)
    job = sim.submit(JobSpec("j0", "terasort", 2.0))
    if script:
        faults.apply_script(sim, job, script)
    sim.run()
    card = scorecard(rec, policy=policy, mode="any")
    wasted_launches = sum(1 for o in attempt_outcomes(rec)
                          if o["speculative"]
                          and o["end_code"] != END_COMPLETED)
    return {
        "finish": round(sim.engine.now, 6),
        "recall": card["recall"],
        "victims": len(card["victims"]),
        "n_backups": card["n_backups"],
        "wasted_launches": wasted_launches,
        "wasted_backup_work": card["wasted_backup_work"],
    }


def run() -> List[Row]:
    quick = bench_quick()
    rows: List[Row] = []
    try:
        import jax  # noqa: F401  — training needs it; inference does not
    except Exception:
        rows.append(("fig_predictor/skipped", 1.0,
                     "jax unavailable: predictor training needs the jax "
                     "lane"))
        return rows

    with tempfile.TemporaryDirectory() as tmp:
        meta = _train_model(tmp)
        ckpt = os.path.join(tmp, "ckpt")
        per: Dict[str, Dict[str, Dict]] = {}
        for name, (script, kw) in SCENARIOS.items():
            per[name] = {p: _run_scenario(p, script, kw, ckpt)
                         for p in POLICIES}
        for name in SCENARIOS:
            base = {p: per["clean"][p]["finish"] for p in POLICIES}
            for p in POLICIES:
                r = per[name][p]
                sd = r["finish"] / base[p]
                rows.append((
                    f"fig_predictor/{name}_{p}_slowdown", round(sd, 4),
                    f"recall={r['recall']} victims={r['victims']} "
                    f"backups={r['n_backups']} "
                    f"wasted={r['wasted_launches']}"))

        # Gate 1: recall — the learned policy must catch everything the
        # hand-built binocular policy catches.
        for name in SCENARIOS:
            if per[name]["predictor"]["recall"] < \
                    per[name]["bino"]["recall"] - 1e-9:
                raise AssertionError(
                    f"{name}: predictor recall "
                    f"{per[name]['predictor']['recall']} < bino "
                    f"{per[name]['bino']['recall']}")
        # Gate 2: false-positive rate — wasted backup launches per true
        # straggler, aggregated over the scenario set, no worse than the
        # always-speculating LATE baseline.
        fp_rate = {}
        for p in POLICIES:
            wasted = sum(per[n][p]["wasted_launches"] for n in SCENARIOS)
            victims = sum(per[n][p]["victims"] for n in SCENARIOS)
            fp_rate[p] = wasted / max(victims, 1)
        rows.append(("fig_predictor/fp_rate_predictor",
                     round(fp_rate["predictor"], 4),
                     f"yarn={fp_rate['yarn']:.4g} "
                     f"bino={fp_rate['bino']:.4g}"))
        if fp_rate["predictor"] > fp_rate["yarn"] + 1e-9:
            raise AssertionError(
                f"predictor wastes more backups per straggler than LATE: "
                f"{fp_rate['predictor']:.4g} > {fp_rate['yarn']:.4g}")

        payload = {
            "seed": SEED,
            "scenarios": {n: {"script": [list(s) for s in script],
                              "results": per[n]}
                          for n, (script, kw) in SCENARIOS.items()},
            "fp_rate": {p: round(v, 6) for p, v in fp_rate.items()},
            "model": {
                "threshold": meta["threshold"],
                "hidden": meta["hidden"],
                "steps": meta["steps"],
                "train_rows": meta["split"]["n_train"],
                "eval_rows": meta["split"]["n_eval"],
                "eval": meta["eval"],
                "final_train_loss": meta["final_train_loss"],
            },
        }
    path = bench_json_update("fig_predictor", payload,
                             mode="quick" if quick else "full")
    rows.append(("fig_predictor/json", 1.0, str(path)))
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.quick and not args.full:
        os.environ["REPRO_BENCH_QUICK"] = "1"
    for name, value, derived in run():
        print(f"{name},{value:.4g},{derived}")


if __name__ == "__main__":
    main()
