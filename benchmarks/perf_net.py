"""Network-substrate scale benchmark (ISSUE 5; DESIGN.md §15.6).

The ROADMAP's measured 1000-node bottleneck after PR 4 was the
quasi-static rate rule: every fetch launch observes the previous
completion's flow counts, so the batch lane's fused drain cannot
amortize the rate decisions. The ε-fair model prices launches against
per-link share tables solved **once per drain**; its honest baseline is
the *same model* under per-flow accounting (``recompute="flow"``: one
vectorized water-fill per launch — what the quasi-static discipline
costs once rates come from a real allocator).

This harness runs the proportionally-sized job (4 map splits/worker,
the perf_scale/perf_shuffle shape) to the sim cap on the batch engine
under four network configs — flat (seed-exact reference), topo
(rack-aware quasi-static), fair-drain, fair-flow — and gates
``fair-flow wall / fair-drain wall`` ≥ 1.5× at 1000 nodes (full sweep;
softer 500-node smoke gate on the quick budget). Results land in
``BENCH_scale.json`` under ``perf_net``.

Usage:
    PYTHONPATH=src python -m benchmarks.perf_net [--quick] [--full]
    PYTHONPATH=src python -m benchmarks.run --only perf_net --quick
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, List, Optional

from benchmarks.common import (
    SCALE_N_CONTAINERS,
    SCALE_SIM_SECONDS_FULL,
    SCALE_SIM_SECONDS_QUICK,
    SCALE_SIZE_XL,
    SCALE_SIZES_FULL,
    SCALE_SIZES_QUICK,
    SCALE_SPLITS_PER_WORKER,
    Row,
    bench_json_update,
    bench_quick,
    drain_seconds,
)
from repro.obs import instrument_drain
from repro.sim.job import JobSpec
from repro.sim.mapreduce import SimParams, Simulation

# Acceptance gate (ISSUE 5): the drain-batched ε-fair allocator vs the
# same allocator under per-flow accounting, end-to-end wall on the
# batch engine at 1000 nodes. Asserted, not just printed.
GATE_FAIR_DRAIN_1000 = 1.5
GATE_FAIR_SMOKE_500 = 1.3
# Acceptance gates (ISSUE 7): the kernelized bulk-launch drain vs the
# PR 4 batch plane on the ε-fair network at the 10 000-node tier. The
# drain-cost gate compares per-record drain-path cost (loop + the
# begin/end recompute/rebuild brackets): the kernel absorbs milestones
# and heartbeat/expiry ticks as in-lane records at a few µs apiece
# while batch pays them as ~25 µs heap events outside its drain, so the
# kernel's drain amortizes the brackets over ~3× the records. Measured
# 3.0× per-record / 1.7× end-to-end on the reference box; gates sit
# well below (wall-clock noise on shared CI runners is ±10 %+) and the
# measured values are what BENCH_scale.json records.
GATE_KERNEL_DRAIN_10K = 2.2
GATE_KERNEL_E2E_10K = 1.3

CONFIGS = (
    ("flat", "flat", None),
    ("topo", "topo", None),
    ("fair_drain", "fair", {"recompute": "drain"}),
    ("fair_flow", "fair", {"recompute": "flow"}),
)


def measure(n_workers: int, *, net: str, net_opts: Optional[Dict],
            sim_seconds: float, seed: int = 0,
            shuffle: str = "batch") -> Dict:
    n_maps = SCALE_SPLITS_PER_WORKER * n_workers
    spec = JobSpec("scale", "terasort", n_maps / 8.0)  # 8 splits per GB
    params = dataclasses.replace(SimParams(), sim_time_cap=sim_seconds)
    racks = max(2, n_workers // 25)
    sim = Simulation(policy="yarn", seed=seed, n_workers=n_workers,
                     n_containers=SCALE_N_CONTAINERS, params=params,
                     shuffle=shuffle, net=net, racks=racks,
                     net_opts=net_opts)
    sim.submit(spec)
    reg = instrument_drain(sim)
    t0 = time.perf_counter()
    sim.run()
    wall = time.perf_counter() - t0
    drain_s = drain_seconds(reg)
    prof = sim.shuffle.profile
    lane = getattr(sim.shuffle, "batches", None)
    recs = lane.applied if lane is not None else 0
    return {
        "n_workers": n_workers,
        "racks": racks,
        "net": net,
        "net_opts": net_opts or {},
        "shuffle": shuffle,
        "sim_seconds": sim_seconds,
        "wall_s": round(wall, 3),
        "drain_s": round(drain_s, 3),
        "drain_records": recs,
        "drain_us_per_record": round(1e6 * drain_s / max(recs, 1), 2),
        "slots_filled": prof.slots_filled,
        "recomputes": getattr(sim.cluster.net, "n_recomputes", 0),
        "reallocs": getattr(sim.shuffle, "n_reallocs", 0),
    }


def run() -> List[Row]:
    quick = bench_quick()
    sizes = SCALE_SIZES_QUICK if quick else SCALE_SIZES_FULL
    sim_seconds = SCALE_SIM_SECONDS_QUICK if quick \
        else SCALE_SIM_SECONDS_FULL
    results: List[Dict] = []
    rows: List[Row] = []
    fair_speedup_at: Dict[int, float] = {}
    for n in sizes:
        walls: Dict[str, float] = {}
        batch_fair: Optional[Dict] = None
        for label, net, opts in CONFIGS:
            r = measure(n, net=net, net_opts=opts, sim_seconds=sim_seconds)
            r["config"] = label
            results.append(r)
            walls[label] = r["wall_s"]
            if label == "fair_drain":
                batch_fair = r
            rows.append((f"perf_net/{label}_{n}n_wall_s", r["wall_s"],
                         f"slots={r['slots_filled']} "
                         f"recomputes={r['recomputes']}"))
        speedup = walls["fair_flow"] / max(walls["fair_drain"], 1e-9)
        fair_speedup_at[n] = round(speedup, 2)
        rows.append((
            f"perf_net/fair_drain_speedup_{n}n", speedup,
            f"fair-flow={walls['fair_flow']:.2f}s "
            f"fair-drain={walls['fair_drain']:.2f}s "
            f"(gate at 1000n: >={GATE_FAIR_DRAIN_1000:g}x)"))
        # Kernelized bulk-launch drain on the same ε-fair/drain config
        # (ISSUE 7 smoke coverage at every size; the gated tier is the
        # 10k run below). No slots_filled equality here: drain-boundary
        # recompute cadence differs once milestones/ticks join the lane,
        # the DESIGN.md §17.3 waiver — equivalence on fair is pinned by
        # the fuzz suite's bulk-vs-generic differential instead.
        ke = measure(n, net="fair", net_opts={"recompute": "drain"},
                     sim_seconds=sim_seconds, shuffle="kernel")
        ke["config"] = "fair_kernel"
        results.append(ke)
        ratio = (batch_fair["drain_us_per_record"]
                 / max(ke["drain_us_per_record"], 1e-9))
        rows.append((
            f"perf_net/fair_kernel_{n}n_wall_s", ke["wall_s"],
            f"batch-drain={batch_fair['wall_s']:.2f}s "
            f"drain_cost_ratio={ratio:.2f}x "
            f"({batch_fair['drain_us_per_record']:.1f} -> "
            f"{ke['drain_us_per_record']:.1f} us/record)"))
    at_1000 = fair_speedup_at.get(1000)
    if at_1000 is not None and at_1000 < GATE_FAIR_DRAIN_1000:
        raise AssertionError(
            f"fair drain 1000-node speedup gate failed: {at_1000} < "
            f"{GATE_FAIR_DRAIN_1000}x over per-flow accounting")
    at_500 = fair_speedup_at.get(500)
    if quick and at_500 is not None and at_500 < GATE_FAIR_SMOKE_500:
        raise AssertionError(
            f"fair drain 500-node smoke gate failed: {at_500} < "
            f"{GATE_FAIR_SMOKE_500}x over per-flow accounting")
    kernel_10k = {}
    if not quick:
        # The gated kernel-drain tier (ISSUE 7): 10 000-node terasort on
        # the ε-fair/drain network, batch plane vs kernelized drain.
        n = SCALE_SIZE_XL
        opts = {"recompute": "drain"}
        ba = measure(n, net="fair", net_opts=opts,
                     sim_seconds=sim_seconds)
        ke = measure(n, net="fair", net_opts=opts,
                     sim_seconds=sim_seconds, shuffle="kernel")
        # Drain-boundary reallocation rides along unguarded: recorded
        # for the §17.4 waiver's cost story, not gated.
        re = measure(n, net="fair", net_opts=dict(opts, realloc=True),
                     sim_seconds=sim_seconds, shuffle="kernel")
        for r, label in ((ba, "fair_batch_10k"), (ke, "fair_kernel_10k"),
                         (re, "fair_realloc_10k")):
            r["config"] = label
            results.append(r)
        e2e = ba["wall_s"] / max(ke["wall_s"], 1e-9)
        ratio = (ba["drain_us_per_record"]
                 / max(ke["drain_us_per_record"], 1e-9))
        kernel_10k = {
            "batch_wall_s": ba["wall_s"],
            "kernel_wall_s": ke["wall_s"],
            "e2e_speedup": round(e2e, 2),
            "batch_drain_us_per_record": ba["drain_us_per_record"],
            "kernel_drain_us_per_record": ke["drain_us_per_record"],
            "drain_cost_ratio": round(ratio, 2),
            "realloc_wall_s": re["wall_s"],
            "reallocs": re["reallocs"],
        }
        rows.append((
            f"perf_net/kernel_drain_ratio_{n}n", ratio,
            f"{ba['drain_us_per_record']:.1f} -> "
            f"{ke['drain_us_per_record']:.1f} us/record, e2e={e2e:.2f}x "
            f"(gates: drain>={GATE_KERNEL_DRAIN_10K:g}x, "
            f"e2e>={GATE_KERNEL_E2E_10K:g}x)"))
        if ratio < GATE_KERNEL_DRAIN_10K:
            raise AssertionError(
                f"kernel drain-cost 10k gate failed: {ratio:.2f} < "
                f"{GATE_KERNEL_DRAIN_10K}x over the batch plane")
        if e2e < GATE_KERNEL_E2E_10K:
            raise AssertionError(
                f"kernel end-to-end 10k gate failed: {e2e:.2f} < "
                f"{GATE_KERNEL_E2E_10K}x over the batch plane")
    payload = {
        "sim_seconds": sim_seconds,
        "splits_per_worker": SCALE_SPLITS_PER_WORKER,
        "results": results,
        "fair_drain_speedup_at": {str(k): v
                                  for k, v in fair_speedup_at.items()},
        "kernel_10k": kernel_10k,
    }
    path = bench_json_update("perf_net", payload,
                             mode="quick" if quick else "full")
    rows.append(("perf_net/json", 1.0, str(path)))
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small sweep (20/100/500 nodes, shorter sim cap)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.quick and not args.full:
        os.environ["REPRO_BENCH_QUICK"] = "1"
    for name, value, derived in run():
        print(f"{name},{value:.4g},{derived}")


if __name__ == "__main__":
    main()
