"""Network-substrate scale benchmark (ISSUE 5; DESIGN.md §15.6).

The ROADMAP's measured 1000-node bottleneck after PR 4 was the
quasi-static rate rule: every fetch launch observes the previous
completion's flow counts, so the batch lane's fused drain cannot
amortize the rate decisions. The ε-fair model prices launches against
per-link share tables solved **once per drain**; its honest baseline is
the *same model* under per-flow accounting (``recompute="flow"``: one
vectorized water-fill per launch — what the quasi-static discipline
costs once rates come from a real allocator).

This harness runs the proportionally-sized job (4 map splits/worker,
the perf_scale/perf_shuffle shape) to the sim cap on the batch engine
under four network configs — flat (seed-exact reference), topo
(rack-aware quasi-static), fair-drain, fair-flow — and gates
``fair-flow wall / fair-drain wall`` ≥ 1.5× at 1000 nodes (full sweep;
softer 500-node smoke gate on the quick budget). Results land in
``BENCH_scale.json`` under ``perf_net``.

Usage:
    PYTHONPATH=src python -m benchmarks.perf_net [--quick] [--full]
    PYTHONPATH=src python -m benchmarks.run --only perf_net --quick
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, List, Optional

from benchmarks.common import (
    SCALE_N_CONTAINERS,
    SCALE_SIM_SECONDS_FULL,
    SCALE_SIM_SECONDS_QUICK,
    SCALE_SIZES_FULL,
    SCALE_SIZES_QUICK,
    SCALE_SPLITS_PER_WORKER,
    Row,
    bench_json_update,
    bench_quick,
)
from repro.sim.job import JobSpec
from repro.sim.mapreduce import SimParams, Simulation

# Acceptance gate (ISSUE 5): the drain-batched ε-fair allocator vs the
# same allocator under per-flow accounting, end-to-end wall on the
# batch engine at 1000 nodes. Asserted, not just printed.
GATE_FAIR_DRAIN_1000 = 1.5
GATE_FAIR_SMOKE_500 = 1.3

CONFIGS = (
    ("flat", "flat", None),
    ("topo", "topo", None),
    ("fair_drain", "fair", {"recompute": "drain"}),
    ("fair_flow", "fair", {"recompute": "flow"}),
)


def measure(n_workers: int, *, net: str, net_opts: Optional[Dict],
            sim_seconds: float, seed: int = 0) -> Dict:
    n_maps = SCALE_SPLITS_PER_WORKER * n_workers
    spec = JobSpec("scale", "terasort", n_maps / 8.0)  # 8 splits per GB
    params = dataclasses.replace(SimParams(), sim_time_cap=sim_seconds)
    racks = max(2, n_workers // 25)
    sim = Simulation(policy="yarn", seed=seed, n_workers=n_workers,
                     n_containers=SCALE_N_CONTAINERS, params=params,
                     shuffle="batch", net=net, racks=racks,
                     net_opts=net_opts)
    sim.submit(spec)
    t0 = time.perf_counter()
    sim.run()
    wall = time.perf_counter() - t0
    prof = sim.shuffle.profile
    return {
        "n_workers": n_workers,
        "racks": racks,
        "net": net,
        "net_opts": net_opts or {},
        "sim_seconds": sim_seconds,
        "wall_s": round(wall, 3),
        "slots_filled": prof.slots_filled,
        "recomputes": getattr(sim.cluster.net, "n_recomputes", 0),
    }


def run() -> List[Row]:
    quick = bench_quick()
    sizes = SCALE_SIZES_QUICK if quick else SCALE_SIZES_FULL
    sim_seconds = SCALE_SIM_SECONDS_QUICK if quick \
        else SCALE_SIM_SECONDS_FULL
    results: List[Dict] = []
    rows: List[Row] = []
    fair_speedup_at: Dict[int, float] = {}
    for n in sizes:
        walls: Dict[str, float] = {}
        for label, net, opts in CONFIGS:
            r = measure(n, net=net, net_opts=opts, sim_seconds=sim_seconds)
            r["config"] = label
            results.append(r)
            walls[label] = r["wall_s"]
            rows.append((f"perf_net/{label}_{n}n_wall_s", r["wall_s"],
                         f"slots={r['slots_filled']} "
                         f"recomputes={r['recomputes']}"))
        speedup = walls["fair_flow"] / max(walls["fair_drain"], 1e-9)
        fair_speedup_at[n] = round(speedup, 2)
        rows.append((
            f"perf_net/fair_drain_speedup_{n}n", speedup,
            f"fair-flow={walls['fair_flow']:.2f}s "
            f"fair-drain={walls['fair_drain']:.2f}s "
            f"(gate at 1000n: >={GATE_FAIR_DRAIN_1000:g}x)"))
    at_1000 = fair_speedup_at.get(1000)
    if at_1000 is not None and at_1000 < GATE_FAIR_DRAIN_1000:
        raise AssertionError(
            f"fair drain 1000-node speedup gate failed: {at_1000} < "
            f"{GATE_FAIR_DRAIN_1000}x over per-flow accounting")
    at_500 = fair_speedup_at.get(500)
    if quick and at_500 is not None and at_500 < GATE_FAIR_SMOKE_500:
        raise AssertionError(
            f"fair drain 500-node smoke gate failed: {at_500} < "
            f"{GATE_FAIR_SMOKE_500}x over per-flow accounting")
    payload = {
        "sim_seconds": sim_seconds,
        "splits_per_worker": SCALE_SPLITS_PER_WORKER,
        "results": results,
        "fair_drain_speedup_at": {str(k): v
                                  for k, v in fair_speedup_at.items()},
    }
    path = bench_json_update("perf_net", payload,
                             mode="quick" if quick else "full")
    rows.append(("perf_net/json", 1.0, str(path)))
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small sweep (20/100/500 nodes, shorter sim cap)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.quick and not args.full:
        os.environ["REPRO_BENCH_QUICK"] = "1"
    for name, value, derived in run():
        print(f"{name},{value:.4g},{derived}")


if __name__ == "__main__":
    main()
