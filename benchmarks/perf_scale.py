"""Assessment-path scale benchmark: ticks/sec vs cluster size, columnar
vs per-object reference snapshots, both policies.

The paper's testbed is 21 nodes; the ROADMAP north-star is a
production-scale system that sweeps many failure scenarios fast. The
binding cost is the speculator tick — the seed rebuilt every
TaskView/AttemptView and re-scanned every attempt per tick. This harness
sweeps cluster sizes with a proportionally-sized job (4 map splits per
worker) and measures the assessment path in isolation
(``Simulation.assess_wall`` times snapshot construction + policy assess).

Writes ``BENCH_scale.json`` at the repo root so later PRs append to a
perf trajectory instead of starting from nothing; the acceptance gate is
``columnar_speedup_500 ≥ 10`` for at least one policy.

Usage:
    PYTHONPATH=src python -m benchmarks.perf_scale [--quick] [--full]
    PYTHONPATH=src python -m benchmarks.run --only perf_scale --quick
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, List

from benchmarks.common import (
    SCALE_N_CONTAINERS,
    SCALE_SIM_SECONDS_FULL,
    SCALE_SIM_SECONDS_QUICK,
    SCALE_SIZES_FULL,
    SCALE_SIZES_QUICK,
    SCALE_SPLITS_PER_WORKER,
    Row,
    bench_json_update,
    bench_quick,
)
from repro.sim.job import JobSpec
from repro.sim.mapreduce import BINO_PARAMS, SimParams, Simulation

# Acceptance gate (ISSUE 1): columnar assessment at least this much
# faster than the per-object seed path at 500 nodes, for at least one
# policy. Asserted, not just printed.
GATE_SPEEDUP_500 = 10.0


def measure(policy: str, n_workers: int, *, columnar: bool,
            sim_seconds: float, seed: int = 0) -> Dict:
    """Run one proportionally-sized job for ``sim_seconds`` of simulated
    time and report assessment-tick throughput."""
    n_maps = SCALE_SPLITS_PER_WORKER * n_workers
    input_gb = n_maps / 8.0            # 8 × 128 MiB splits per GB
    spec = JobSpec("scale", "terasort", input_gb)
    base = BINO_PARAMS if policy == "bino" else SimParams()
    params = dataclasses.replace(base, sim_time_cap=sim_seconds)
    sim = Simulation(policy=policy, seed=seed, n_workers=n_workers,
                     n_containers=SCALE_N_CONTAINERS, params=params,
                     columnar=columnar)
    sim.submit(spec)
    t0 = time.perf_counter()
    sim.run()
    wall = time.perf_counter() - t0
    ticks = max(1, sim.assess_ticks)
    return {
        "policy": policy,
        "n_workers": n_workers,
        "n_tasks": spec.n_maps + spec.reduces,
        "mode": "columnar" if columnar else "object",
        "sim_seconds": sim_seconds,
        "assess_ticks": sim.assess_ticks,
        "assess_wall_s": round(sim.assess_wall, 4),
        "ticks_per_s": round(ticks / max(sim.assess_wall, 1e-9), 2),
        "actions": sim.actions_emitted,
        "actions_per_s": round(
            sim.actions_emitted / max(sim.assess_wall, 1e-9), 2),
        "wall_s": round(wall, 3),
    }


def run() -> List[Row]:
    quick = bench_quick()
    sizes = SCALE_SIZES_QUICK if quick else SCALE_SIZES_FULL
    sim_seconds = SCALE_SIM_SECONDS_QUICK if quick \
        else SCALE_SIM_SECONDS_FULL
    results: List[Dict] = []
    rows: List[Row] = []
    for n in sizes:
        for policy in ("yarn", "bino"):
            col = measure(policy, n, columnar=True, sim_seconds=sim_seconds)
            obj = measure(policy, n, columnar=False, sim_seconds=sim_seconds)
            results.extend([col, obj])
            speedup = col["ticks_per_s"] / max(obj["ticks_per_s"], 1e-9)
            rows.append((
                f"perf_scale/{policy}_{n}n_columnar_ticks_per_s",
                col["ticks_per_s"],
                f"object={obj['ticks_per_s']:.1f}/s speedup={speedup:.1f}x"))
            if n == 500:
                rows.append((f"perf_scale/{policy}_500n_speedup", speedup,
                             f"gate: >={GATE_SPEEDUP_500:g}x over "
                             f"per-object seed path"))
    at_500 = [r for r in rows if r[0].endswith("_500n_speedup")]
    if at_500 and max(v for _, v, _ in at_500) < GATE_SPEEDUP_500:
        raise AssertionError(
            f"columnar 500-node speedup gate failed: "
            f"{[(n_, v) for n_, v, _ in at_500]} all below "
            f"{GATE_SPEEDUP_500}x")
    payload = {
        "sim_seconds": sim_seconds,
        "splits_per_worker": SCALE_SPLITS_PER_WORKER,
        "results": results,
        "speedup_at_500": {
            p: round(
                next(r["ticks_per_s"] for r in results
                     if r["policy"] == p and r["n_workers"] == 500
                     and r["mode"] == "columnar")
                / max(next(r["ticks_per_s"] for r in results
                           if r["policy"] == p and r["n_workers"] == 500
                           and r["mode"] == "object"), 1e-9), 2)
            for p in ("yarn", "bino")
        } if any(r["n_workers"] == 500 for r in results) else {},
    }
    path = bench_json_update("perf_scale", payload,
                             mode="quick" if quick else "full")
    rows.append(("perf_scale/json", 1.0, str(path)))
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small sweep (20/100/500 nodes, shorter sim cap)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.quick and not args.full:
        os.environ["REPRO_BENCH_QUICK"] = "1"
    for name, value, derived in run():
        print(f"{name},{value:.4g},{derived}")


if __name__ == "__main__":
    main()
