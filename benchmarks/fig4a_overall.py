"""Fig. 4(a): overall job execution time under node failures injected at
10 %..100 % of map progress. Paper: Bino improves JCT 7.3× @1 GB and
1.9× @10 GB vs YARN."""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, avg_slowdown, crash_fault, vs_paper


def run() -> List[Row]:
    rows: List[Row] = []
    for gb, paper in ((1.0, 7.3), (10.0, 1.9)):
        yarn, _ = avg_slowdown("yarn", gb, crash_fault)
        bino, _ = avg_slowdown("bino", gb, crash_fault)
        imp = yarn / bino
        rows.append((f"fig4a/yarn_slowdown_{gb:g}GB", yarn, ""))
        rows.append((f"fig4a/bino_slowdown_{gb:g}GB", bino, ""))
        rows.append((f"fig4a/improvement_{gb:g}GB", imp,
                     vs_paper(imp, paper)))
    return rows
