"""Fig. 4(c): scope-limited speculation — 1 GB jobs co-located on one node;
that node fails; no MOF recovery path confounds (small job, maps and data
on the victim). Paper: Bino 6.8× better than YARN."""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, avg_slowdown, crash_fault, vs_paper


def run() -> List[Row]:
    yarn, _ = avg_slowdown("yarn", 1.0, crash_fault)
    bino, _ = avg_slowdown("bino", 1.0, crash_fault)
    imp = yarn / bino
    return [
        ("fig4c/yarn_slowdown_1GB", yarn, ""),
        ("fig4c/bino_slowdown_1GB", bino, ""),
        ("fig4c/improvement", imp, vs_paper(imp, 6.8)),
    ]
