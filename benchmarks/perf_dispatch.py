"""Dispatch-plane benchmark (ISSUE 9; ROADMAP open item 1).

Two parts:

**Plane cost** — µs per placement decision at 1 000 and 10 000 nodes
(kernel shuffle engine, a burst of concurrent jobs so the pending
queues stay deep), comparing the multi-tenant plane's bulk placement
pass against the pre-§19 *linear* pass — the single flat pending list
rescanned per dispatch with a per-request heap query and an O(pending)
``has_queued`` — embedded here verbatim as the measurement baseline.
Acceptance gate (full mode): 10 000-node cost per decision at least
``GATE_DECISION_SPEEDUP_10K``× down vs that linear pass.

**Fleet figure** — ``fleet_workload`` bursts (heavy-tailed sizes, MMPP
arrivals; ≥ 100 concurrent jobs in full mode) through all four
policies (yarn / bino / budgeted / clone), reporting p50/p99 job
slowdown vs the per-size fault-free baseline and time-weighted fleet
utilization.

Writes the ``perf_dispatch`` payload into ``BENCH_scale.json``.

Usage:
    PYTHONPATH=src python -m benchmarks.perf_dispatch [--quick] [--full]
    PYTHONPATH=src python -m benchmarks.run --only perf_dispatch --quick
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, List, Optional

import numpy as np

from benchmarks.common import Row, bench_json_update, bench_quick
from repro.core.types import TaskKind, TaskState
from repro.sim.dispatch import Dispatcher, LaunchRequest
from repro.sim.job import JobSpec
from repro.sim.mapreduce import SimParams, Simulation
from repro.sim.runner import baseline_jct, run_workload
from repro.sim.workload import fleet_workload

# Acceptance gate (ISSUE 9): 10 000-node dispatch cost per decision at
# least this much lower on the multi-tenant plane than on the pre-§19
# linear pass. Asserted in full mode, printed in quick mode.
GATE_DECISION_SPEEDUP_10K = 2.0

FLEET_POLICIES = ("yarn", "bino", "budgeted", "clone")


# ---------------------------------------------------------------------------
# The pre-§19 pass, kept as the measurement baseline: one flat pending
# list, full rescan per dispatch, per-request heap query, O(pending)
# has_queued / watchdog set. Subclasses the plane only to inherit the
# Simulation-facing surface; every hot method is the old code plus the
# profile counters the new plane exposes.
# ---------------------------------------------------------------------------
class LegacyLinearDispatcher(Dispatcher):
    def __init__(self, sim):
        super().__init__(sim, profile=True)
        self._pending: List[LaunchRequest] = []

    @property
    def pending(self) -> List[LaunchRequest]:
        return self._pending

    def enqueue(self, req: LaunchRequest) -> None:
        task = req.task
        if task.job.done:
            return  # keep the PR 9 enqueue bugfix out of the comparison
        if task.state == TaskState.COMPLETED and not req.speculative:
            if task.kind == TaskKind.MAP:
                task.job.n_maps_done -= 1
            task.state = TaskState.RUNNING
            task.output_available = bool(task.output_nodes)
            self.sim._arr_task_state(task)
        self._pending.append(req)

    def has_queued(self, task) -> bool:
        return any(r.task is task for r in self._pending)

    def task_done(self, task) -> None:
        pass  # the old plane had no eager purge — stale requests
        # lingered until the next full rescan dropped them

    def job_done(self, job_id: str) -> None:
        pass

    def dispatch(self) -> None:
        sim = self.sim
        t0 = time.perf_counter()
        still: List[LaunchRequest] = []
        for req in self._pending:
            task = req.task
            if task.job.done or task.state == TaskState.COMPLETED:
                continue
            if len(task.running_attempts()) >= \
                    sim.params.max_running_attempts:
                continue  # the old pass dropped capped requests
            exclude = {a.node_id for a in task.running_attempts()}
            exclude |= sim._marked_failed
            self.n_decisions += 1
            node_id = sim.cluster.pick_container(list(req.placement),
                                                 exclude=exclude)
            if node_id is None:
                still.append(req)
                continue
            self.n_grants += 1
            sim._start_attempt(req, node_id)
        self._pending = still
        self.n_scalar_passes += 1
        self.decision_wall += time.perf_counter() - t0

    def watchdog(self) -> None:
        sim = self.sim
        arr = sim.arrays
        candidates = []
        if arr is not None:
            for r in arr.idle_task_rows():
                candidates.append(arr.owner(r).task)
        else:
            for job in sim.active_jobs.values():
                for t in job.tasks:
                    if t.state == TaskState.RUNNING \
                            and not t.running_attempts():
                        candidates.append(t)
        if candidates:
            queued = {r.task.task_id for r in self._pending}
            for t in candidates:
                if t.kind == TaskKind.REDUCE \
                        and not t.job.reduces_scheduled:
                    continue
                if t.task_id not in queued:
                    self.enqueue(LaunchRequest(t, reason="am-watchdog"))
        self.dispatch()


# ---------------------------------------------------------------------------
# Part A: plane cost per decision
# ---------------------------------------------------------------------------
def _burst_specs(n_workers: int) -> List[JobSpec]:
    """A same-instant burst of concurrent jobs sized to ~4 map splits
    per worker in total (PR 7's proportional shape, split across
    tenants so the multi-tenant plane actually rotates)."""
    n_jobs = max(8, n_workers // 50)
    maps_per_job = max(1, 4 * n_workers // n_jobs)
    gb = maps_per_job / 8.0            # 8 × 128 MiB splits per GB
    return [JobSpec(f"b{i:04d}", "terasort", gb, n_reduces=2)
            for i in range(n_jobs)]


def measure_plane(n_workers: int, plane: str, *, sim_seconds: float,
                  seed: int = 0) -> Dict:
    """Kernel-mode burst with 2 containers/worker — demand is 2× the
    slot count, so pending queues stay deep and the cluster sits full
    (the PR 7 profile's regime). ``decision_wall`` brackets the whole
    placement pass; attempt *construction* (``_start_attempt``) is
    identical under both planes and timed out of the metric."""
    params = dataclasses.replace(SimParams(), sim_time_cap=sim_seconds)
    sim = Simulation(policy="yarn", seed=seed, n_workers=n_workers,
                     n_containers=2, params=params, shuffle="kernel",
                     dispatch_opts={"profile": True})
    if plane == "legacy":
        sim.sched = LegacyLinearDispatcher(sim)
    construct = {"s": 0.0}
    orig = sim._start_attempt

    def timed(req, node_id):
        c0 = time.perf_counter()
        r = orig(req, node_id)
        construct["s"] += time.perf_counter() - c0
        return r

    sim._start_attempt = timed
    for spec in _burst_specs(n_workers):
        sim.submit(spec)
    t0 = time.perf_counter()
    sim.run()
    wall = time.perf_counter() - t0
    sched = sim.sched
    plane_wall = max(sched.decision_wall - construct["s"], 1e-9)
    # The comparable unit is the granted launch — both planes issue the
    # same ~N grants for this workload. Normalizing by placement
    # *attempts* would flatter the legacy pass, which burns millions of
    # keep-churn rescans per grant (reported as `attempts` below); the
    # new plane's early-stop visits only what it can place.
    us = 1e6 * plane_wall / max(sched.n_grants, 1)
    return {
        "n_workers": n_workers,
        "plane": plane,
        "n_jobs": len(_burst_specs(n_workers)),
        "sim_seconds": sim_seconds,
        "attempts": sched.n_decisions,
        "grants": sched.n_grants,
        "bulk_passes": sched.n_bulk_passes,
        "scalar_passes": sched.n_scalar_passes,
        "skipped_passes": sched.n_skipped_passes,
        "dispatch_wall_s": round(plane_wall, 4),
        "construct_wall_s": round(construct["s"], 4),
        "us_per_decision": round(us, 3),
        "wall_s": round(wall, 3),
    }


# ---------------------------------------------------------------------------
# Part B: fleet figure
# ---------------------------------------------------------------------------
def _fleet_metrics(sim: Simulation, total_slots: int):
    """Wrap the assessment tick to sample fleet utilization and job
    concurrency (the tick re-schedules itself through the instance
    attribute, so the wrapper stays in the loop)."""
    samples = {"t": [], "busy": [], "jobs": []}
    inner = sim._speculator_tick

    def tick():
        free = int(sim.arrays.node_free.sum()) if sim.arrays is not None \
            else sum(n.free_containers for n in sim.cluster.nodes.values())
        samples["t"].append(sim.engine.now)
        samples["busy"].append(total_slots - free)
        samples["jobs"].append(len(sim.active_jobs))
        inner()

    sim._speculator_tick = tick
    return samples


def measure_fleet(policy: str, specs: List[JobSpec], *, n_workers: int,
                  n_containers: int, seed: int = 0) -> Dict:
    total_slots = n_workers * n_containers
    sim = Simulation(policy=policy, seed=seed, n_workers=n_workers,
                     n_containers=n_containers)
    samples = _fleet_metrics(sim, total_slots)
    for spec in specs:
        sim.submit(spec)
    t0 = time.perf_counter()
    results = sim.run()
    wall = time.perf_counter() - t0
    by_id = {s.job_id: s for s in specs}
    slowdowns = sorted(
        r.jct / baseline_jct(by_id[r.job_id].bench,
                             by_id[r.job_id].input_gb, seed=seed,
                             n_workers=n_workers,
                             n_containers=n_containers)
        for r in results)
    t = np.asarray(samples["t"])
    busy = np.asarray(samples["busy"], dtype=np.float64)
    if len(t) > 1:
        dt = np.diff(t)
        util = float((busy[:-1] * dt).sum() / (total_slots * dt.sum()))
    else:
        util = 0.0
    return {
        "policy": policy,
        "n_jobs": len(specs),
        "n_workers": n_workers,
        "n_containers": n_containers,
        "finished": len(results),
        "max_concurrent_jobs": int(max(samples["jobs"], default=0)),
        "utilization": round(util, 4),
        "p50_slowdown": round(float(np.percentile(slowdowns, 50)), 3),
        "p99_slowdown": round(float(np.percentile(slowdowns, 99)), 3),
        "mean_slowdown": round(float(np.mean(slowdowns)), 3),
        "spec_attempts": int(sum(r.n_spec_attempts for r in results)),
        "wall_s": round(wall, 3),
    }


def run() -> List[Row]:
    quick = bench_quick()
    rows: List[Row] = []
    # -- Part A: µs/decision, bulk plane vs the linear pass ------------
    plane_sizes = (1000,) if quick else (1000, 10_000)
    sim_seconds = 60.0 if quick else 120.0
    plane_results: List[Dict] = []
    speedup_10k: Optional[float] = None
    for n in plane_sizes:
        bulk = measure_plane(n, "bulk", sim_seconds=sim_seconds)
        legacy = measure_plane(n, "legacy", sim_seconds=sim_seconds)
        plane_results.extend([bulk, legacy])
        speedup = legacy["us_per_decision"] / \
            max(bulk["us_per_decision"], 1e-9)
        rows.append((
            f"perf_dispatch/{n}n_us_per_decision",
            bulk["us_per_decision"],
            f"linear={legacy['us_per_decision']:.3g}us "
            f"speedup={speedup:.2f}x "
            f"(dispatch wall {bulk['dispatch_wall_s']:.3g}s vs "
            f"{legacy['dispatch_wall_s']:.3g}s)"))
        if n == 10_000:
            speedup_10k = speedup
            rows.append((
                "perf_dispatch/10000n_decision_speedup", speedup,
                f"gate: >={GATE_DECISION_SPEEDUP_10K:g}x over the "
                f"linear pass"))
    if speedup_10k is not None \
            and speedup_10k < GATE_DECISION_SPEEDUP_10K:
        raise AssertionError(
            f"dispatch-plane 10k gate failed: {speedup_10k:.2f}x < "
            f"{GATE_DECISION_SPEEDUP_10K}x per decision vs linear pass")
    # -- Part B: fleet slowdown + utilization --------------------------
    n_fleet = 40 if quick else 150
    fleet_workers, fleet_containers = 100, 8
    specs = fleet_workload(n_fleet, seed=11, mean_interarrival=1.0,
                           burst_factor=8.0, burst_len=120.0,
                           idle_len=120.0)
    fleet_results: List[Dict] = []
    for policy in FLEET_POLICIES:
        r = measure_fleet(policy, specs, n_workers=fleet_workers,
                          n_containers=fleet_containers)
        fleet_results.append(r)
        rows.append((
            f"perf_dispatch/fleet_{policy}_p99_slowdown",
            r["p99_slowdown"],
            f"p50={r['p50_slowdown']} util={r['utilization']} "
            f"max_concurrent={r['max_concurrent_jobs']} "
            f"spec={r['spec_attempts']}"))
        if r["finished"] != len(specs):
            raise AssertionError(
                f"fleet run incomplete: {policy} finished "
                f"{r['finished']}/{len(specs)}")
    if not quick:
        max_conc = max(r["max_concurrent_jobs"] for r in fleet_results)
        if max_conc < 100:
            raise AssertionError(
                f"fleet figure must reach >=100 concurrent jobs, "
                f"got {max_conc}")
    payload = {
        "plane": plane_results,
        "decision_speedup_10k": None if speedup_10k is None
        else round(speedup_10k, 2),
        "fleet": fleet_results,
        "fleet_n_jobs": n_fleet,
    }
    path = bench_json_update("perf_dispatch", payload,
                             mode="quick" if quick else "full")
    rows.append(("perf_dispatch/json", 1.0, str(path)))
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="1 000-node tier + a 40-job fleet")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.quick and not args.full:
        os.environ["REPRO_BENCH_QUICK"] = "1"
    for name, value, derived in run():
        print(f"{name},{value:.4g},{derived}")


if __name__ == "__main__":
    main()
