"""Fig. 1: job slowdown caused by a single node failure under YARN's
default speculation. Paper: 4.6×–9.2× for 1–10 GB jobs."""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, avg_slowdown, crash_fault, vs_paper

SIZES = (1.0, 2.0, 5.0, 10.0)


def run() -> List[Row]:
    rows: List[Row] = []
    for gb in SIZES:
        mean, _ = avg_slowdown("yarn", gb, crash_fault)
        rows.append((f"fig1/yarn_slowdown_{gb:g}GB", mean,
                     "paper band 4.6-9.2x for 1-10GB"))
    small = [r[1] for r in rows]
    rows.append(("fig1/yarn_slowdown_band_lo", min(small),
                 vs_paper(min(small), 4.6)))
    rows.append(("fig1/yarn_slowdown_band_hi", max(small),
                 vs_paper(max(small), 9.2)))
    return rows
