"""Benchmark aggregator: one module per paper figure, plus the dry-run
roofline summary. Prints ``name,value,derived`` CSV rows.

``--jobs N`` fans the figure modules out over N worker processes. Rows
are still printed in the canonical ``FIGS`` order (results are collected
per module and emitted in submission order), so the CSV is deterministic
regardless of completion order.

Usage:
    PYTHONPATH=src python -m benchmarks.run             # all figures
    PYTHONPATH=src python -m benchmarks.run --only fig4a,fig9
    PYTHONPATH=src python -m benchmarks.run --jobs 4
    PYTHONPATH=src python -m benchmarks.run --only perf_scale --quick
    # shuffle-substrate rows incl. the batch fetch-plane gate (>=2x over
    # event at 1000 nodes, full sweep) merged into BENCH_scale.json:
    PYTHONPATH=src python -m benchmarks.run --only perf_shuffle
"""
from __future__ import annotations

import argparse
import importlib
import os
import sys
import time
import traceback
from typing import List, Tuple

FIGS = [
    "fig1_slowdown",
    "fig4a_overall",
    "fig4b_dependency",
    "fig4c_scope",
    "fig5_variance",
    "fig6_stress",
    "fig7_glance",
    "fig8_collective",
    "fig9_rollback",
    "fig_scorecard",
    "fig_predictor",
    "perf_scale",
    "perf_shuffle",
    "perf_accel",
    "perf_net",
    "perf_runtime",
    "perf_dispatch",
]

# (rows, wall seconds, error string or "")
_ModResult = Tuple[List[Tuple[str, float, str]], float, str]


def _run_module(mod_name: str, quick: bool, inner_procs: int) -> _ModResult:
    """Execute one figure module; runs in a worker process under --jobs.
    ``inner_procs`` caps the module's own sweep fan-out so nested pools
    don't oversubscribe the machine."""
    if quick:
        os.environ["REPRO_BENCH_QUICK"] = "1"
    os.environ["REPRO_BENCH_PROCS"] = str(inner_procs)
    t0 = time.time()
    try:
        mod = importlib.import_module(f"benchmarks.{mod_name}")
        rows = mod.run()
    except Exception as e:
        traceback.print_exc(file=sys.stderr)
        return [], time.time() - t0, f"{type(e).__name__}: {e}"
    return list(rows), time.time() - t0, ""


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated figure prefixes (e.g. fig4a,fig9)")
    ap.add_argument("--quick", action="store_true",
                    help="bounded wall-time budget for modules that "
                         "support it (perf_scale/perf_shuffle: smaller "
                         "size sweep, shorter sim cap)")
    ap.add_argument("--jobs", type=int, default=1,
                    help="run figure modules across N processes "
                         "(CSV row order stays deterministic)")
    args = ap.parse_args()
    if args.quick:
        os.environ["REPRO_BENCH_QUICK"] = "1"
    selected = FIGS
    if args.only:
        keys = [k.strip() for k in args.only.split(",")]
        selected = [f for f in FIGS if any(f.startswith(k) for k in keys)]

    print("name,value,derived")
    failures = []
    jobs = max(1, args.jobs)
    # Modules that merge into BENCH_scale.json must not race each other's
    # read-modify-write; they run serially after the parallel batch.
    writers = {"fig_scorecard", "fig_predictor", "perf_scale",
               "perf_shuffle", "perf_accel", "perf_net", "perf_runtime",
               "perf_dispatch"}
    parallel = [m for m in selected if m not in writers]
    by_mod = {}
    if jobs > 1 and len(parallel) > 1:
        import concurrent.futures as cf
        inner = max(1, (os.cpu_count() or 1) // jobs)
        try:
            with cf.ProcessPoolExecutor(max_workers=jobs) as ex:
                futs = {m: ex.submit(_run_module, m, args.quick, inner)
                        for m in parallel}
                by_mod = {m: f.result() for m, f in futs.items()}
        except (OSError, ImportError, cf.process.BrokenProcessPool):
            # restricted environment (no fork/sem): serial fallback
            by_mod = {}

    def emit(mod_name, outcome):
        rows, wall, err = outcome
        if err:
            failures.append(mod_name)
            print(f"{mod_name}/ERROR,nan,{err}", flush=True)
            return
        for name, value, derived in rows:
            print(f"{name},{value:.4g},{derived}")
        print(f"{mod_name}/wall_s,{wall:.1f},", flush=True)

    # Emit in canonical FIGS order; modules not covered by the parallel
    # batch run (and stream their rows) as this loop reaches them.
    inner = int(os.environ.get("REPRO_BENCH_PROCS",
                               str(os.cpu_count() or 1)))
    for m in selected:
        if m not in by_mod:
            by_mod[m] = _run_module(m, args.quick, inner)
        emit(m, by_mod[m])
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
