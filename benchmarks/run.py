"""Benchmark aggregator: one module per paper figure, plus the dry-run
roofline summary. Prints ``name,value,derived`` CSV rows.

Usage:
    PYTHONPATH=src python -m benchmarks.run             # all figures
    PYTHONPATH=src python -m benchmarks.run --only fig4a,fig9
    PYTHONPATH=src python -m benchmarks.run --only perf_scale --quick
"""
from __future__ import annotations

import argparse
import importlib
import os
import sys
import time
import traceback

FIGS = [
    "fig1_slowdown",
    "fig4a_overall",
    "fig4b_dependency",
    "fig4c_scope",
    "fig5_variance",
    "fig6_stress",
    "fig7_glance",
    "fig8_collective",
    "fig9_rollback",
    "perf_scale",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated figure prefixes (e.g. fig4a,fig9)")
    ap.add_argument("--quick", action="store_true",
                    help="bounded wall-time budget for modules that "
                         "support it (currently perf_scale: smaller size "
                         "sweep, shorter sim cap)")
    args = ap.parse_args()
    if args.quick:
        os.environ["REPRO_BENCH_QUICK"] = "1"
    selected = FIGS
    if args.only:
        keys = [k.strip() for k in args.only.split(",")]
        selected = [f for f in FIGS if any(f.startswith(k) for k in keys)]

    print("name,value,derived")
    failures = []
    for mod_name in selected:
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{mod_name}")
            rows = mod.run()
        except Exception as e:
            failures.append(mod_name)
            print(f"{mod_name}/ERROR,nan,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
            continue
        for name, value, derived in rows:
            print(f"{name},{value:.4g},{derived}")
        print(f"{mod_name}/wall_s,{time.time() - t0:.1f},", flush=True)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
