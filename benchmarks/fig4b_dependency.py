"""Fig. 4(b): dependency-oblivious speculation — JCT when a completed map's
MOF is lost (node stays healthy; no map task failure). Paper: YARN slows
4.0× vs fault-free; Bino is 2.0× better than YARN.

Scenario notes (§IV.B.2 "measurements were collected when there is at least
one fetch failure of MOF"): the qualifying runs lose an EARLY map's MOF
after the map phase drains, so most reducers already fetched it and only
the shuffle stragglers hit fetch failures — few reporters means the AM's
3-report fuse burns through multiple 180 s fetch cycles, which is the
Hadoop stall the paper measures. Only shuffle-heavy applications produce
the qualifying condition (light-shuffle jobs finish fetching the partition
before the loss lands), hence the bench subset.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import Row, avg_slowdown, mof_fault, vs_paper

MOF_FRACS = (1.0,)  # lose the MOF once the map phase has drained
SHUFFLE_HEAVY = ("terasort", "secondarysort", "join", "pagerank")


def run() -> List[Row]:
    yarn, _ = avg_slowdown("yarn", 10.0, mof_fault, fracs=MOF_FRACS,
                           benches=SHUFFLE_HEAVY, seeds=(1, 2, 3))
    bino, _ = avg_slowdown("bino", 10.0, mof_fault, fracs=MOF_FRACS,
                           benches=SHUFFLE_HEAVY, seeds=(1, 2, 3))
    imp = yarn / bino
    return [
        ("fig4b/yarn_slowdown_mof_loss", yarn, vs_paper(yarn, 4.0)),
        ("fig4b/bino_slowdown_mof_loss", bino, ""),
        ("fig4b/improvement", imp, vs_paper(imp, 2.0)),
    ]
