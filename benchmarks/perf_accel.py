"""Assessment-backend benchmark: numpy → jax → pallas live-path
throughput, plus the batched multi-scenario sweep (DESIGN.md §13.4).

Two measurements land in ``BENCH_scale.json`` under ``perf_accel``:

- **live** — assessment ticks/sec of the per-tick policy path on each
  backend (same proportional terasort job as perf_scale). On CPU the
  device backends lose to numpy here (per-tick upload + dispatch beats
  a sub-millisecond kernel); the row exists to track that honestly and
  to catch regressions when a real accelerator flips the sign.
- **sweep** — N fault scenarios scored per device step on a mid-run
  multi-job snapshot: one vmapped jit dispatch vs the same clones walked
  serially on the numpy reference backend. This is where batching wins
  even on CPU; the acceptance gate asserts ≥ 2× amortization at ≥ 8
  scenarios (and the two paths must agree bit-for-bit).

Usage:
    PYTHONPATH=src python -m benchmarks.perf_accel [--quick] [--full]
    PYTHONPATH=src python -m benchmarks.run --only perf_accel --quick
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import (
    SCALE_N_CONTAINERS,
    SCALE_SPLITS_PER_WORKER,
    Row,
    bench_json_update,
    bench_quick,
)
from repro.sim.job import JobSpec
from repro.sim.mapreduce import BINO_PARAMS, SimParams, Simulation

# Acceptance gate (ISSUE 3): the batched sweep must amortize assessment
# across ≥ 8 scenarios at least this much better than scoring them
# serially on the numpy backend. Asserted, not just printed.
GATE_SWEEP_SPEEDUP = 2.0
SWEEP_MIN_SCENARIOS = 8

LIVE_SIZES_QUICK = (20, 100)
LIVE_SIZES_FULL = (20, 100, 500)
LIVE_SIM_SECONDS = 90.0
SWEEP_N_WORKERS = 100
SWEEP_N_JOBS = 30
SWEEP_GRID_QUICK = (8, 16)
SWEEP_GRID_FULL = (8, 16, 32)


def measure_live(policy: str, backend: str, n_workers: int, *,
                 sim_seconds: float, seed: int = 0) -> Dict:
    """Live-path assessment throughput under one backend."""
    n_maps = SCALE_SPLITS_PER_WORKER * n_workers
    spec = JobSpec("scale", "terasort", n_maps / 8.0)
    base = BINO_PARAMS if policy == "bino" else SimParams()
    params = dataclasses.replace(base, sim_time_cap=sim_seconds)
    sim = Simulation(policy=policy, seed=seed, n_workers=n_workers,
                     n_containers=SCALE_N_CONTAINERS, params=params,
                     assess_backend=backend)
    sim.submit(spec)
    t0 = time.perf_counter()
    sim.run()
    wall = time.perf_counter() - t0
    ticks = max(1, sim.assess_ticks)
    return {
        "policy": policy,
        "backend": backend,
        "n_workers": n_workers,
        "sim_seconds": sim_seconds,
        "assess_ticks": sim.assess_ticks,
        "assess_wall_s": round(sim.assess_wall, 4),
        "ticks_per_s": round(ticks / max(sim.assess_wall, 1e-9), 2),
        "actions": sim.actions_emitted,
        "wall_s": round(wall, 3),
    }


def _sweep_snapshot(seed: int = 3) -> Simulation:
    """A mid-run multi-job cluster — the workload shape the multi-job
    speculative-execution literature sweeps (many concurrent jobs)."""
    params = dataclasses.replace(SimParams(), sim_time_cap=100.0)
    sim = Simulation(policy="yarn", seed=seed, n_workers=SWEEP_N_WORKERS,
                     params=params)
    for j in range(SWEEP_N_JOBS):
        sim.submit(JobSpec(f"j{j}", "terasort", 3.0,
                           submit_time=float(j)))
    sim.run()
    return sim


def measure_sweep(sim: Simulation, n_scenarios: int,
                  repeats: int = 3) -> Dict:
    from repro.accel.sweep import BatchedSweep, scenario_grid
    arr = sim.arrays
    scenarios = scenario_grid(n_scenarios, len(arr.node_ids), seed=1)
    sweep = BatchedSweep(arr, sim.engine.now).prepare(scenarios)
    batched = sweep.run_batched()          # compile + warm
    serial = sweep.run_serial()
    for a, b in zip(serial, batched):
        for k in a:
            if not np.array_equal(np.asarray(a[k]), np.asarray(b[k])):
                raise AssertionError(
                    f"sweep paths diverged on {k} (N={n_scenarios})")
    t_batched = min(_timed(sweep.run_batched) for _ in range(repeats))
    t_serial = min(_timed(sweep.run_serial) for _ in range(repeats))
    return {
        "n_scenarios": n_scenarios,
        "n_workers": SWEEP_N_WORKERS,
        "n_jobs_submitted": SWEEP_N_JOBS,
        "n_rows": arr.n,
        "serial_numpy_ms": round(t_serial * 1e3, 2),
        "batched_ms": round(t_batched * 1e3, 2),
        "speedup": round(t_serial / max(t_batched, 1e-9), 2),
        "scenarios_per_s_batched": round(
            n_scenarios / max(t_batched, 1e-9), 1),
    }


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run() -> List[Row]:
    quick = bench_quick()
    sizes = LIVE_SIZES_QUICK if quick else LIVE_SIZES_FULL
    grid = SWEEP_GRID_QUICK if quick else SWEEP_GRID_FULL
    live: List[Dict] = []
    rows: List[Row] = []
    for n in sizes:
        base = None
        for backend in ("numpy", "jax", "pallas"):
            r = measure_live("bino", backend, n,
                             sim_seconds=LIVE_SIM_SECONDS)
            live.append(r)
            if backend == "numpy":
                base = r["ticks_per_s"]
            rel = r["ticks_per_s"] / max(base, 1e-9)
            rows.append((
                f"perf_accel/live_{backend}_{n}n_ticks_per_s",
                r["ticks_per_s"], f"vs numpy {rel:.2f}x"))
    sim = _sweep_snapshot()
    sweeps: List[Dict] = []
    best = 0.0
    for n_sc in grid:
        r = measure_sweep(sim, n_sc)
        sweeps.append(r)
        if n_sc >= SWEEP_MIN_SCENARIOS:
            best = max(best, r["speedup"])
        rows.append((
            f"perf_accel/sweep_{n_sc}x_speedup", r["speedup"],
            f"serial={r['serial_numpy_ms']}ms "
            f"batched={r['batched_ms']}ms "
            f"({r['scenarios_per_s_batched']}/s)"))
    if best < GATE_SWEEP_SPEEDUP:
        # Loaded shared runners skew single measurements; re-measure with
        # more repeats (min-of-5) once before declaring the gate failed.
        for n_sc in grid:
            if n_sc >= SWEEP_MIN_SCENARIOS:
                best = max(best,
                           measure_sweep(sim, n_sc, repeats=5)["speedup"])
    if best < GATE_SWEEP_SPEEDUP:
        raise AssertionError(
            f"batched-sweep gate failed: best speedup {best} at "
            f">={SWEEP_MIN_SCENARIOS} scenarios is below "
            f"{GATE_SWEEP_SPEEDUP}x")
    rows.append(("perf_accel/sweep_gate", best,
                 f"gate: >={GATE_SWEEP_SPEEDUP:g}x vs serial numpy"))
    payload = {
        "live": live,
        "sweep": sweeps,
        "sweep_best_speedup": best,
        "sweep_workload": {"n_workers": SWEEP_N_WORKERS,
                           "n_jobs": SWEEP_N_JOBS},
    }
    path = bench_json_update("perf_accel", payload,
                             mode="quick" if quick else "full")
    rows.append(("perf_accel/json", 1.0, str(path)))
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small live sweep + N in (8, 16)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.quick and not args.full:
        os.environ["REPRO_BENCH_QUICK"] = "1"
    for name, value, derived in run():
        print(f"{name},{value:.4g},{derived}")


if __name__ == "__main__":
    main()
