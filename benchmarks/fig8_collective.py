"""Fig. 8: tuning collective speculation — COLL_INIT_NUM and COLL_MULTIPLY
against a delayed node and a failed node. Paper: COLL_MULTIPLY has the
bigger effect; COLL_INIT_NUM helps less; aggressive settings burn
containers."""
from __future__ import annotations

from typing import List

from repro.core.collective import CollectiveConfig
from repro.core.speculator import BinoConfig, BinocularSpeculator
from repro.sim import JobSpec
from repro.sim.runner import slowdown

from benchmarks.common import Row, crash_fault, delay_fault


def _factory(init: int, mult: int):
    cfg = BinoConfig(collective=CollectiveConfig(
        coll_init_num=init, coll_multiply=mult))
    return lambda node_ids: BinocularSpeculator(node_ids, cfg)


def run() -> List[Row]:
    rows: List[Row] = []
    # A busy-ish cluster (12 workers) so the ramp actually gates launches.
    for fname, fault in (("delay", delay_fault(20.0)),
                         ("fail", crash_fault(0.5))):
        for init, mult in ((1, 1), (1, 2), (1, 4), (2, 2), (4, 2)):
            sd, res = slowdown(
                "bino", JobSpec("j0", "terasort", 10.0), fault,
                seed=1, n_workers=12,
                policy_factory=_factory(init, mult))
            rows.append((
                f"fig8/{fname}_init{init}_mult{mult}", sd,
                f"n_spec={res.n_spec_attempts} "
                "(paper: COLL_MULTIPLY dominates)"))
    return rows
