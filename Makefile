# Developer entry points. Everything also works without install via
# PYTHONPATH=src (the tier-1 convention); `pip install -e .[test]` makes
# the repro package importable directly.
PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test test-fuzz test-net test-runtime test-kernel-drain test-obs \
	test-dispatch test-predict \
	lint bench bench-perf bench-perf-full bench-accel bench-accel-full \
	bench-net bench-net-full bench-runtime bench-runtime-full \
	bench-bulk bench-bulk-full bench-scorecard bench-scorecard-full \
	bench-dispatch bench-dispatch-full \
	train-predictor bench-predictor bench-predictor-full

test:
	$(PY) -m pytest -x -q

# Differential fault-fuzz lane (DESIGN.md §14.4): the pinned corpus runs
# everywhere; with hypothesis installed the random-script budget widens
# to REPRO_FUZZ_EXAMPLES per strategy (CI pins the seed budget here).
test-fuzz:
	REPRO_FUZZ_EXAMPLES=25 $(PY) -m pytest -q \
		tests/test_fuzz_equivalence.py tests/test_engine.py

# Network-substrate lane (DESIGN.md §15): seed byte-identity anchors,
# topo/fair equivalence gates, ε-fair allocator properties, and the
# rack/link fault corpus of the differential fuzzer.
test-net:
	$(PY) -m pytest -q tests/test_net.py
	REPRO_FUZZ_EXAMPLES=15 $(PY) -m pytest -q \
		tests/test_fuzz_equivalence.py -k net

# Kernelized-drain lane (DESIGN.md §17): the kernel engine's byte-
# identity column of the fuzz matrix on flat/topo, the ε-fair
# bulk/scalar/generic differentials + jax bulk-solver parity + realloc
# invariants, and the engine/BatchQueue ordering unit gate. CPU-only:
# jax pinned to the CPU platform, pallas kernels in interpret mode.
test-kernel-drain:
	JAX_PLATFORMS=cpu REPRO_FUZZ_EXAMPLES=10 $(PY) -m pytest -q \
		tests/test_fuzz_equivalence.py tests/test_engine.py \
		-k "kernel or fair or drain or pinned"

# Chaos-hardened live-runtime lane (DESIGN.md §16): fault-free golden +
# the pinned chaos matrix (fault scripts x recovery policies, exactly-
# once bit-identity, differential columnar/reference decisions) on the
# deterministic FakeClock, plus checkpoint crash-safety. Thread-based,
# wall-clock-bounded; REPRO_CHAOS_EXAMPLES widens the randomized-script
# budget (CI pins a small one).
test-runtime:
	REPRO_CHAOS_EXAMPLES=$(or $(REPRO_CHAOS_EXAMPLES),5) \
		$(PY) -m pytest -q \
		tests/test_runtime.py tests/test_data_checkpoint.py

# Multi-tenant dispatch-plane lane (DESIGN.md §19): the two dispatcher
# bugfixes (capped-launch retention, done-job enqueue guard + the
# n_maps_done invariant), DRR fair-share properties, bulk/scalar/legacy
# placement equivalence, the cluster-wide speculation budget policies
# (budgeted/clone), workload generators, and the dispatch column of the
# fuzz matrix.
test-dispatch:
	JAX_PLATFORMS=cpu $(PY) -m pytest -q tests/test_dispatch.py
	JAX_PLATFORMS=cpu REPRO_FUZZ_EXAMPLES=10 $(PY) -m pytest -q \
		tests/test_fuzz_equivalence.py -k dispatch

# Flight-recorder lane (DESIGN.md §18): schema round-trip, bounded
# memory, the obs-on == obs-off byte-identity gate per shuffle engine,
# scorecard math, and the sim vs FakeClock-runtime cross-world
# scorecard identity.
test-obs:
	$(PY) -m pytest -q tests/test_obs.py

# Learned straggler prediction lane (DESIGN.md §20): corpus byte-
# determinism, feature semantics vs hand-computed values, training
# convergence/determinism on a synthetic separable corpus (jax, CPU),
# PredictorPolicy protocol conformance + budget admission, and the new
# policy's column of the engine/obs equivalence matrix.
test-predict:
	JAX_PLATFORMS=cpu $(PY) -m pytest -q tests/test_predict.py

# Ruff config lives in pyproject.toml ([tool.ruff]). Scope = the layers
# the shuffle refactor owns; widen as seed modules are modernized.
# Degrades to a no-op warning where ruff isn't installed (the baked
# container has no network; CI installs it).
LINT_PATHS = src/repro/sim src/repro/net src/repro/core/arrays.py \
	src/repro/accel src/repro/obs src/repro/runtime src/repro/predict \
	benchmarks examples/cluster_sim.py examples/serve.py \
	tests/test_shuffle.py \
	tests/test_columnar.py tests/test_accel.py tests/test_cluster_index.py \
	tests/test_engine.py tests/test_fuzz_equivalence.py tests/test_net.py \
	tests/test_runtime.py tests/test_obs.py tests/test_dispatch.py \
	tests/test_predict.py tests/conftest.py

lint:
	@if $(PY) -m ruff --version >/dev/null 2>&1; then \
		$(PY) -m ruff check $(LINT_PATHS); \
	else \
		echo "ruff not installed; skipping lint (pip install ruff)"; \
	fi

bench:
	$(PY) -m benchmarks.run

# Scale trajectory, appended into BENCH_scale.json: assessment ticks/sec
# (perf_scale, columnar vs per-object) and end-to-end sim wall-clock
# (perf_shuffle, event-driven vs rescan substrate) at 20/100/500 nodes.
# Quick mode keeps the wall budget to a few minutes on a laptop-class
# machine.
bench-perf:
	$(PY) -m benchmarks.run --only perf_scale,perf_shuffle --quick

bench-perf-full:
	$(PY) -m benchmarks.run --only perf_scale,perf_shuffle

# Assessment-backend trajectory (numpy vs jax vs pallas live throughput
# + the batched multi-scenario sweep, gate >= 2x vs serial numpy).
bench-accel:
	$(PY) -m benchmarks.run --only perf_accel --quick

bench-accel-full:
	$(PY) -m benchmarks.run --only perf_accel

# Network-substrate trajectory (flat/topo/fair walls + the fair-drain
# vs per-flow-accounting gate, >= 1.5x at 1000 nodes in the full sweep).
bench-net:
	$(PY) -m benchmarks.run --only perf_net --quick

bench-net-full:
	$(PY) -m benchmarks.run --only perf_net

# Kernelized bulk-launch drain trajectory (DESIGN.md §17.6): kernel vs
# batch walls and drain-path cost in perf_shuffle + perf_net. The quick
# budget smokes flat slots_filled equality and records the fair
# drain-cost ratio at 20/100/500 nodes; the full sweep adds the gated
# 10 000-node tier (drain-cost >= 2.2x, end-to-end >= 1.3x on fair).
bench-bulk:
	$(PY) -m benchmarks.run --only perf_shuffle,perf_net --quick

bench-bulk-full:
	$(PY) -m benchmarks.run --only perf_shuffle,perf_net

# Live-runtime load harness: fault-free p50/p99 step latency + recovery
# time for one crash script under both policies (gate: bino < restart).
bench-runtime:
	$(PY) -m benchmarks.run --only perf_runtime --quick

bench-runtime-full:
	$(PY) -m benchmarks.run --only perf_runtime

# Speculation scorecards (DESIGN.md §18.5): yarn vs bino detection
# precision/recall/time-to-detect from flight-recorder traces on pinned
# fault scripts, with the sim vs live-runtime cross-world identity gate.
bench-scorecard:
	$(PY) -m benchmarks.run --only fig_scorecard --quick

bench-scorecard-full:
	$(PY) -m benchmarks.run --only fig_scorecard

# Multi-tenant dispatch plane (DESIGN.md §19): µs per granted launch,
# bulk plane vs the pre-§19 linear rescan, plus the 100-worker fleet
# figure (p50/p99 job slowdown + utilization for yarn/bino/budgeted/
# clone). The full sweep adds the gated 10 000-node tier (plane cost
# per decision >= 2x down vs the linear pass) and a 150-job fleet
# reaching >= 100 concurrent jobs.
bench-dispatch:
	$(PY) -m benchmarks.run --only perf_dispatch --quick

bench-dispatch-full:
	$(PY) -m benchmarks.run --only perf_dispatch

# Learned straggler predictor (DESIGN.md §20). ``train-predictor``
# regenerates the pinned corpus and sweep-trains a checkpoint under
# artifacts/predictor (git-ignored — checkpoints are reproducible from
# seed, not committed). The figure trains its own model in a tempdir and
# races it against yarn/bino on held-out scenarios, asserting the recall
# and false-positive gates.
train-predictor:
	mkdir -p artifacts/predictor
	$(PY) -m repro.predict.dataset --out artifacts/predictor/corpus.npz
	JAX_PLATFORMS=cpu $(PY) -m repro.predict.train \
		--corpus artifacts/predictor/corpus.npz \
		--out artifacts/predictor/ckpt

bench-predictor:
	JAX_PLATFORMS=cpu $(PY) -m benchmarks.run --only fig_predictor --quick

bench-predictor-full:
	JAX_PLATFORMS=cpu $(PY) -m benchmarks.run --only fig_predictor
