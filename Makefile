# Developer entry points. Everything also works without install via
# PYTHONPATH=src (the tier-1 convention); `pip install -e .[test]` makes
# the repro package importable directly.
PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test bench bench-perf bench-perf-full

test:
	$(PY) -m pytest -x -q

bench:
	$(PY) -m benchmarks.run

# Scale trajectory: assessment ticks/sec at 20/100/500 nodes, columnar vs
# per-object, appended into BENCH_scale.json. Quick mode keeps the wall
# budget to a few minutes on a laptop-class machine.
bench-perf:
	$(PY) -m benchmarks.run --only perf_scale --quick

bench-perf-full:
	$(PY) -m benchmarks.run --only perf_scale
