from repro.parallel.sharding import (
    ACT_RULES,
    PARAM_RULES,
    ShardingRules,
    constrain,
    current_mesh,
    physical_spec,
    set_rules,
    use_mesh,
)

__all__ = [
    "ACT_RULES",
    "PARAM_RULES",
    "ShardingRules",
    "constrain",
    "current_mesh",
    "physical_spec",
    "set_rules",
    "use_mesh",
]
