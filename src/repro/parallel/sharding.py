"""Logical-axis sharding (MaxText-style annotation-only SPMD).

Model code names every tensor dimension with a *logical* axis
(e.g. ``("batch", "seq", "heads", "head_dim")``); a rule table maps logical
axes onto physical mesh axes. XLA's SPMD partitioner inserts the actual
collectives. Two rule tables exist because parameters and activations want
different placements (e.g. ``embed`` is FSDP-sharded over ``data`` on
*weights* but must stay unsharded on *activations*, whose batch dim already
occupies ``data``).

Rules map one logical name to one physical axis or a tuple of axes
(e.g. ``batch → ("pod", "data")``). A mapping is silently dropped for a
tensor whose dimension size is not divisible by the mesh-axis size (MQA
``kv_heads=1``, odd vocab sizes, ``global_batch=1`` long-context decode),
mirroring how production frameworks degrade to replication.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]
ShardingRules = Dict[str, Axis]

# ---------------------------------------------------------------------------
# Default rule tables for the production meshes (pod, data, model).
# ---------------------------------------------------------------------------
PARAM_RULES: ShardingRules = {
    # FSDP/ZeRO: the d_model dim of every weight is sharded over `data`.
    "embed": "data",
    # Tensor parallelism over `model`.
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "expert": "model",         # expert parallelism rides the model axis
    "expert_mlp": None,        # per-expert FFN width stays local
    "mamba_inner": "model",
    "mamba_heads": "model",
    "mamba_group_state": None, # B/C projections replicated (groups < mesh)
    "frontend_feature": None,
    "layers": None,            # scan dim
    "head_dim": None,
    "state": None,
    "conv_kernel": None,
    "norm": None,
}

# Serving layout: no FSDP. Re-gathering ZeRO-sharded weights on every
# decoded token costs ~6 weight all-gathers per layer per token (measured:
# 4.6 GB/device/token on granite-20b decode — §Perf iteration 4); decode
# wants weights resident: TP over `model`, replicated over `data`.
SERVE_PARAM_RULES: ShardingRules = dict(PARAM_RULES, embed=None)

ACT_RULES: ShardingRules = {
    "batch": ("pod", "data"),
    "seq": None,
    # KV-cache sequence dim: sharded over `model` (distributed flash-decode;
    # falls back automatically when `model` is already taken by kv_heads).
    "kv_seq": "model",
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "expert_cap": ("pod", "data"),  # MoE dispatch buffer capacity dim
    "expert_mlp": None,
    "mamba_inner": "model",
    "mamba_heads": "model",
    "mamba_group_state": None,
    "head_dim": None,
    "state": None,
    "conv_kernel": None,
}

# ---------------------------------------------------------------------------
# Mesh + rules context (thread-local so the simulator's worker threads can
# hold distinct meshes).
# ---------------------------------------------------------------------------
_ctx = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_ctx, "mesh", None)


def _current_rules() -> Tuple[ShardingRules, ShardingRules]:
    return (
        getattr(_ctx, "param_rules", PARAM_RULES),
        getattr(_ctx, "act_rules", ACT_RULES),
    )


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh],
             param_rules: Optional[ShardingRules] = None,
             act_rules: Optional[ShardingRules] = None):
    """Activate a mesh (and optional rule overrides) for model tracing."""
    prev = (getattr(_ctx, "mesh", None),
            getattr(_ctx, "param_rules", PARAM_RULES),
            getattr(_ctx, "act_rules", ACT_RULES))
    _ctx.mesh = mesh
    _ctx.param_rules = param_rules or PARAM_RULES
    _ctx.act_rules = act_rules or ACT_RULES
    try:
        yield mesh
    finally:
        _ctx.mesh, _ctx.param_rules, _ctx.act_rules = prev


@contextlib.contextmanager
def set_rules(param_rules: Optional[ShardingRules] = None,
              act_rules: Optional[ShardingRules] = None):
    """Override rule tables only (mesh unchanged) — used by perf sweeps."""
    with use_mesh(current_mesh(), param_rules, act_rules):
        yield


# ---------------------------------------------------------------------------
# Logical → physical resolution.
# ---------------------------------------------------------------------------
def _axis_size(mesh: Mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis] if axis in mesh.shape else 0
    size = 1
    for a in axis:
        if a not in mesh.shape:
            return 0
        size *= mesh.shape[a]
    return size


def physical_spec(shape: Sequence[int],
                  logical: Sequence[Optional[str]],
                  rules: ShardingRules,
                  mesh: Mesh) -> P:
    """Resolve logical axis names to a PartitionSpec, dropping mappings whose
    mesh-axis product does not evenly divide the dimension, and never mapping
    one mesh axis twice."""
    assert len(shape) == len(logical), (shape, logical)
    used: set = set()
    out = []
    for dim, name in zip(shape, logical):
        axis: Axis = rules.get(name) if name is not None else None
        if axis is None:
            out.append(None)
            continue
        axes = (axis,) if isinstance(axis, str) else tuple(axis)
        # keep only mesh axes that exist, are unused, and divide the dim
        kept = []
        size = 1
        for a in axes:
            if a in mesh.shape and a not in used:
                kept.append(a)
                size *= mesh.shape[a]
        if kept and dim % size == 0 and dim > 0:
            used.update(kept)
            out.append(tuple(kept) if len(kept) > 1 else kept[0])
        else:
            out.append(None)
    return P(*out)


def named_sharding(shape: Sequence[int],
                   logical: Sequence[Optional[str]],
                   rules: ShardingRules,
                   mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, physical_spec(shape, logical, rules, mesh))


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate an activation with its logical sharding (no-op off-mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    _, act_rules = _current_rules()
    spec = physical_spec(x.shape, logical, act_rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def param_sharding_tree(axes_tree, shapes_tree, mesh: Mesh,
                        rules: Optional[ShardingRules] = None):
    """Map a pytree of logical-axis tuples + matching ShapeDtypeStructs to a
    pytree of NamedShardings (for jit in_shardings)."""
    if rules is None:
        rules, _ = _current_rules()

    def resolve(axes, shape_struct):
        return named_sharding(shape_struct.shape, axes, rules, mesh)

    return jax.tree.map(
        resolve, axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x),
    )
