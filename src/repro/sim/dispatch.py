"""Container scheduling: the multi-tenant AM/RM dispatch plane
(DESIGN.md §12.4, §19).

Owns the pending-launch queues and the container-placement pass that was
inlined in ``Simulation``. The dispatcher decides *where and when* an
attempt runs (placement preference, exclusion of sibling hosts and
marked-failed nodes, max-running-attempts cap); the simulation retains
attempt *construction* (``Simulation._start_attempt``) because that is
lifecycle state (arrays write-through, milestones, shuffle attach).

Since ISSUE 9 the plane is multi-tenant (tenant = job):

* **Per-tenant queues + index.** Pending launches live in per-job
  deques, with a ``task_id → queued-count`` index, so ``has_queued`` and
  the watchdog's queued-set are O(1) instead of O(pending) scans.
* **Deficit round-robin fair-share.** With more than one tenant holding
  demand, free containers are granted by DRR over the tenant rotation
  (arrival order): each cycle a tenant earns its quantum (weight,
  default 1) of container credit and serves until a grant spends it or
  its head request blocks. A single tenant — or ``fair=False`` — runs
  the legacy strict-FIFO pass, byte-identical to the pre-§19 plane (the
  single-job equivalence gate; with ``fair=False`` all tenants share one
  arrival-ordered queue, i.e. the exact legacy global FIFO).
* **Bulk placement.** With the columnar mirror on and a deep enough
  batch, the placement pass runs against a pass-local copy of the
  ``node_free`` column with a low-water pointer instead of per-request
  heap queries — same decisions (the dispatch column of the fuzz matrix
  pins bulk ≡ scalar byte-identical), one vectorized setup per drain in
  the spirit of PR 7's bulk staging.
* **Capped requests are retained** (ISSUE 9 bugfix). The old pass
  silently dropped a ``LaunchRequest`` whose task sat at
  ``max_running_attempts``, losing rollback/placement metadata; the
  request now stays queued until the cap clears or the task finishes.
* **``enqueue`` is a no-op for finished jobs** (ISSUE 9 bugfix). The
  completed-producer re-execution branch used to mutate task state and
  decrement ``n_maps_done`` before checking whether the request could
  ever place; a request against a done job is now dropped before any
  mutation (the ``n_maps_done >= 0`` invariant in tests/conftest.py).
"""
from __future__ import annotations

import time
from collections import OrderedDict, deque
from typing import TYPE_CHECKING, Deque, Dict, List, Optional, Tuple

import dataclasses

from repro.core.types import TaskKind, TaskState
from repro.obs.trace import K_DISPATCH

if TYPE_CHECKING:  # pragma: no cover - import cycle guard, typing only
    from repro.sim.mapreduce import SimTask, Simulation


@dataclasses.dataclass
class LaunchRequest:
    task: "SimTask"
    placement: Tuple[str, ...] = ()
    speculative: bool = False
    rollback: bool = False
    rollback_node: Optional[str] = None
    reason: str = ""


# Placement-pass outcomes (shared by the scalar and bulk passes).
_GRANT, _KEEP, _DROP = 0, 1, 2

# Batch depth at which the bulk pass pays for its per-pass setup (one
# node_free copy); below it the scalar heap query wins.
_BULK_MIN = 16


class Dispatcher:
    """Per-tenant pending queues + the placement pass over free
    containers.

    ``fair``      — DRR fair-share across tenants (default). ``False``
                    collapses every tenant into one arrival-ordered
                    queue: the legacy global-FIFO pass.
    ``bulk``      — force the bulk placement pass on/off; ``None``
                    (default) auto-selects it when the columnar mirror
                    exists and the batch is at least ``bulk_min`` deep.
    ``weights``   — optional tenant → DRR quantum map (containers of
                    credit per rotation cycle; default 1.0 each).
    ``profile``   — accumulate wall-clock in ``decision_wall`` around
                    each placement pass (benchmarks/perf_dispatch.py).
    """

    def __init__(self, sim: "Simulation", *, fair: bool = True,
                 bulk: Optional[bool] = None, bulk_min: int = _BULK_MIN,
                 weights: Optional[Dict[str, float]] = None,
                 profile: bool = False):
        self.sim = sim
        self.fair = fair
        self.bulk = bulk
        self.bulk_min = bulk_min
        self.weights = weights or {}
        for jid, w in self.weights.items():
            if not w > 0:
                raise ValueError(f"tenant weight must be > 0: {jid}={w}")
        self.profile = profile
        # tenant (job_id) → FIFO of its pending launches, in arrival
        # order of first demand; "" is the shared legacy queue
        # (fair=False).
        self._queues: "OrderedDict[str, Deque[LaunchRequest]]" = \
            OrderedDict()
        # task_id → number of queued requests (the O(1) has_queued /
        # watchdog index).
        self._queued: Dict[str, int] = {}
        self._total = 0
        # Plane accounting (read by benchmarks and the metrics plane).
        self.n_decisions = 0   # placement decisions attempted
        self.n_grants = 0      # containers granted
        self.n_bulk_passes = 0
        self.n_scalar_passes = 0
        self.n_skipped_passes = 0   # zero-free early-outs
        self.decision_wall = 0.0

    # ------------------------------------------------------------------
    # Queue maintenance
    # ------------------------------------------------------------------
    def _tenant(self, req: LaunchRequest) -> str:
        return req.task.job.spec.job_id if self.fair else ""

    def enqueue(self, req: LaunchRequest) -> None:
        task = req.task
        if task.job.done:
            # The placement pass would drop this request unlaunched
            # anyway; dropping it *before* the completed-producer branch
            # keeps a finished job's n_maps_done / task states frozen
            # (ISSUE 9 bugfix — MOF loss racing job completion).
            return
        if task.state == TaskState.COMPLETED and not req.speculative:
            # re-execution of a completed producer
            if task.kind == TaskKind.MAP:
                task.job.n_maps_done -= 1
            task.state = TaskState.RUNNING
            task.output_available = bool(task.output_nodes)
            self.sim._arr_task_state(task)
        jid = self._tenant(req)
        q = self._queues.get(jid)
        if q is None:
            q = self._queues[jid] = deque()
        q.append(req)
        tid = task.task_id
        self._queued[tid] = self._queued.get(tid, 0) + 1
        self._total += 1

    def _unindex(self, task: "SimTask") -> None:
        tid = task.task_id
        c = self._queued.get(tid, 0) - 1
        if c > 0:
            self._queued[tid] = c
        else:
            self._queued.pop(tid, None)
        self._total -= 1

    def task_done(self, task: "SimTask") -> None:
        """Eager purge on task completion: queued launches for the task
        drop immediately, so ``has_queued`` flips false the instant the
        task completes (not at the next placement pass) and an unvisited
        request can never be a stale drop — what lets the placement pass
        stop at pool exhaustion instead of rescanning the whole backlog.
        O(1) when the task had nothing queued (the common case)."""
        if not self._queued.pop(task.task_id, 0):
            return
        jid = task.job.spec.job_id if self.fair else ""
        q = self._queues.get(jid)
        if q:
            kept = deque(r for r in q if r.task is not task)
            self._total -= len(q) - len(kept)
            self._queues[jid] = kept

    def job_done(self, job_id: str) -> None:
        """Tenant teardown on job completion: the whole queue drops."""
        if self.fair:
            q = self._queues.pop(job_id, None)
            if not q:
                return
        else:
            shared = self._queues.get("")
            if not shared:
                return
            q = [r for r in shared
                 if r.task.job.spec.job_id == job_id]
            if not q:
                return
            self._queues[""] = deque(
                r for r in shared if r.task.job.spec.job_id != job_id)
        for r in q:
            self._unindex(r.task)

    @property
    def pending(self) -> List[LaunchRequest]:
        """Flat view of every queued launch (tenant rotation order, FIFO
        within a tenant) — compatibility/introspection only; the plane
        itself never walks it."""
        return [r for q in self._queues.values() for r in q]

    def has_queued(self, task: "SimTask") -> bool:
        return self._queued.get(task.task_id, 0) > 0

    # ------------------------------------------------------------------
    # Placement pass
    # ------------------------------------------------------------------
    def dispatch(self) -> None:
        if not self._total:
            return
        t0 = time.perf_counter() if self.profile else 0.0
        arr = self.sim.arrays
        # Grant budget: a pass can grant at most the cluster's free
        # slots, and with the eager task_done/job_done purge every
        # queued request is live, so once the pool is spent the rest of
        # the backlog could only KEEP — stopping there is
        # outcome-identical to the full rescan. The sum may overcount
        # by marked-node slots (excluded from placement); that only
        # delays the stop, never changes a decision. Without the
        # columnar mirror there is no O(nodes) free sum, so the
        # reference pass visits everything (budget=None).
        budget: Optional[int] = None
        if arr is not None:
            budget = int(arr.node_free.sum())
            if not budget:
                # Cluster exactly full: nothing can place; skip the
                # pass entirely. O(nodes) early-out instead of the
                # O(pending) full rescan that was the bulk of the
                # PR 7 10 000-node dispatch wall.
                self.n_skipped_passes += 1
                if self.profile:
                    self.decision_wall += time.perf_counter() - t0
                return
        if self.bulk is None:
            use_bulk = arr is not None and self._total >= self.bulk_min
        else:
            use_bulk = bool(self.bulk) and arr is not None
        if use_bulk:
            self.n_bulk_passes += 1
            self._run_pass(self._make_bulk_try(), budget)
        else:
            self.n_scalar_passes += 1
            self._run_pass(self._try_scalar, budget)
        if self.profile:
            self.decision_wall += time.perf_counter() - t0

    def _run_pass(self, try_place, budget: Optional[int]) -> None:
        """One placement pass: every queued request is visited at most
        once, and at most ``budget`` grants are issued (the pass stops
        once the free pool is provably spent — the unvisited tail is
        all live requests that could only KEEP). Single tenant (or
        fair=False): strict FIFO — the legacy pass. Multiple tenants:
        deficit round-robin."""
        tenants = [jid for jid, q in self._queues.items() if q]
        if len(tenants) <= 1:
            for jid in tenants:
                q = self._queues[jid]
                kept: Deque[LaunchRequest] = deque()
                while q:
                    req = q.popleft()
                    out = try_place(req)
                    if out is _KEEP:
                        kept.append(req)
                    elif out is _GRANT and budget is not None:
                        budget -= 1
                        if not budget:
                            break  # pool spent: stop the pass
                kept.extend(q)  # untried tail keeps FIFO order
                self._queues[jid] = kept
            return
        self._drr_pass(tenants, try_place, budget)

    def _drr_pass(self, tenants: List[str], try_place,
                  budget: Optional[int]) -> None:
        """Deficit round-robin over the tenant rotation (arrival order).
        Each cycle a tenant earns its quantum of container credit and
        serves its queue head-first until a grant spends the credit or
        the head request blocks (no free non-excluded container) — a
        blocked tenant yields the cycle but keeps its place in the
        rotation, so it catches up within the pass once siblings'
        demand drains (the no-starvation property in
        tests/test_dispatch.py). Drops (job done / task completed) cost
        nothing. Unit container cost; quantum defaults to 1.

        Deficit credit is pass-local: a full pass always drains every
        live queue (each cycle moves the head to granted or kept), so
        credit never survives to the next pass — which is also what
        makes the ``budget`` early-stop exact, since the skipped
        keep-churn tail has no carried state to diverge on."""
        kept: Dict[str, Deque[LaunchRequest]] = {
            jid: deque() for jid in tenants}
        deficit: Dict[str, float] = {}
        active: Deque[str] = deque(tenants)
        stop = False
        while active and not stop:
            jid = active.popleft()
            q = self._queues[jid]
            d = deficit.get(jid, 0.0) + self.weights.get(jid, 1.0)
            while q and d >= 1.0:
                req = q.popleft()
                out = try_place(req)
                if out is _GRANT:
                    d -= 1.0
                    if budget is not None:
                        budget -= 1
                        if not budget:
                            stop = True  # pool spent: stop the pass
                            break
                elif out is _KEEP:
                    kept[jid].append(req)
                    break  # head blocked: yield the cycle
            if q and not stop:
                # Carry at most one quantum of credit while blocked —
                # bounded catch-up, not an unbounded burst later.
                deficit[jid] = min(d, self.weights.get(jid, 1.0))
                active.append(jid)
        for jid in tenants:
            q = self._queues[jid]
            if kept[jid]:
                kept[jid].extend(q)  # untried tail keeps FIFO order
                self._queues[jid] = kept[jid]

    # --- shared request logic ------------------------------------------
    def _screen(self, req: LaunchRequest) -> Optional[int]:
        """Drop/cap screening shared by the scalar and bulk passes;
        returns an outcome or None when placement should be attempted."""
        task = req.task
        if task.job.done or task.state == TaskState.COMPLETED:
            self._unindex(task)
            return _DROP
        if len(task.running_attempts()) >= \
                self.sim.params.max_running_attempts:
            # ISSUE 9 bugfix: retain the request (metadata and all)
            # until the cap clears, instead of silently dropping it.
            return _KEEP
        return None

    def _grant(self, req: LaunchRequest, node_id: str) -> int:
        sim = self.sim
        self._unindex(req.task)
        self.n_grants += 1
        if sim.obs is not None:
            sim.obs.emit(
                K_DISPATCH, a=sim.cluster._node_pos[node_id],
                b=(1 if req.speculative else 0) |
                  (2 if req.rollback else 0),
                obj=req.reason or None)
        sim._start_attempt(req, node_id)
        return _GRANT

    # --- scalar placement (reference): per-request heap query ----------
    def _try_scalar(self, req: LaunchRequest) -> int:
        out = self._screen(req)
        if out is not None:
            return out
        sim = self.sim
        task = req.task
        exclude = {a.node_id for a in task.running_attempts()}
        exclude |= sim._marked_failed
        self.n_decisions += 1
        node_id = sim.cluster.pick_container(list(req.placement),
                                             exclude=exclude)
        if node_id is None:
            return _KEEP
        return self._grant(req, node_id)

    # --- bulk placement: pass-local free vector + low-water pointer ----
    def _make_bulk_try(self):
        """Build the bulk placement closure for ONE pass. Setup copies
        the columnar ``node_free`` mirror once and zeroes marked-failed
        nodes (excluded for every request, exactly as the scalar pass
        unions ``_marked_failed`` into each exclude set; dead nodes
        already mirror 0 free). Per request the pack-first choice is the
        lowest-index node with local free > 0 that is not a running
        sibling's host — ``Cluster.pick_container``'s documented
        semantics — found by a low-water pointer over the exhausted
        prefix. Grants decrement the local vector; nothing else can
        change free counts mid-pass (attempt construction schedules
        engine events, it never completes work synchronously)."""
        sim = self.sim
        arr = sim.arrays
        free_col = arr.node_free.copy()
        free_col[arr.node_marked] = 0
        # Plain list: the per-request ops below are scalar reads and
        # decrements, where ndarray item access costs several times a
        # list index.
        free = free_col.tolist()
        nidx = arr.node_index
        node_ids = arr.node_ids
        n = len(node_ids)
        state = {"lo": 0}

        def try_place(req: LaunchRequest) -> int:
            out = self._screen(req)
            if out is not None:
                return out
            self.n_decisions += 1
            exclude = {nidx[a.node_id]
                       for a in req.task.running_attempts()}
            for pref in req.placement:
                j = nidx.get(pref)
                if j is not None and free[j] > 0 and j not in exclude:
                    free[j] -= 1
                    return self._grant(req, node_ids[j])
            i = state["lo"]
            while i < n and free[i] <= 0:
                i += 1
            state["lo"] = i  # prefix permanently exhausted this pass
            while i < n and (free[i] <= 0 or i in exclude):
                i += 1
            if i >= n:
                return _KEEP
            free[i] -= 1
            return self._grant(req, node_ids[i])

        return try_place

    # ------------------------------------------------------------------
    def watchdog(self) -> None:
        """AM retry loop: any live task with no running attempt and no
        queued launch gets re-enqueued (covers killed/failed edges).

        With the columnar mirror available, the candidate scan is one
        segmented reduction over the attempt columns
        (:meth:`ArraySnapshot.idle_task_rows`) instead of an
        O(tasks × attempts) object walk per tick; rows arrive in
        canonical §11.3 order, which is exactly the reference loop's
        job-submission → task-creation order, so the enqueue sequence
        is identical (test_columnar's trace gate covers this). The
        queued-launch check is the O(1) ``_queued`` index — the old
        O(pending) set build is gone.
        """
        sim = self.sim
        arr = sim.arrays
        candidates: List["SimTask"] = []
        if arr is not None:
            for r in arr.idle_task_rows():
                candidates.append(arr.owner(r).task)
        else:
            for job in sim.active_jobs.values():
                for t in job.tasks:
                    if t.state == TaskState.RUNNING \
                            and not t.running_attempts():
                        candidates.append(t)
        for t in candidates:
            if t.kind == TaskKind.REDUCE \
                    and not t.job.reduces_scheduled:
                continue
            if t.task_id not in self._queued:
                self.enqueue(LaunchRequest(t, reason="am-watchdog"))
        self.dispatch()
