"""Container scheduling: the AM/RM launch queue (DESIGN.md §12.4).

Owns the pending-launch queue and the container-placement pass that was
inlined in ``Simulation``. The dispatcher decides *where and when* an
attempt runs (placement preference, exclusion of sibling hosts and
marked-failed nodes, max-running-attempts cap); the simulation retains
attempt *construction* (``Simulation._start_attempt``) because that is
lifecycle state (arrays write-through, milestones, shuffle attach).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, List, Optional, Tuple

from repro.core.types import TaskKind, TaskState
from repro.obs.trace import K_DISPATCH

if TYPE_CHECKING:  # pragma: no cover - import cycle guard, typing only
    from repro.sim.mapreduce import SimTask, Simulation


@dataclasses.dataclass
class LaunchRequest:
    task: "SimTask"
    placement: Tuple[str, ...] = ()
    speculative: bool = False
    rollback: bool = False
    rollback_node: Optional[str] = None
    reason: str = ""


class Dispatcher:
    """Pending launches + the placement pass over free containers."""

    def __init__(self, sim: "Simulation"):
        self.sim = sim
        self.pending: List[LaunchRequest] = []

    def enqueue(self, req: LaunchRequest) -> None:
        if req.task.state == TaskState.COMPLETED and not req.speculative:
            # re-execution of a completed producer
            if req.task.kind == TaskKind.MAP:
                req.task.job.n_maps_done -= 1
            req.task.state = TaskState.RUNNING
            req.task.output_available = bool(req.task.output_nodes)
            self.sim._arr_task_state(req.task)
        self.pending.append(req)

    def dispatch(self) -> None:
        sim = self.sim
        still: List[LaunchRequest] = []
        for req in self.pending:
            task = req.task
            if task.job.done or task.state == TaskState.COMPLETED:
                continue
            if len(task.running_attempts()) >= \
                    sim.params.max_running_attempts:
                continue
            exclude = {a.node_id for a in task.running_attempts()}
            exclude |= sim._marked_failed
            node_id = sim.cluster.pick_container(list(req.placement),
                                                 exclude=exclude)
            if node_id is None:
                still.append(req)
                continue
            if sim.obs is not None:
                sim.obs.emit(
                    K_DISPATCH, a=sim.cluster._node_pos[node_id],
                    b=(1 if req.speculative else 0) |
                      (2 if req.rollback else 0),
                    obj=req.reason or None)
            sim._start_attempt(req, node_id)
        self.pending = still

    def has_queued(self, task: "SimTask") -> bool:
        return any(r.task is task for r in self.pending)

    def watchdog(self) -> None:
        """AM retry loop: any live task with no running attempt and no
        queued launch gets re-enqueued (covers killed/failed edges).

        With the columnar mirror available, the candidate scan is one
        segmented reduction over the attempt columns
        (:meth:`ArraySnapshot.idle_task_rows`) instead of an
        O(tasks × attempts) object walk per tick; rows arrive in
        canonical §11.3 order, which is exactly the reference loop's
        job-submission → task-creation order, so the enqueue sequence
        is identical (test_columnar's trace gate covers this).
        """
        sim = self.sim
        arr = sim.arrays
        candidates: List["SimTask"] = []
        if arr is not None:
            for r in arr.idle_task_rows():
                candidates.append(arr.owner(r).task)
        else:
            for job in sim.active_jobs.values():
                for t in job.tasks:
                    if t.state == TaskState.RUNNING \
                            and not t.running_attempts():
                        candidates.append(t)
        if candidates:
            queued = {r.task.task_id for r in self.pending}
            for t in candidates:
                if t.kind == TaskKind.REDUCE \
                        and not t.job.reduces_scheduled:
                    continue
                if t.task_id not in queued:
                    self.enqueue(LaunchRequest(t, reason="am-watchdog"))
        self.dispatch()
