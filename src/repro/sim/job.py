"""MapReduce job model + the benchmark application profiles of §IV.A.

Each benchmark is a (map rate, MOF ratio, reduce rate) profile: Terasort
moves its whole input through the shuffle, Grep emits almost nothing,
Aggregation is reduce-heavy, etc. Rates are bytes/s of split processing on
the paper's hardware (one 500 GB SATA disk, hex-core Xeons) — chosen so an
unfaulted 1 GB job lands near a minute, matching the paper's small-job
regime (Fig. 1 normalizes against these fault-free baselines, so only the
*ratios* matter for the reproduction claims).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

SPLIT_BYTES = 128 * 2 ** 20  # HDFS block

# Map-task spills per split (progress points the rollback log can resume
# from; Fig. 9 sweeps the failure point across these).
DEFAULT_SPILLS = 5


@dataclasses.dataclass(frozen=True)
class BenchProfile:
    name: str
    map_rate: float       # bytes/s consumed by a map task
    mof_ratio: float      # MOF bytes = split bytes × ratio
    reduce_rate: float    # bytes/s consumed by reduce compute
    output_ratio: float = 0.1  # HDFS output bytes = input bytes × ratio


# The paper's suite: four YARN built-ins + six from HiBench (§IV.A).
# output_ratio feeds the 3-way-replicated HDFS commit (shared 1 GbE).
BENCHMARKS: Dict[str, BenchProfile] = {
    "terasort":      BenchProfile("terasort",      8e6, 1.00, 20e6, 1.00),
    "wordcount":     BenchProfile("wordcount",     6e6, 0.15, 25e6, 0.05),
    "secondarysort": BenchProfile("secondarysort", 8e6, 1.00, 18e6, 1.00),
    "grep":          BenchProfile("grep",         10e6, 0.02, 40e6, 0.01),
    "aggregation":   BenchProfile("aggregation",   7e6, 0.30, 10e6, 0.20),
    "join":          BenchProfile("join",          7e6, 0.90, 15e6, 0.60),
    "kmeans":        BenchProfile("kmeans",        4e6, 0.10, 30e6, 0.05),
    "pagerank":      BenchProfile("pagerank",      6e6, 0.80, 15e6, 0.80),
    "scan":          BenchProfile("scan",         12e6, 0.05, 40e6, 0.05),
    "sort":          BenchProfile("sort",          8e6, 1.00, 20e6, 1.00),
}

# 3-way HDFS write pipeline over the shared 1 GbE: effective commit rate.
HDFS_WRITE_RATE = 5e7


@dataclasses.dataclass(frozen=True)
class JobSpec:
    job_id: str
    bench: str
    input_gb: float
    submit_time: float = 0.0
    n_reduces: Optional[int] = None
    n_spills: int = DEFAULT_SPILLS

    @property
    def profile(self) -> BenchProfile:
        return BENCHMARKS[self.bench]

    @property
    def n_maps(self) -> int:
        return max(1, math.ceil(self.input_gb * 2 ** 30 / SPLIT_BYTES))

    @property
    def reduces(self) -> int:
        if self.n_reduces is not None:
            return self.n_reduces
        # ~2 reducers per GB (Hadoop-era sizing: ~0.5 GB per reducer),
        # capped well under the cluster's slots.
        return max(1, min(32, math.ceil(2 * self.input_gb)))

    def map_work_seconds(self) -> float:
        return SPLIT_BYTES / self.profile.map_rate

    def mof_bytes(self) -> float:
        return SPLIT_BYTES * self.profile.mof_ratio

    def partition_bytes(self) -> float:
        return self.mof_bytes() / self.reduces

    def reduce_work_seconds(self) -> float:
        total_in = self.mof_bytes() * self.n_maps / self.reduces
        compute = total_in / self.profile.reduce_rate
        out_bytes = self.input_gb * 2 ** 30 * self.profile.output_ratio
        commit = out_bytes / self.reduces / HDFS_WRITE_RATE
        return compute + commit


@dataclasses.dataclass
class JobResult:
    job_id: str
    bench: str
    input_gb: float
    submit_time: float
    finish_time: float
    n_spec_attempts: int
    n_attempts: int
    n_fetch_failures: int
    task_durations: List[float]

    @property
    def jct(self) -> float:
        return self.finish_time - self.submit_time
