"""Shuffle substrate: per-reduce fetch state, MOF registry, and the two
fetch-candidate selection engines (DESIGN.md §12).

The seed simulator rediscovered work by rescanning: every free fetch slot
re-walked the reducer's full dependency list (O(n_maps) per slot), and
every map completion broadcast to every running reduce attempt. That poll
loop was ~2/3 of a 500-node run's wall time once the assessment path went
columnar. This module replaces it with an event-driven subsystem while
keeping the rescan path in-tree as the byte-exact reference:

- :class:`RescanShuffle` — the seed algorithm, verbatim: candidate list
  comprehension over ``task.deps`` per slot, completion broadcast over
  ``job.reduces × running_attempts``, MOF source by attribute scan.
- :class:`EventShuffle` — per-attempt indexed ready-deque (a min-heap of
  dependency indices, so slot filling pops the *lowest-index* ready
  producer in O(log n) — the same producer the reference scan would
  pick), fed by a per-producer subscriber registry (map completion
  notifies only attempts still wanting that partition), with MOF sources
  answered by :class:`MofRegistry` instead of attribute scans.

Equivalence contract: both engines drive the simulation through identical
event sequences — same fetches, same sources, same flow accounting, same
failure cycles, in the same order — so seeded runs emit byte-identical
action traces (``tests/test_shuffle.py`` enforces this, mirroring the
columnar gate of DESIGN.md §11.3).

Dependency status is a per-attempt ``int8`` column (one code per dep):
every dependency is in exactly one of WAITING / READY / FAIL_CYCLE /
INFLIGHT / FETCHED, and the live counts are written through to the
columnar snapshot (``sh_ready``/``sh_inflight``/``sh_fail``) so fetch-
health signals stay vectorized.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import TYPE_CHECKING, Dict, List, Optional, Set

import numpy as np

from repro.core.speculator import BinocularSpeculator
from repro.core.types import AttemptState, TaskState

if TYPE_CHECKING:  # pragma: no cover - import cycle guard, typing only
    from repro.sim.engine import EventHandle
    from repro.sim.mapreduce import SimAttempt, SimTask, Simulation

# Dependency status codes. "Subscribed" states (the attempt still wants a
# completion notification for this producer) are exactly codes < INFLIGHT.
S_WAITING = 0      # producer not (re)completed yet
S_READY = 1        # producer completed; awaiting a free fetch slot
S_FAIL_CYCLE = 2   # burning a failed-fetch timeout cycle
S_INFLIGHT = 3     # transfer in progress
S_FETCHED = 4      # partition landed
_SUBSCRIBED_MAX = S_FAIL_CYCLE


@dataclasses.dataclass
class ShuffleProfile:
    """Work counters exposing the rescan-vs-event win (examples/cluster_sim
    prints these: fetch slots filled per unit of candidate-selection work)."""

    notifies: int = 0        # producer-completion notifications processed
    try_calls: int = 0       # try_start_fetches invocations
    slots_filled: int = 0    # fetch starts + failure cycles begun
    deps_scanned: int = 0    # rescan mode: dependency list entries walked
    heap_pops: int = 0       # event mode: ready-heap pops (incl. stale)

    @property
    def selection_work(self) -> int:
        return self.deps_scanned + self.heap_pops

    def slots_per_kwork(self) -> float:
        """Fetch slots filled per 1000 candidate-selection steps."""
        return 1000.0 * self.slots_filled / max(1, self.selection_work)


class ShuffleState:
    """Per-reduce-attempt shuffle bookkeeping.

    One status code per dependency plus the handle/source maps keyed by
    producer task id. ``key`` is the canonical notification order:
    (task creation order, attempt index) — exactly the order the rescan
    broadcast visits attempts, so the event engine's subscriber fan-out
    stays trace-equivalent.
    """

    __slots__ = ("attempt", "status", "ready", "n_ready", "fetched",
                 "inflight", "fail_cycles", "fetch_srcs", "failed_cycles",
                 "key")

    def __init__(self, attempt: "SimAttempt"):
        task = attempt.task
        self.attempt = attempt
        self.status = np.zeros(len(task.deps), dtype=np.int8)
        self.ready: List[int] = []          # min-heap of dependency indices
        self.n_ready = 0
        self.fetched: Set[str] = set()
        self.inflight: Dict[str, "EventHandle"] = {}
        self.fail_cycles: Dict[str, "EventHandle"] = {}
        self.fetch_srcs: Dict[str, str] = {}
        self.failed_cycles = 0              # abort counter (EXCEEDED_MAX)
        self.key = (task.order, len(task.attempts))

    def set_status(self, i: int, code: int) -> None:
        old = self.status[i]
        if old == code:
            return
        if old == S_READY:
            self.n_ready -= 1
        if code == S_READY:
            self.n_ready += 1
        self.status[i] = code


class MofRegistry:
    """Indexed map-output locations: producer → live source nodes, plus
    node → completed tasks listing it in ``output_nodes``.

    ``live[m]`` holds exactly the nodes where the old attribute scan would
    find the MOF (alive ∧ MOF on disk ∧ not marked failed): entries are
    added on map completion and dropped on node death / marked-failed /
    silent MOF loss — the node's own ``mofs`` dict is the reverse index,
    so drops are O(MOFs on that node), not O(all maps).

    ``placements`` mirrors ``output_nodes`` membership so node expiry can
    prune exactly the affected producers instead of sweeping every map of
    every active job.
    """

    def __init__(self):
        self.live: Dict[str, Set[str]] = {}
        self.placements: Dict[str, Dict["SimTask", None]] = {}

    def add(self, task: "SimTask", node_id: str) -> None:
        self.live.setdefault(task.task_id, set()).add(node_id)
        self.placements.setdefault(node_id, {})[task] = None

    def drop_node_sources(self, node) -> None:
        """Node died or was marked failed: its MOF copies stop being
        fetchable. Must run before ``node.mofs`` is cleared."""
        for task_id in node.mofs:
            s = self.live.get(task_id)
            if s is not None:
                s.discard(node.node_id)

    def drop_producer(self, task_id: str) -> None:
        self.live.pop(task_id, None)

    def pick(self, task: "SimTask") -> Optional[str]:
        """First live source in ``output_nodes`` order — the same copy the
        reference attribute scan returns."""
        live = self.live.get(task.task_id)
        if not live:
            return None
        for nid in task.output_nodes:
            if nid in live:
                return nid
        return None

    def take_placed(self, node_id: str) -> List["SimTask"]:
        """Producers with ``node_id`` in their ``output_nodes``, in task
        creation order (= active-job submission order → map index order,
        the reference sweep order). Callers re-register tasks they skip
        via :meth:`keep_placed`."""
        tasks = self.placements.pop(node_id, None)
        if not tasks:
            return []
        return sorted(tasks, key=lambda t: t.order)

    def keep_placed(self, node_id: str, task: "SimTask") -> None:
        self.placements.setdefault(node_id, {})[task] = None

    def forget_task(self, task: "SimTask") -> None:
        self.live.pop(task.task_id, None)
        for nid in task.output_nodes:
            d = self.placements.get(nid)
            if d is not None:
                d.pop(task, None)


class ShuffleEngine:
    """Mode-independent fetch mechanics: flow accounting, transfer and
    failure-cycle timers, completion/failure handling, teardown. The two
    subclasses differ only in *candidate selection* (how free slots find
    ready producers) and *notification* (who hears about a completion)."""

    mode = "base"

    def __init__(self, sim: "Simulation"):
        self.sim = sim
        self.registry = MofRegistry()
        self.profile = ShuffleProfile()

    # -- attempt lifecycle ------------------------------------------------
    def attach(self, a: "SimAttempt") -> ShuffleState:
        ss = ShuffleState(a)
        a.shuffle = ss
        self._init_ready(a, ss)
        self._arr_sh(a, ss)
        return ss

    def detach(self, a: "SimAttempt") -> None:
        """Attempt ended: cancel transfers and timers, release flows,
        drop subscriptions."""
        ss = a.shuffle
        if ss is None:
            return
        for m, h in list(ss.inflight.items()):
            h.cancel()
            self._end_flow(a, ss, m, ss.fetch_srcs.get(m))
        for h in ss.fail_cycles.values():
            h.cancel()
        ss.inflight.clear()
        ss.fail_cycles.clear()
        self._drop_subscriptions(ss)
        ss.ready = []
        ss.n_ready = 0
        self._arr_sh(a, ss)

    def on_job_done(self, job) -> None:
        for t in job.maps:
            self.registry.forget_task(t)
            self._drop_producer_subs(t.task_id)

    # -- producer-side events --------------------------------------------
    def on_producer_completed(self, task: "SimTask", node_id: str) -> None:
        self.registry.add(task, node_id)
        self.profile.notifies += 1
        self._notify(task)

    def abort_fetch(self, a: "SimAttempt", m: str) -> None:
        """An in-flight transfer was invalidated (source died / MOF lost):
        cancel it and return the dependency to the candidate pool. The
        caller decides whether to retry immediately (``try_start``)."""
        ss = a.shuffle
        h = ss.inflight.get(m)
        if h is not None:
            h.cancel()
        self._end_flow(a, ss, m, ss.fetch_srcs.get(m))
        self._requeue(ss, a.task.dep_pos[m], m)
        self._arr_sh(a, ss)

    def mark_stalled(self, a: "SimAttempt") -> None:
        """The caller aborted transfers WITHOUT an immediate retry (a
        crashed host's own fetches stall silently), so the attempt sits
        with free budget and ready producers until the next completion in
        its job re-kicks it. The rescan broadcast reaches such attempts
        for free; the event engine must track them explicitly — this is
        the one place the "budget exhausted or ready-queue empty" steady
        state is deliberately broken."""

    def someone_still_needs(self, prod: "SimTask") -> bool:
        for r in prod.job.reduces:
            if r.state == TaskState.COMPLETED:
                continue
            for a in r.running_attempts():
                if prod.task_id not in a.shuffle.fetched:
                    return True
            if not r.running_attempts():
                return True  # a future attempt will need everything
        return False

    # -- shared fetch mechanics ------------------------------------------
    def _launch_fetch(self, a: "SimAttempt", ss: ShuffleState, m: str,
                      prod: "SimTask", src: str) -> None:
        sim = self.sim
        size = prod.job.spec.partition_bytes()
        rate = sim.cluster.fetch_throughput(src, a.node_id)
        sim.cluster.nodes[src].active_flows += 1
        sim.cluster.nodes[a.node_id].active_flows += 1
        ss.fetch_srcs[m] = src
        ss.inflight[m] = sim.engine.after(
            max(size / rate, 1e-3), self._fetch_done, a, m, src)
        self.profile.slots_filled += 1

    def _launch_fail_cycle(self, a: "SimAttempt", ss: ShuffleState,
                           m: str) -> None:
        # MOF is supposed to exist but no live copy: failure cycle.
        ss.fail_cycles[m] = self.sim.engine.after(
            self.sim.params.fetch_cycle, self._fetch_failed, a, m)
        self.profile.slots_filled += 1

    def _end_flow(self, a: "SimAttempt", ss: ShuffleState, m: str,
                  src: Optional[str]) -> None:
        if ss.inflight.pop(m, None) is not None and src is not None:
            nodes = self.sim.cluster.nodes
            nodes[src].active_flows = max(0, nodes[src].active_flows - 1)
            nodes[a.node_id].active_flows = max(
                0, nodes[a.node_id].active_flows - 1)
        ss.fetch_srcs.pop(m, None)

    def _fetch_done(self, a: "SimAttempt", m: str, src: str) -> None:
        ss = a.shuffle
        self._end_flow(a, ss, m, src)
        if a.state != AttemptState.RUNNING:
            return
        ss.fetched.add(m)
        ss.set_status(a.task.dep_pos[m], S_FETCHED)
        sim = self.sim
        if a.row >= 0:
            sim.arrays.fetched[a.row] = len(ss.fetched)
            self._arr_sh(a, ss)
        if isinstance(sim.speculator, BinocularSpeculator):
            sim.speculator.note_fetch_ok(m)
        if len(ss.fetched) == len(a.task.deps):
            sim._start_compute(a)
        else:
            self.try_start(a)

    def _fetch_failed(self, a: "SimAttempt", m: str) -> None:
        ss = a.shuffle
        ss.fail_cycles.pop(m, None)
        if a.state != AttemptState.RUNNING:
            return
        ss.failed_cycles += 1
        sim = self.sim
        # AM-side report (quorum bookkeeping may re-run the producer).
        sim._report_fetch_failure(a, m)
        prod = sim._task(m)
        i = a.task.dep_pos[m]
        if prod is not None and prod.state == TaskState.COMPLETED:
            self._requeue(ss, i, m)
        else:
            ss.set_status(i, S_WAITING)  # producer re-running; await notify
        self._arr_sh(a, ss)
        # Shuffle self-abort: the reduce attempt declares itself failed and
        # a fresh attempt re-shuffles — into the same missing MOF.
        if ss.failed_cycles >= sim.params.reduce_abort_cycles:
            sim._attempt_failed(a, reason="shuffle-exceeded-failures")
            return
        # retry (or go back to waiting if the producer restarted)
        self.try_start(a)

    # -- columnar write-through ------------------------------------------
    def _arr_sh(self, a: "SimAttempt", ss: ShuffleState) -> None:
        if a.row >= 0:
            arr = self.sim.arrays
            arr.sh_ready[a.row] = ss.n_ready
            arr.sh_inflight[a.row] = len(ss.inflight)
            arr.sh_fail[a.row] = len(ss.fail_cycles)

    # -- consistency (tests / verify_arrays) ------------------------------
    def verify_state(self, a: "SimAttempt") -> None:
        """Every dependency in exactly one status, and each status bucket
        in sync with its side structure."""
        ss = a.shuffle
        deps = a.task.deps
        counts = np.bincount(ss.status, minlength=5)
        assert int(counts.sum()) == len(deps)
        assert int(counts[S_FETCHED]) == len(ss.fetched)
        assert int(counts[S_INFLIGHT]) == len(ss.inflight)
        assert int(counts[S_FAIL_CYCLE]) == len(ss.fail_cycles)
        assert int(counts[S_READY]) == ss.n_ready
        assert ss.fetched == {deps[i] for i in
                              np.flatnonzero(ss.status == S_FETCHED)}
        assert set(ss.inflight) == {deps[i] for i in
                                    np.flatnonzero(ss.status == S_INFLIGHT)}
        assert set(ss.fail_cycles) == {
            deps[i] for i in np.flatnonzero(ss.status == S_FAIL_CYCLE)}
        assert set(ss.inflight) == set(ss.fetch_srcs)

    # -- mode hooks -------------------------------------------------------
    def try_start(self, a: "SimAttempt") -> None:
        raise NotImplementedError

    def _init_ready(self, a: "SimAttempt", ss: ShuffleState) -> None:
        raise NotImplementedError

    def _notify(self, task: "SimTask") -> None:
        raise NotImplementedError

    def _requeue(self, ss: ShuffleState, i: int, m: str) -> None:
        raise NotImplementedError

    def _mof_source(self, prod: "SimTask") -> Optional[str]:
        raise NotImplementedError

    def _drop_subscriptions(self, ss: ShuffleState) -> None:
        raise NotImplementedError

    def _drop_producer_subs(self, task_id: str) -> None:
        raise NotImplementedError


class RescanShuffle(ShuffleEngine):
    """The seed's poll-and-rescan algorithm, preserved as the equivalence
    reference: O(n_deps) candidate scan per free slot, completion
    broadcast to every running reduce attempt of the job, MOF sources by
    attribute scan. Status codes are maintained for the columnar shuffle
    columns but never drive control flow — the dict/set membership tests
    below are byte-for-byte the seed logic."""

    mode = "rescan"

    def _init_ready(self, a: "SimAttempt", ss: ShuffleState) -> None:
        sim = self.sim
        for i, m in enumerate(a.task.deps):
            prod = sim._task(m)
            if prod is not None and prod.state == TaskState.COMPLETED:
                ss.set_status(i, S_READY)

    def try_start(self, a: "SimAttempt") -> None:
        ss = a.shuffle
        if a.state != AttemptState.RUNNING or a.compute_started:
            return
        sim = self.sim
        prof = self.profile
        prof.try_calls += 1
        budget = sim.params.parallel_fetches - len(ss.inflight) \
            - len(ss.fail_cycles)
        if budget <= 0:
            return
        deps = a.task.deps
        dep_pos = a.task.dep_pos
        prof.deps_scanned += len(deps)
        candidates = [m for m in deps
                      if m not in ss.fetched and m not in ss.inflight
                      and m not in ss.fail_cycles]
        for m in candidates:
            if budget <= 0:
                break
            prod = sim._task(m)
            i = dep_pos[m]
            if prod is None or prod.state != TaskState.COMPLETED:
                # not produced yet; map completion will notify
                if ss.status[i] == S_READY:   # producer re-enqueued since
                    ss.set_status(i, S_WAITING)
                    self._arr_sh(a, ss)
                continue
            src = self._mof_source(prod)
            if src is None:
                ss.set_status(i, S_FAIL_CYCLE)
                self._launch_fail_cycle(a, ss, m)
                budget -= 1
                self._arr_sh(a, ss)
                continue
            ss.set_status(i, S_INFLIGHT)
            self._launch_fetch(a, ss, m, prod, src)
            budget -= 1
            self._arr_sh(a, ss)

    def _notify(self, task: "SimTask") -> None:
        # fresh MOF ⇒ every running reduce attempt of the job goes again
        m = task.task_id
        for r in task.job.reduces:
            for ra in r.running_attempts():
                ss = ra.shuffle
                i = ra.task.dep_pos.get(m)
                if i is not None:
                    st = int(ss.status[i])
                    if st == S_FAIL_CYCLE:
                        # cancel the pending failure cycle so the retry is
                        # immediate rather than waiting out the timeout
                        h = ss.fail_cycles.pop(m, None)
                        if h is not None:
                            h.cancel()
                    if st in (S_WAITING, S_FAIL_CYCLE):
                        ss.set_status(i, S_READY)
                        self._arr_sh(ra, ss)
                self.try_start(ra)

    def _requeue(self, ss: ShuffleState, i: int, m: str) -> None:
        ss.set_status(i, S_READY)

    def _mof_source(self, prod: "SimTask") -> Optional[str]:
        sim = self.sim
        for nid in prod.output_nodes:
            node = sim.cluster.nodes[nid]
            if node.alive and prod.task_id in node.mofs \
                    and nid not in sim._marked_failed:
                return nid
        return None

    def _drop_subscriptions(self, ss: ShuffleState) -> None:
        pass

    def _drop_producer_subs(self, task_id: str) -> None:
        pass


class EventShuffle(ShuffleEngine):
    """Event-driven candidate selection: each attempt keeps an indexed
    ready-deque (min-heap over dependency indices, lazily pruned), and a
    per-producer subscriber registry routes completion news to exactly
    the attempts still wanting that partition. Slot filling is O(log n)
    per slot; notification is O(interested attempts)."""

    mode = "event"

    def __init__(self, sim: "Simulation"):
        super().__init__(sim)
        # producer task_id → subscribed states (order irrelevant: fan-out
        # sorts by the canonical (task order, attempt index) key).
        self.subs: Dict[str, Dict[ShuffleState, None]] = {}
        # job → states parked with free budget + ready producers after a
        # silent abort (see mark_stalled); re-kicked on the job's next
        # producer completion, like the rescan broadcast would.
        self.stalled: Dict[object, Dict[ShuffleState, None]] = {}

    def mark_stalled(self, a: "SimAttempt") -> None:
        self.stalled.setdefault(a.task.job, {})[a.shuffle] = None

    def _init_ready(self, a: "SimAttempt", ss: ShuffleState) -> None:
        sim = self.sim
        subs = self.subs
        for i, m in enumerate(a.task.deps):
            subs.setdefault(m, {})[ss] = None
            prod = sim._task(m)
            if prod is not None and prod.state == TaskState.COMPLETED:
                ss.set_status(i, S_READY)
                heapq.heappush(ss.ready, i)

    def try_start(self, a: "SimAttempt") -> None:
        ss = a.shuffle
        if a.state != AttemptState.RUNNING or a.compute_started:
            return
        sim = self.sim
        prof = self.profile
        prof.try_calls += 1
        budget = sim.params.parallel_fetches - len(ss.inflight) \
            - len(ss.fail_cycles)
        if budget <= 0:
            return
        deps = a.task.deps
        ready = ss.ready
        changed = False
        while budget > 0 and ready:
            i = heapq.heappop(ready)
            prof.heap_pops += 1
            if ss.status[i] != S_READY:
                continue  # stale entry (lazy deletion)
            m = deps[i]
            prod = sim._task(m)
            if prod is None or prod.state != TaskState.COMPLETED:
                # producer re-enqueued since it went ready; its next
                # completion re-notifies (we stay subscribed)
                ss.set_status(i, S_WAITING)
                changed = True
                continue
            src = self._mof_source(prod)
            if src is None:
                ss.set_status(i, S_FAIL_CYCLE)
                self._launch_fail_cycle(a, ss, m)
                budget -= 1
                changed = True
                continue
            ss.set_status(i, S_INFLIGHT)
            d = self.subs.get(m)
            if d is not None:
                d.pop(ss, None)
            self._launch_fetch(a, ss, m, prod, src)
            budget -= 1
            changed = True
        if changed:
            self._arr_sh(a, ss)

    def _notify(self, task: "SimTask") -> None:
        m = task.task_id
        targets = dict(self.subs.get(m) or ())
        # States parked by a silent abort get the broadcast's re-kick on
        # any completion in their job, even if this producer is already
        # fetched for them (their try_start below restores the steady
        # state, so they leave the stalled set).
        stalled = self.stalled.pop(task.job, None)
        if stalled:
            targets.update(stalled)
        if not targets:
            return
        # canonical broadcast order: job's reduces in creation order, each
        # task's attempts in start order — matches the rescan reference
        for ss in sorted(targets, key=lambda s: s.key):
            a = ss.attempt
            if a.state != AttemptState.RUNNING:
                continue
            i = a.task.dep_pos[m]
            st = int(ss.status[i])
            if st == S_FAIL_CYCLE:
                # fresh MOF: cancel the pending failure cycle so the retry
                # is immediate rather than waiting out the timeout
                h = ss.fail_cycles.pop(m, None)
                if h is not None:
                    h.cancel()
            if st in (S_WAITING, S_FAIL_CYCLE):
                ss.set_status(i, S_READY)
                heapq.heappush(ss.ready, i)
                self._arr_sh(a, ss)
            self.try_start(a)

    def _requeue(self, ss: ShuffleState, i: int, m: str) -> None:
        ss.set_status(i, S_READY)
        heapq.heappush(ss.ready, i)
        self.subs.setdefault(m, {})[ss] = None

    def _mof_source(self, prod: "SimTask") -> Optional[str]:
        return self.registry.pick(prod)

    def _drop_subscriptions(self, ss: ShuffleState) -> None:
        deps = ss.attempt.task.deps
        for i in np.flatnonzero(ss.status <= _SUBSCRIBED_MAX):
            d = self.subs.get(deps[i])
            if d is not None:
                d.pop(ss, None)
        parked = self.stalled.get(ss.attempt.task.job)
        if parked is not None:
            parked.pop(ss, None)

    def _drop_producer_subs(self, task_id: str) -> None:
        self.subs.pop(task_id, None)

    def on_job_done(self, job) -> None:
        super().on_job_done(job)
        self.stalled.pop(job, None)

    def verify_state(self, a: "SimAttempt") -> None:
        super().verify_state(a)
        ss = a.shuffle
        deps = a.task.deps
        in_heap = set(ss.ready)
        for i in np.flatnonzero(ss.status == S_READY):
            assert int(i) in in_heap, (a.attempt_id, deps[i])
        if a.state == AttemptState.RUNNING:
            for i in np.flatnonzero(ss.status <= _SUBSCRIBED_MAX):
                assert ss in self.subs.get(deps[i], {}), \
                    (a.attempt_id, deps[i])


def make_engine(sim: "Simulation", mode: str) -> ShuffleEngine:
    if mode == "event":
        return EventShuffle(sim)
    if mode == "rescan":
        return RescanShuffle(sim)
    raise ValueError(f"unknown shuffle mode: {mode!r}")
