"""Shuffle substrate: per-reduce fetch state, MOF registry, and the two
fetch-candidate selection engines (DESIGN.md §12).

The seed simulator rediscovered work by rescanning: every free fetch slot
re-walked the reducer's full dependency list (O(n_maps) per slot), and
every map completion broadcast to every running reduce attempt. That poll
loop was ~2/3 of a 500-node run's wall time once the assessment path went
columnar. This module replaces it with an event-driven subsystem while
keeping the rescan path in-tree as the byte-exact reference:

- :class:`RescanShuffle` — the seed algorithm, verbatim: candidate list
  comprehension over ``task.deps`` per slot, completion broadcast over
  ``job.reduces × running_attempts``, MOF source by attribute scan.
- :class:`EventShuffle` — per-attempt indexed ready-deque (a min-heap of
  dependency indices, so slot filling pops the *lowest-index* ready
  producer in O(log n) — the same producer the reference scan would
  pick), fed by a per-producer subscriber registry (map completion
  notifies only attempts still wanting that partition), with MOF sources
  answered by :class:`MofRegistry` instead of attribute scans.
- :class:`BatchShuffle` — EventShuffle's selection logic over the
  engine's macro-event calendar lane (DESIGN.md §14): fetch completions
  and failure cycles are typed records in a
  :class:`~repro.sim.engine.BatchQueue` instead of per-event heap
  entries, drained in bulk between heap events; timer cancellation is a
  token drop (stale records are discarded at apply time); the columnar
  ``sh_*``/``fetched`` write-through is deferred per drain and flushed
  as one bulk write before any heap event can read it; producer
  completions fan out with a budget gate that skips the (provably
  no-op) ``try_start`` of saturated attempts.

Equivalence contract: all engines drive the simulation through identical
event sequences — same fetches, same sources, same flow accounting, same
failure cycles, in the same order — so seeded runs emit byte-identical
action traces (``tests/test_shuffle.py`` enforces this, mirroring the
columnar gate of DESIGN.md §11.3).

Dependency status is a per-attempt ``int8`` column (one code per dep):
every dependency is in exactly one of WAITING / READY / FAIL_CYCLE /
INFLIGHT / FETCHED, and the live counts are written through to the
columnar snapshot (``sh_ready``/``sh_inflight``/``sh_fail``) so fetch-
health signals stay vectorized.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Set

import numpy as np

from repro.core.rollback import ProgressLog
from repro.core.speculator import BinocularSpeculator
from repro.core.types import AttemptState, TaskState
from repro.obs.trace import K_FETCH_FAIL
from repro.sim.cluster import DISK_BW, NIC_BW

if TYPE_CHECKING:  # pragma: no cover - import cycle guard, typing only
    from repro.sim.engine import EventHandle
    from repro.sim.mapreduce import SimAttempt, SimTask, Simulation

# Dependency status codes. "Subscribed" states (the attempt still wants a
# completion notification for this producer) are exactly codes < INFLIGHT.
S_WAITING = 0      # producer not (re)completed yet
S_READY = 1        # producer completed; awaiting a free fetch slot
S_FAIL_CYCLE = 2   # burning a failed-fetch timeout cycle
S_INFLIGHT = 3     # transfer in progress
S_FETCHED = 4      # partition landed
_SUBSCRIBED_MAX = S_FAIL_CYCLE


@dataclasses.dataclass
class ShuffleProfile:
    """Work counters exposing the rescan-vs-event win (examples/cluster_sim
    prints these: fetch slots filled per unit of candidate-selection work)."""

    notifies: int = 0        # producer-completion notifications processed
    try_calls: int = 0       # try_start_fetches invocations
    slots_filled: int = 0    # fetch starts + failure cycles begun
    deps_scanned: int = 0    # rescan mode: dependency list entries walked
    heap_pops: int = 0       # event mode: ready-heap pops (incl. stale)
    lane_records: int = 0    # batch mode: calendar-lane records applied

    @property
    def selection_work(self) -> int:
        return self.deps_scanned + self.heap_pops

    def slots_per_kwork(self) -> float:
        """Fetch slots filled per 1000 candidate-selection steps."""
        return 1000.0 * self.slots_filled / max(1, self.selection_work)


class ShuffleState:
    """Per-reduce-attempt shuffle bookkeeping.

    One status code per dependency plus the handle/source maps keyed by
    producer task id. ``key`` is the canonical notification order:
    (task creation order, attempt index) — exactly the order the rescan
    broadcast visits attempts, so the event engine's subscriber fan-out
    stays trace-equivalent.
    """

    __slots__ = ("attempt", "status", "ready", "n_ready", "fetched",
                 "inflight", "fail_cycles", "fetch_srcs", "failed_cycles",
                 "key", "log", "log_pos", "parked")

    def __init__(self, attempt: "SimAttempt"):
        task = attempt.task
        self.attempt = attempt
        self.status = np.zeros(len(task.deps), dtype=np.int8)
        self.ready: List[int] = []          # min-heap of dependency indices
        self.n_ready = 0
        self.fetched: Set[str] = set()
        self.inflight: Dict[str, "EventHandle"] = {}
        self.fail_cycles: Dict[str, "EventHandle"] = {}
        self.fetch_srcs: Dict[str, str] = {}
        self.failed_cycles = 0              # abort counter (EXCEEDED_MAX)
        self.key = (task.order, len(task.attempts))
        # Batch mode: the job's producer-completion log (shared,
        # append-only; BatchShuffle._init_ready swaps in the job's real
        # list — under rescan/event this stays the immutable empty
        # sentinel and is never read) and the position up to which this
        # attempt has reconciled its WAITING→READY flips; ``parked``
        # mirrors membership in the engine's idle set so the steady
        # state skips the dict entirely.
        self.log: Sequence[int] = ()
        self.log_pos = 0
        self.parked = False

    def set_status(self, i: int, code: int) -> None:
        old = self.status[i]
        if old == code:
            return
        if old == S_READY:
            self.n_ready -= 1
        if code == S_READY:
            self.n_ready += 1
        self.status[i] = code


class MofRegistry:
    """Indexed map-output locations: producer → live source nodes, plus
    node → completed tasks listing it in ``output_nodes``.

    ``live[m]`` holds exactly the nodes where the old attribute scan would
    find the MOF (alive ∧ MOF on disk ∧ not marked failed): entries are
    added on map completion and dropped on node death / marked-failed /
    silent MOF loss — the node's own ``mofs`` dict is the reverse index,
    so drops are O(MOFs on that node), not O(all maps).

    ``placements`` mirrors ``output_nodes`` membership so node expiry can
    prune exactly the affected producers instead of sweeping every map of
    every active job.
    """

    def __init__(self):
        self.live: Dict[str, Set[str]] = {}
        self.placements: Dict[str, Dict["SimTask", None]] = {}
        # Nodes whose network link is cut (shared with the simulation's
        # ``_link_down`` set): their MOF copies are unreachable, so they
        # never enter ``live`` — mirroring the reference scan's
        # link-liveness check (DESIGN.md §15.5).
        self.down: Set[str] = set()

    def add(self, task: "SimTask", node_id: str) -> None:
        if node_id not in self.down:
            self.live.setdefault(task.task_id, set()).add(node_id)
        self.placements.setdefault(node_id, {})[task] = None

    def drop_node_sources(self, node) -> None:
        """Node died or was marked failed: its MOF copies stop being
        fetchable. Must run before ``node.mofs`` is cleared."""
        for task_id in node.mofs:
            s = self.live.get(task_id)
            if s is not None:
                s.discard(node.node_id)

    def drop_producer(self, task_id: str) -> None:
        self.live.pop(task_id, None)

    def pick(self, task: "SimTask") -> Optional[str]:
        """First live source in ``output_nodes`` order — the same copy the
        reference attribute scan returns."""
        live = self.live.get(task.task_id)
        if not live:
            return None
        for nid in task.output_nodes:
            if nid in live:
                return nid
        return None

    def take_placed(self, node_id: str) -> List["SimTask"]:
        """Producers with ``node_id`` in their ``output_nodes``, in task
        creation order (= active-job submission order → map index order,
        the reference sweep order). Callers re-register tasks they skip
        via :meth:`keep_placed`."""
        tasks = self.placements.pop(node_id, None)
        if not tasks:
            return []
        return sorted(tasks, key=lambda t: t.order)

    def keep_placed(self, node_id: str, task: "SimTask") -> None:
        self.placements.setdefault(node_id, {})[task] = None

    def forget_task(self, task: "SimTask") -> None:
        self.live.pop(task.task_id, None)
        for nid in task.output_nodes:
            d = self.placements.get(nid)
            if d is not None:
                d.pop(task, None)


class ShuffleEngine:
    """Mode-independent fetch mechanics: flow accounting, transfer and
    failure-cycle timers, completion/failure handling, teardown. The two
    subclasses differ only in *candidate selection* (how free slots find
    ready producers) and *notification* (who hears about a completion)."""

    mode = "base"

    def __init__(self, sim: "Simulation"):
        self.sim = sim
        self.registry = MofRegistry()
        self.registry.down = sim._link_down
        self.profile = ShuffleProfile()
        # Pluggable network model (DESIGN.md §15): every rate decision
        # and all flow accounting go through it.
        self._net = sim.cluster.net

    # -- attempt lifecycle ------------------------------------------------
    def attach(self, a: "SimAttempt") -> ShuffleState:
        ss = ShuffleState(a)
        a.shuffle = ss
        self._init_ready(a, ss)
        self._arr_sh(a, ss)
        return ss

    def detach(self, a: "SimAttempt") -> None:
        """Attempt ended: cancel transfers and timers, release flows,
        drop subscriptions."""
        ss = a.shuffle
        if ss is None:
            return
        for m, h in list(ss.inflight.items()):
            self._cancel(h)
            self._end_flow(a, ss, m, ss.fetch_srcs.get(m))
        for h in ss.fail_cycles.values():
            self._cancel(h)
        ss.inflight.clear()
        ss.fail_cycles.clear()
        self._drop_subscriptions(ss)
        ss.ready = []
        ss.n_ready = 0
        self._arr_sh(a, ss)

    def on_job_done(self, job) -> None:
        for t in job.maps:
            self.registry.forget_task(t)
            self._drop_producer_subs(t.task_id)

    # -- producer-side events --------------------------------------------
    def on_producer_completed(self, task: "SimTask", node_id: str) -> None:
        self.registry.add(task, node_id)
        self.profile.notifies += 1
        self._notify(task)

    def abort_fetch(self, a: "SimAttempt", m: str) -> None:
        """An in-flight transfer was invalidated (source died / MOF lost):
        cancel it and return the dependency to the candidate pool. The
        caller decides whether to retry immediately (``try_start``)."""
        ss = a.shuffle
        h = ss.inflight.get(m)
        if h is not None:
            self._cancel(h)
        self._end_flow(a, ss, m, ss.fetch_srcs.get(m))
        self._requeue(ss, a.task.dep_pos[m], m)
        self._arr_sh(a, ss)

    def mark_stalled(self, a: "SimAttempt") -> None:
        """The caller aborted transfers WITHOUT an immediate retry (a
        crashed host's own fetches stall silently), so the attempt sits
        with free budget and ready producers until the next completion in
        its job re-kicks it. The rescan broadcast reaches such attempts
        for free; the event engine must track them explicitly — this is
        the one place the "budget exhausted or ready-queue empty" steady
        state is deliberately broken."""

    def someone_still_needs(self, prod: "SimTask") -> bool:
        for r in prod.job.reduces:
            if r.state == TaskState.COMPLETED:
                continue
            for a in r.running_attempts():
                if prod.task_id not in a.shuffle.fetched:
                    return True
            if not r.running_attempts():
                return True  # a future attempt will need everything
        return False

    # -- shared fetch mechanics ------------------------------------------
    def _launch_fetch(self, a: "SimAttempt", ss: ShuffleState, m: str,
                      prod: "SimTask", src: str) -> None:
        sim = self.sim
        size = prod.job.spec.partition_bytes()
        rate = self._net.open_flow(src, a.node_id)
        ss.fetch_srcs[m] = src
        ss.inflight[m] = sim.engine.after(
            max(size / rate, 1e-3), self._fetch_done, a, m, src)
        self.profile.slots_filled += 1

    def _launch_fail_cycle(self, a: "SimAttempt", ss: ShuffleState,
                           m: str) -> None:
        # MOF is supposed to exist but no live copy: failure cycle.
        ss.fail_cycles[m] = self.sim.engine.after(
            self.sim.params.fetch_cycle, self._fetch_failed, a, m)
        self.profile.slots_filled += 1

    def _end_flow(self, a: "SimAttempt", ss: ShuffleState, m: str,
                  src: Optional[str]) -> None:
        if ss.inflight.pop(m, None) is not None and src is not None:
            self._net.close_flow(src, a.node_id)
        ss.fetch_srcs.pop(m, None)

    def _fetch_done(self, a: "SimAttempt", m: str, src: str) -> None:
        ss = a.shuffle
        self._end_flow(a, ss, m, src)
        if a.state != AttemptState.RUNNING:
            return
        ss.fetched.add(m)
        ss.set_status(a.task.dep_pos[m], S_FETCHED)
        sim = self.sim
        if a.row >= 0:
            sim.arrays.fetched[a.row] = len(ss.fetched)
            self._arr_sh(a, ss)
        if isinstance(sim.speculator, BinocularSpeculator):
            sim.speculator.note_fetch_ok(m)
        if len(ss.fetched) == len(a.task.deps):
            sim._start_compute(a)
        else:
            self.try_start(a)

    def _fetch_failed(self, a: "SimAttempt", m: str) -> None:
        ss = a.shuffle
        ss.fail_cycles.pop(m, None)
        if a.state != AttemptState.RUNNING:
            return
        ss.failed_cycles += 1
        sim = self.sim
        if sim.obs is not None:
            sim.obs.emit(K_FETCH_FAIL, a=sim.cluster._node_pos[a.node_id],
                         b=ss.failed_cycles, obj=m)
        # AM-side report (quorum bookkeeping may re-run the producer).
        sim._report_fetch_failure(a, m)
        prod = sim._task(m)
        i = a.task.dep_pos[m]
        if prod is not None and prod.state == TaskState.COMPLETED:
            self._requeue(ss, i, m)
        else:
            ss.set_status(i, S_WAITING)  # producer re-running; await notify
        self._arr_sh(a, ss)
        # Shuffle self-abort: the reduce attempt declares itself failed and
        # a fresh attempt re-shuffles — into the same missing MOF.
        if ss.failed_cycles >= sim.params.reduce_abort_cycles:
            sim._attempt_failed(a, reason="shuffle-exceeded-failures")
            return
        # retry (or go back to waiting if the producer restarted)
        self.try_start(a)

    # -- columnar write-through ------------------------------------------
    def _arr_sh(self, a: "SimAttempt", ss: ShuffleState) -> None:
        if a.row >= 0:
            arr = self.sim.arrays
            arr.sh_ready[a.row] = ss.n_ready
            arr.sh_inflight[a.row] = len(ss.inflight)
            arr.sh_fail[a.row] = len(ss.fail_cycles)

    # -- consistency (tests / verify_arrays) ------------------------------
    def verify_state(self, a: "SimAttempt") -> None:
        """Every dependency in exactly one status, and each status bucket
        in sync with its side structure."""
        ss = a.shuffle
        deps = a.task.deps
        counts = np.bincount(ss.status, minlength=5)
        assert int(counts.sum()) == len(deps)
        assert int(counts[S_FETCHED]) == len(ss.fetched)
        assert int(counts[S_INFLIGHT]) == len(ss.inflight)
        assert int(counts[S_FAIL_CYCLE]) == len(ss.fail_cycles)
        assert int(counts[S_READY]) == ss.n_ready
        assert ss.fetched == {deps[i] for i in
                              np.flatnonzero(ss.status == S_FETCHED)}
        assert set(ss.inflight) == {deps[i] for i in
                                    np.flatnonzero(ss.status == S_INFLIGHT)}
        assert set(ss.fail_cycles) == {
            deps[i] for i in np.flatnonzero(ss.status == S_FAIL_CYCLE)}
        assert set(ss.inflight) == set(ss.fetch_srcs)

    # -- mode hooks -------------------------------------------------------
    @staticmethod
    def _cancel(h) -> None:
        """Disarm a pending transfer/failure-cycle timer. Heap-backed
        engines hold EventHandles; the batch engine holds integer lane
        tokens, for which forgetting the token (the dict removal the
        caller already performs) *is* the cancellation."""
        h.cancel()

    # -- simulation-side timers (map milestones, background ticks) --------
    # Heap-backed engines schedule plain events; the batch engine routes
    # both through its calendar lane as typed records (same global seq
    # counter — identical merged order).
    def schedule_milestone(self, a: "SimAttempt", dt: float, idx: int,
                           frac: float, kind: str):
        """Arm the attempt's next map-milestone timer; returns the value
        ``a._milestone`` should hold (EventHandle or lane token)."""
        sim = self.sim
        return sim.engine.after(dt, sim._map_milestone_fired, a, frac,
                                kind)

    def schedule_tick(self, dt: float, which: int) -> None:
        """Arm one background tick (TICK_HB / TICK_EXPIRY)."""
        sim = self.sim
        fn = sim._heartbeat_tick if which == TICK_HB else sim._expiry_tick
        sim.engine.after(dt, fn)

    def verify_timer(self, a: "SimAttempt") -> None:
        """Consistency hook for a live ``a._milestone`` (verify_arrays)."""
        h = a._milestone
        if h is not None:
            assert not isinstance(h, int), a.attempt_id

    def try_start(self, a: "SimAttempt") -> None:
        raise NotImplementedError

    def _init_ready(self, a: "SimAttempt", ss: ShuffleState) -> None:
        raise NotImplementedError

    def _notify(self, task: "SimTask") -> None:
        raise NotImplementedError

    def _requeue(self, ss: ShuffleState, i: int, m: str) -> None:
        raise NotImplementedError

    def _mof_source(self, prod: "SimTask") -> Optional[str]:
        raise NotImplementedError

    def _drop_subscriptions(self, ss: ShuffleState) -> None:
        raise NotImplementedError

    def _drop_producer_subs(self, task_id: str) -> None:
        raise NotImplementedError


class RescanShuffle(ShuffleEngine):
    """The seed's poll-and-rescan algorithm, preserved as the equivalence
    reference: O(n_deps) candidate scan per free slot, completion
    broadcast to every running reduce attempt of the job, MOF sources by
    attribute scan. Status codes are maintained for the columnar shuffle
    columns but never drive control flow — the dict/set membership tests
    below are byte-for-byte the seed logic."""

    mode = "rescan"

    def _init_ready(self, a: "SimAttempt", ss: ShuffleState) -> None:
        sim = self.sim
        for i, m in enumerate(a.task.deps):
            prod = sim._task(m)
            if prod is not None and prod.state == TaskState.COMPLETED:
                ss.set_status(i, S_READY)

    def try_start(self, a: "SimAttempt") -> None:
        ss = a.shuffle
        if a.state != AttemptState.RUNNING or a.compute_started:
            return
        sim = self.sim
        prof = self.profile
        prof.try_calls += 1
        budget = sim.params.parallel_fetches - len(ss.inflight) \
            - len(ss.fail_cycles)
        if budget <= 0:
            return
        deps = a.task.deps
        dep_pos = a.task.dep_pos
        prof.deps_scanned += len(deps)
        candidates = [m for m in deps
                      if m not in ss.fetched and m not in ss.inflight
                      and m not in ss.fail_cycles]
        for m in candidates:
            if budget <= 0:
                break
            prod = sim._task(m)
            i = dep_pos[m]
            if prod is None or prod.state != TaskState.COMPLETED:
                # not produced yet; map completion will notify
                if ss.status[i] == S_READY:   # producer re-enqueued since
                    ss.set_status(i, S_WAITING)
                    self._arr_sh(a, ss)
                continue
            src = self._mof_source(prod)
            if src is None:
                ss.set_status(i, S_FAIL_CYCLE)
                self._launch_fail_cycle(a, ss, m)
                budget -= 1
                self._arr_sh(a, ss)
                continue
            ss.set_status(i, S_INFLIGHT)
            self._launch_fetch(a, ss, m, prod, src)
            budget -= 1
            self._arr_sh(a, ss)

    def _notify(self, task: "SimTask") -> None:
        # fresh MOF ⇒ every running reduce attempt of the job goes again
        m = task.task_id
        for r in task.job.reduces:
            for ra in r.running_attempts():
                ss = ra.shuffle
                i = ra.task.dep_pos.get(m)
                if i is not None:
                    st = int(ss.status[i])
                    if st == S_FAIL_CYCLE:
                        # cancel the pending failure cycle so the retry is
                        # immediate rather than waiting out the timeout
                        h = ss.fail_cycles.pop(m, None)
                        if h is not None:
                            self._cancel(h)
                    if st in (S_WAITING, S_FAIL_CYCLE):
                        ss.set_status(i, S_READY)
                        self._arr_sh(ra, ss)
                self.try_start(ra)

    def _requeue(self, ss: ShuffleState, i: int, m: str) -> None:
        ss.set_status(i, S_READY)

    def _mof_source(self, prod: "SimTask") -> Optional[str]:
        sim = self.sim
        down = sim._link_down
        for nid in prod.output_nodes:
            node = sim.cluster.nodes[nid]
            if node.alive and prod.task_id in node.mofs \
                    and nid not in sim._marked_failed \
                    and nid not in down:
                return nid
        return None

    def _drop_subscriptions(self, ss: ShuffleState) -> None:
        pass

    def _drop_producer_subs(self, task_id: str) -> None:
        pass


class EventShuffle(ShuffleEngine):
    """Event-driven candidate selection: each attempt keeps an indexed
    ready-deque (min-heap over dependency indices, lazily pruned), and a
    per-producer subscriber registry routes completion news to exactly
    the attempts still wanting that partition. Slot filling is O(log n)
    per slot; notification is O(interested attempts)."""

    mode = "event"

    def __init__(self, sim: "Simulation"):
        super().__init__(sim)
        # producer task_id → subscribed states (order irrelevant: fan-out
        # sorts by the canonical (task order, attempt index) key).
        self.subs: Dict[str, Dict[ShuffleState, None]] = {}
        # job → states parked with free budget + ready producers after a
        # silent abort (see mark_stalled); re-kicked on the job's next
        # producer completion, like the rescan broadcast would.
        self.stalled: Dict[object, Dict[ShuffleState, None]] = {}

    def mark_stalled(self, a: "SimAttempt") -> None:
        self.stalled.setdefault(a.task.job, {})[a.shuffle] = None

    def _init_ready(self, a: "SimAttempt", ss: ShuffleState) -> None:
        sim = self.sim
        subs = self.subs
        for i, m in enumerate(a.task.deps):
            subs.setdefault(m, {})[ss] = None
            prod = sim._task(m)
            if prod is not None and prod.state == TaskState.COMPLETED:
                ss.set_status(i, S_READY)
                heapq.heappush(ss.ready, i)

    def try_start(self, a: "SimAttempt") -> None:
        ss = a.shuffle
        if a.state != AttemptState.RUNNING or a.compute_started:
            return
        sim = self.sim
        prof = self.profile
        prof.try_calls += 1
        budget = sim.params.parallel_fetches - len(ss.inflight) \
            - len(ss.fail_cycles)
        if budget <= 0:
            return
        deps = a.task.deps
        ready = ss.ready
        changed = False
        while budget > 0 and ready:
            i = heapq.heappop(ready)
            prof.heap_pops += 1
            if ss.status[i] != S_READY:
                continue  # stale entry (lazy deletion)
            m = deps[i]
            prod = sim._task(m)
            if prod is None or prod.state != TaskState.COMPLETED:
                # producer re-enqueued since it went ready; its next
                # completion re-notifies (we stay subscribed)
                ss.set_status(i, S_WAITING)
                changed = True
                continue
            src = self._mof_source(prod)
            if src is None:
                ss.set_status(i, S_FAIL_CYCLE)
                self._launch_fail_cycle(a, ss, m)
                budget -= 1
                changed = True
                continue
            ss.set_status(i, S_INFLIGHT)
            d = self.subs.get(m)
            if d is not None:
                d.pop(ss, None)
            self._launch_fetch(a, ss, m, prod, src)
            budget -= 1
            changed = True
        if changed:
            self._arr_sh(a, ss)

    def _notify(self, task: "SimTask") -> None:
        m = task.task_id
        targets = dict(self.subs.get(m) or ())
        # States parked by a silent abort get the broadcast's re-kick on
        # any completion in their job, even if this producer is already
        # fetched for them (their try_start below restores the steady
        # state, so they leave the stalled set).
        stalled = self.stalled.pop(task.job, None)
        if stalled:
            targets.update(stalled)
        if not targets:
            return
        # canonical broadcast order: job's reduces in creation order, each
        # task's attempts in start order — matches the rescan reference
        for ss in sorted(targets, key=lambda s: s.key):
            a = ss.attempt
            if a.state != AttemptState.RUNNING:
                continue
            i = a.task.dep_pos[m]
            st = int(ss.status[i])
            if st == S_FAIL_CYCLE:
                # fresh MOF: cancel the pending failure cycle so the retry
                # is immediate rather than waiting out the timeout
                h = ss.fail_cycles.pop(m, None)
                if h is not None:
                    self._cancel(h)
            if st in (S_WAITING, S_FAIL_CYCLE):
                ss.set_status(i, S_READY)
                heapq.heappush(ss.ready, i)
                self._arr_sh(a, ss)
            self.try_start(a)

    def _requeue(self, ss: ShuffleState, i: int, m: str) -> None:
        ss.set_status(i, S_READY)
        heapq.heappush(ss.ready, i)
        self.subs.setdefault(m, {})[ss] = None

    def _mof_source(self, prod: "SimTask") -> Optional[str]:
        return self.registry.pick(prod)

    def _drop_subscriptions(self, ss: ShuffleState) -> None:
        deps = ss.attempt.task.deps
        for i in np.flatnonzero(ss.status <= _SUBSCRIBED_MAX):
            d = self.subs.get(deps[i])
            if d is not None:
                d.pop(ss, None)
        parked = self.stalled.get(ss.attempt.task.job)
        if parked is not None:
            parked.pop(ss, None)

    def _drop_producer_subs(self, task_id: str) -> None:
        self.subs.pop(task_id, None)

    def on_job_done(self, job) -> None:
        super().on_job_done(job)
        self.stalled.pop(job, None)

    def verify_state(self, a: "SimAttempt") -> None:
        super().verify_state(a)
        ss = a.shuffle
        deps = a.task.deps
        in_heap = set(ss.ready)
        for i in np.flatnonzero(ss.status == S_READY):
            assert int(i) in in_heap, (a.attempt_id, deps[i])
        if a.state == AttemptState.RUNNING:
            for i in np.flatnonzero(ss.status <= _SUBSCRIBED_MAX):
                assert ss in self.subs.get(deps[i], {}), \
                    (a.attempt_id, deps[i])


# BatchQueue record kinds (the shuffle owns the registry; 0 stays invalid
# so a zeroed record slot can never masquerade as a live event). Kinds 3/4
# carry the simulation's map-milestone ladder and fixed-rate background
# ticks as typed lane records (DESIGN.md §17): same global seq counter, so
# the merged order equals the heap-only order; their appliers obey the
# lane contract (neither can complete a job — reduce completions, the one
# job-finishing event, stay on the heap).
K_FETCH_DONE = 1
K_FAIL_CYCLE = 2
K_MILESTONE = 3    # obj = map SimAttempt, dep = milestone ladder index
K_TICK = 4         # obj = None, dep = TICK_* selector

TICK_HB = 0        # Simulation._heartbeat_tick
TICK_EXPIRY = 1    # Simulation._expiry_tick


class BatchShuffle(EventShuffle):
    """The macro-event fetch plane (DESIGN.md §14): EventShuffle's
    candidate selection with its three per-fetch overheads amortized
    away, trace-equivalently.

    1. **Timers → calendar-lane records.** Fetch completions and failure
       cycles are typed records in the engine's
       :class:`~repro.sim.engine.BatchQueue` instead of per-event heap
       entries: no EventHandle, no args tuple, no generic dispatch.
       Cancellation is forgetting the record's integer token (the dict
       removal the canceller already performs); stale records are
       dropped at apply time by matching the token against the
       inflight/fail-cycle maps. A whole burst of records drains off one
       lane run between heap events, with the columnar
       ``fetched``/``sh_*`` write-through deferred per drain and flushed
       as one bulk store before the next heap event can read it.

    2. **Per-subscriber broadcast → completion log.** The event engine
       pays O(running reduce attempts) scalar status flips per map
       completion. Here a completion appends one entry to its job's
       *completion log*; each attempt holds a cursor (``ss.log_pos``)
       and reconciles the log delta **vectorized** (one mask over the
       int8 status column) the next time it selects candidates. This is
       trace-invariant because a WAITING→READY flip is unobservable
       until the attempt actually pops candidates: the live policies
       never read readiness (the ``sh_ready`` column is write-through
       telemetry), and ``try_start`` re-validates every popped index
       against the producer's current state exactly as the event engine
       does. The flip *is* observable for two groups, which keep an
       eager kick:

       - attempts burning a failure cycle for the completed producer
         (the pending timer must be cancelled now, not lazily) — the
         ``_fail_subs`` registry, populated only under faults;
       - attempts parked with free fetch budget (the event engine would
         launch at notify time) — the ``_idle`` set, which also absorbs
         EventShuffle's ``stalled`` bookkeeping (a silent abort parks
         the attempt exactly like budget starvation does).

       Attach vectorizes the same way: a fresh attempt starts its
       cursor at zero and reconciles the whole log in one mask instead
       of walking ``n_deps`` producer objects.

    3. **No-op fan-out → budget gate.** The eager kick only calls
       ``try_start`` when the attempt has (or just regained) free
       budget; for a saturated attempt the event engine's call provably
       returns without touching state, so skipping it is trace-inert.

    The fetch *transitions* stay sequential per record — flow counts
    feed the per-fetch throughput model, so end-flow/next-launch
    interleaving per completion is observable — the batching win is the
    machinery around them (``benchmarks/perf_shuffle.py`` gates ≥2×
    end-to-end over ``event`` at 1000 nodes).
    """

    mode = "batch"

    def __init__(self, sim: "Simulation"):
        super().__init__(sim)
        from repro.sim.engine import BatchQueue
        self.batches = BatchQueue(sim.engine, self._apply_record,
                                  self._flush_dirty, drain=self._drain_run)
        # job → producer-completion log: one dependency index appended
        # per (re-)completion, in completion order. Never mutated in
        # place, only appended — cursors stay valid.
        self._logs: Dict[object, List[int]] = {}
        # job → attempts parked with free fetch budget (ready queue
        # drained, or silently aborted): the next completion in the job
        # re-kicks them, replacing both the per-producer subscriber
        # fan-out and EventShuffle's stalled set.
        self._idle: Dict[object, Dict[ShuffleState, None]] = {}
        # producer task_id → attempts burning a failure cycle against
        # it (eager cancellation on re-completion; faulted runs only).
        self._fail_subs: Dict[str, Dict[ShuffleState, None]] = {}
        # Deferred write-through: attempts whose shuffle columns changed
        # during the current lane drain.
        self._dirty: Dict["SimAttempt", None] = {}
        # Drain-boundary re-allocation registry (DESIGN.md §17.4, opt-in
        # via net_opts={"realloc": True} on the kernel engine): live
        # fetch token → (flow slot, launch rate). None = off (the
        # default; launches then skip the bookkeeping entirely).
        self._tok_rate: Optional[Dict[int, tuple]] = None
        self.n_reallocs = 0
        # Hot-path caches (immutable for the simulation's lifetime).
        self._psizes: Dict[object, float] = {}
        self._node_pos = sim.cluster._node_pos
        self._pf = sim.params.parallel_fetches
        self._cycle = sim.params.fetch_cycle
        self._bino = isinstance(sim.speculator, BinocularSpeculator)
        # Network fast path: only the seed-compat flat model may take the
        # hand-inlined rate/flow arithmetic below (it IS that model);
        # every other model goes through its open/close methods. The
        # ε-fair model re-solves its share tables once per drain run via
        # the lane's bracketing hooks (DESIGN.md §15.3).
        self._inline_flat = self._net.inline_flat
        if self._net.wants_drain_hook:
            self.batches.on_begin = self._net.begin_drain
            self.batches.on_end = self._net.end_drain

    @staticmethod
    def _cancel(h) -> None:
        """Lane tokens need no disarming — the caller's dict removal
        already orphaned the record (see BatchQueue)."""

    def _apply_tick(self, which: int) -> None:
        # Shared record machinery: only KernelShuffle *schedules* K_TICK
        # records, but the reference applier and the fused loop dispatch
        # them here (the generic-drain parity path runs under kernel too).
        sim = self.sim
        if which == TICK_HB:
            sim._heartbeat_tick()
        else:
            sim._expiry_tick()

    def _psize(self, job) -> float:
        s = self._psizes.get(job)
        if s is None:
            s = self._psizes[job] = job.spec.partition_bytes()
        return s

    # -- completion log ----------------------------------------------------
    def _reconcile(self, ss: ShuffleState) -> None:
        """Fold the job's completion-log delta into the status column:
        every WAITING dependency with a completion logged since this
        attempt last looked flips to READY, in one vectorized mask. A
        stale entry (producer re-enqueued since) yields a transient
        READY that ``try_start`` re-validates and parks back to WAITING
        — the same recovery the event engine performs on its own stale
        ready-heap entries."""
        log = ss.log
        pos = ss.log_pos
        n = len(log)
        if pos >= n:
            return
        ss.log_pos = n
        status = ss.status
        if n - pos == 1:  # steady state: one completion since last look
            i = log[pos]
            if status[i] == S_WAITING:
                status[i] = S_READY
                ss.n_ready += 1
                heapq.heappush(ss.ready, i)
            return
        idx = np.array(log[pos:], dtype=np.int64)
        # duplicates (producer completed twice within one delta) must
        # count once: unique BEFORE the mask so n_ready stays exact
        idx = np.unique(idx)
        flip = idx[status[idx] == S_WAITING]
        k = len(flip)
        if k:
            status[flip] = S_READY
            ss.n_ready += k
            ready = ss.ready
            if ready:
                for i in flip.tolist():
                    heapq.heappush(ready, i)
            else:
                # np.unique output is ascending — already a valid heap
                ss.ready = flip.tolist()

    def _init_ready(self, a: "SimAttempt", ss: ShuffleState) -> None:
        ss.log = self._logs.setdefault(a.task.job, [])
        ss.log_pos = 0
        self._reconcile(ss)

    # -- record application (reference path; the fused drain below must
    #    stay transition-identical — tests run both on one seeded sim) --
    def _apply_record(self, kind: int, a: "SimAttempt", i: int,
                      src_idx: int, token: int) -> None:
        self.profile.lane_records += 1
        if kind > K_FAIL_CYCLE:
            if kind == K_MILESTONE:
                # stale-token drop = cancellation (reschedule/teardown
                # moved the attempt's milestone past this record)
                if a._milestone == token:
                    a._milestone = None
                    self.sim._map_milestone_fired_idx(a, i)
            else:
                self._apply_tick(i)
            return
        if kind == K_FETCH_DONE and self._tok_rate is not None:
            # token dies with this pop, live or stale — slots recycle
            # (§17.4: realloc registry hygiene; mirrors the fused loop)
            self._tok_rate.pop(token, None)
        ss = a.shuffle
        if ss is None:
            return
        if kind == K_FETCH_DONE:
            # ---- one fetch completion: _fetch_done minus the handles
            m = a.task.deps[i]
            if ss.inflight.get(m) != token:
                return  # cancelled (detach/abort) or superseded re-fetch
            del ss.inflight[m]
            src = ss.fetch_srcs.pop(m, None)
            if src is not None:
                self._net.close_flow(src, a.node_id)
            if a.state != AttemptState.RUNNING:
                return
            ss.fetched.add(m)
            ss.status[i] = S_FETCHED  # from INFLIGHT: n_ready untouched
            self._dirty[a] = None
            sim = self.sim
            if self._bino:
                sim.speculator.note_fetch_ok(m)
            if len(ss.fetched) == len(a.task.deps):
                sim._start_compute(a)
            else:
                self.try_start(a)
            return
        self._apply_fail(a, ss, i, token)

    def _apply_fail(self, a: "SimAttempt", ss: ShuffleState, i: int,
                    token: int) -> None:
        """One burned failure cycle — ``_fetch_failed`` over the lane."""
        m = a.task.deps[i]
        if ss.fail_cycles.get(m) != token:
            return
        del ss.fail_cycles[m]
        d = self._fail_subs.get(m)
        if d is not None:
            d.pop(ss, None)
        if a.state != AttemptState.RUNNING:
            return
        ss.failed_cycles += 1
        sim = self.sim
        if sim.obs is not None:
            sim.obs.emit(K_FETCH_FAIL, a=sim.cluster._node_pos[a.node_id],
                         b=ss.failed_cycles, obj=m)
        sim._report_fetch_failure(a, m)
        prod = sim._task(m)
        if prod is not None and prod.state == TaskState.COMPLETED:
            self._requeue(ss, i, m)
        else:
            ss.set_status(i, S_WAITING)  # producer re-running; await notify
        self._dirty[a] = None
        if ss.failed_cycles >= sim.params.reduce_abort_cycles:
            sim._attempt_failed(a, reason="shuffle-exceeded-failures")
            return
        self.try_start(a)

    # -- fused drain loop ---------------------------------------------------
    def _drain_run(self, heap: list, until) -> bool:
        """The hot loop of the whole simulator at scale: pops due lane
        records and applies them with every piece of shared state bound
        once per drain run (~tens of records) instead of once per
        record. Semantics are pinned to the reference path above —
        ``_apply_record`` + ``try_start`` transition-for-transition; the
        equivalence fuzzer and the generic-drain parity test enforce it.
        Failure-cycle records (faults only) take the reference path."""
        q = self.batches
        lheap = q._heap
        eng = q.engine
        objs = q.objs
        free = q._free
        kind_v = q._kind
        dep_v = q._dep
        time_v = q._time
        row_v = q._row
        pay_v = q._payload
        time_v = q._time
        row_v = q._row
        pay_v = q._payload
        pop = heapq.heappop
        push = heapq.heappush
        sim = self.sim
        nodes = sim.cluster.nodes
        task_index = sim._task_index
        live_map = self.registry.live
        node_pos = self._node_pos
        net = self._net
        inline_net = self._inline_flat
        nf = net.node_flows
        psizes = self._psizes
        dirty = self._dirty
        idle = self._idle
        fail_subs = self._fail_subs
        pf = self._pf
        cycle = self._cycle
        bino = self._bino
        speculator = sim.speculator
        tok_rate = self._tok_rate
        arrs = sim.arrays
        arr_wd = arrs.work_done if arrs is not None else None
        arr_ls = arrs.last_sync if arrs is not None else None
        RUNNING = AttemptState.RUNNING
        T_COMPLETED = TaskState.COMPLETED
        # FairNetwork bulk mode (kernel drain): open/close stage only the
        # scalar flow-table fields while the drain holds shares frozen —
        # small enough to inline here, like the flat block below. The
        # staged arithmetic mirrors FairNetwork.open_flow/close_flow's
        # frozen branches field-for-field (the bulk-vs-incremental fuzz
        # differential pins it).
        bulk_net = (not inline_net) and getattr(net, "_bulk", False) \
            and net._frozen
        if bulk_net:
            pair = net._pair
            nfree = net._free
            f_active = net.f_active
            f_rate = net.f_rate
            f_si = net.f_si
            f_di = net.f_di
            # python-scalar reads: frozen shares + static rack layout
            share_l = net.link_share.tolist()
            rack_l = net._rack_py
            n_nodes = len(net.node_ids)
            nn2 = 2 * n_nodes
        n_records = 0
        n_pops = 0
        n_slots = 0
        n_try = 0
        paused = False
        while lheap:
            l0 = lheap[0]
            lt = l0[0]
            if heap:
                h0 = heap[0]
                ht = h0[0]
                if lt > ht or (lt == ht and l0[1] > h0[1]):
                    break
            if until is not None and lt > until:
                paused = True
                break
            eng.now = lt
            slot = pop(lheap)[2]
            if kind_v is not q._kind:  # store grew mid-drain
                kind_v = q._kind
                dep_v = q._dep
                time_v = q._time
                row_v = q._row
                pay_v = q._payload
            a = objs[slot]
            objs[slot] = None
            i = int(dep_v[slot])
            k = kind_v[slot]
            free.append(slot)  # popped ⇒ recyclable (reads done above)
            n_records += 1
            if k != K_FETCH_DONE:
                if k == K_MILESTONE:
                    # ---- map-milestone ladder (kernel mode only; the
                    # map phase's hot loop). The common transition — an
                    # on-schedule spill with the node still at speed —
                    # is `_map_milestone_fired` + `_schedule_map_
                    # milestone` inlined arithmetic-for-arithmetic
                    # (sync fold, max clamp, ladder scan); everything
                    # else (slowdown recheck, disk exception,
                    # completion) drops to the reference path.
                    if a._milestone != slot:
                        continue  # stale: rescheduled or torn down
                    a._milestone = None
                    if a.state is not RUNNING:
                        continue
                    cache = a._milestones_cache
                    if cache is not None and \
                            cache[0] == a.disk_exception_at:
                        pts = cache[1]
                    else:
                        pts = sim._map_milestones(a)
                    p = pts[i]
                    frac = p[0]
                    node = nodes[a.node_id]
                    speed = node.speed
                    wt = a.work_total
                    wd = a.work_done + (lt - a.last_sync) * speed
                    if wd > wt:
                        wd = wt
                    target = frac * wt
                    if p[1] != "spill" or wd + 1e-9 < target:
                        sim._map_milestone_fired(a, frac, p[1])
                        kind_v = q._kind
                        dep_v = q._dep
                        time_v = q._time
                        row_v = q._row
                        pay_v = q._payload
                        continue
                    if target > wd:
                        wd = target
                    a.work_done = wd
                    a.last_sync = lt
                    row_a = a.row
                    if row_a >= 0:
                        if arr_wd is not arrs.work_done:
                            arr_wd = arrs.work_done  # grew mid-drain
                            arr_ls = arrs.last_sync
                        arr_wd[row_a] = wd
                        arr_ls[row_a] = lt
                    tid = a.task.task_id
                    sl = node.spill_logs
                    prev = sl.get(tid)
                    if prev is None or frac > prev:
                        sl[tid] = frac
                    if bino:
                        speculator.record_progress_log(ProgressLog(
                            task_id=tid, node_id=a.node_id, offset=frac))
                    if speed <= 0.0:
                        continue  # frozen; expiry/death cleans up
                    thresh = wd / wt + 1e-12
                    nxt = 0
                    npts = len(pts)
                    while nxt < npts and pts[nxt][0] <= thresh:
                        nxt += 1
                    if nxt == npts:  # degenerate: ladder exhausted
                        sim._schedule_map_milestone(a)
                        kind_v = q._kind
                        dep_v = q._dep
                        time_v = q._time
                        row_v = q._row
                        pay_v = q._payload
                        continue
                    dt = (pts[nxt][0] * wt - wd) / speed
                    if free:
                        tok = free.pop()
                        objs[tok] = a
                    else:
                        tok = q._n
                        if tok == len(q.recs):
                            q._grow()
                            kind_v = q._kind
                            dep_v = q._dep
                            time_v = q._time
                            row_v = q._row
                            pay_v = q._payload
                        q._n = tok + 1
                        objs.append(a)
                    t2 = lt + dt if dt > 0.0 else lt
                    kind_v[tok] = K_MILESTONE
                    time_v[tok] = t2
                    row_v[tok] = row_a
                    dep_v[tok] = nxt
                    pay_v[tok] = 0
                    push(lheap, (t2, eng._seq, tok))
                    eng._seq += 1
                    a._milestone = tok
                    continue
                # rare kinds (faults, background ticks): reference
                # paths; they may re-enter try_start/schedule and grow
                # the store — rebind defensively after
                if k == K_FAIL_CYCLE:
                    ss = a.shuffle
                    if ss is not None:
                        self._apply_fail(a, ss, i, slot)
                else:  # K_TICK
                    self._apply_tick(i)
                kind_v = q._kind
                dep_v = q._dep
                time_v = q._time
                row_v = q._row
                pay_v = q._payload
                continue
            # ---- fetch completion (== _apply_record's hot branch) ----
            if tok_rate is not None:
                # The token dies with this pop — live or stale. Lane
                # slots recycle, so a leftover entry would silently
                # re-key itself to whatever fetch is issued the slot
                # next (§17.4: realloc registry hygiene).
                tok_rate.pop(slot, None)
            ss = a.shuffle
            if ss is None:
                continue
            deps = a.task.deps
            m = deps[i]
            inflight = ss.inflight
            if inflight.get(m) != slot:
                continue  # cancelled or superseded re-fetch
            del inflight[m]
            src = ss.fetch_srcs.pop(m, None)
            dst = a.node_id
            if src is not None:
                if inline_net:
                    sn = nodes[src]
                    dn = nodes[dst]
                    f = sn.active_flows - 1
                    sn.active_flows = f if f > 0 else 0
                    f = dn.active_flows - 1
                    dn.active_flows = f if f > 0 else 0
                    nf[node_pos[src]] = sn.active_flows
                    nf[node_pos[dst]] = dn.active_flows
                elif bulk_net:
                    # staged close: the slot dies now, count tables
                    # catch up in the end_drain rebuild
                    key = (src, dst)
                    slots_f = pair[key]
                    slot_f = slots_f.pop()
                    if not slots_f:
                        del pair[key]
                    f_active[slot_f] = False
                    f_rate[slot_f] = 0.0
                    net.n_flows -= 1
                    nfree.append(slot_f)
                    net._stale = True
                else:
                    net.close_flow(src, dst)
            if a.state is not RUNNING:
                continue
            fetched = ss.fetched
            fetched.add(m)
            status = ss.status
            status[i] = S_FETCHED  # from INFLIGHT: n_ready untouched
            dirty[a] = None
            if bino:
                speculator.note_fetch_ok(m)
            if len(fetched) == len(deps):
                sim._start_compute(a)
                continue
            # ---- inline try_start (state/compute checks hold: the
            #      attempt is RUNNING and still missing partitions) ----
            fail_cycles = ss.fail_cycles
            budget = pf - len(inflight) - len(fail_cycles)
            if budget <= 0:
                continue
            n_try += 1
            if ss.log_pos < len(ss.log):
                self._reconcile(ss)
            ready = ss.ready
            changed = False
            while budget > 0 and ready:
                j = pop(ready)
                n_pops += 1
                if status[j] != S_READY:
                    continue  # stale entry (lazy deletion)
                m2 = deps[j]
                prod = task_index.get(m2)
                if prod is None or prod.state is not T_COMPLETED:
                    status[j] = S_WAITING  # re-enqueued; next completion
                    ss.n_ready -= 1       # re-logs it
                    changed = True
                    continue
                src2 = None
                live = live_map.get(m2)
                if live:
                    for nid in prod.output_nodes:
                        if nid in live:
                            src2 = nid
                            break
                if src2 is None:
                    status[j] = S_FAIL_CYCLE
                    ss.n_ready -= 1
                    if free:
                        tok = free.pop()
                        objs[tok] = a
                    else:
                        tok = q._n
                        if tok == len(q.recs):
                            q._grow()
                            kind_v = q._kind
                            dep_v = q._dep
                            time_v = q._time
                            row_v = q._row
                            pay_v = q._payload
                        q._n = tok + 1
                        objs.append(a)
                    t2 = lt + cycle
                    kind_v[tok] = K_FAIL_CYCLE
                    time_v[tok] = t2
                    row_v[tok] = a.row
                    dep_v[tok] = j
                    pay_v[tok] = 0
                    push(lheap, (t2, eng._seq, tok))
                    eng._seq += 1
                    fail_cycles[m2] = tok
                    fail_subs.setdefault(m2, {})[ss] = None
                    n_slots += 1
                    budget -= 1
                    changed = True
                    continue
                status[j] = S_INFLIGHT
                ss.n_ready -= 1
                if inline_net:
                    # per-flow rate decided at flow start (the seed-
                    # compat flat model's fetch_throughput arithmetic)
                    sn = nodes[src2]
                    dn = nodes[dst]
                    if src2 == dst:
                        rate = DISK_BW / (sn.active_flows + 1)
                    else:
                        sf = sn.active_flows + 1
                        df = dn.active_flows + 1
                        rate = NIC_BW / (sf if sf > df else df)
                    sn.active_flows += 1
                    dn.active_flows += 1
                    nf[node_pos[src2]] = sn.active_flows
                    nf[node_pos[dst]] = dn.active_flows
                elif bulk_net:
                    # staged open priced against the frozen shares
                    si = node_pos[src2]
                    if src2 == dst:
                        di = si
                        r = share_l[n_nodes + si]
                    else:
                        di = node_pos[dst]
                        r = share_l[si]
                        x = share_l[di]
                        if x < r:
                            r = x
                        rs = rack_l[si]
                        rd = rack_l[di]
                        if rs != rd:
                            x = share_l[nn2 + rs]
                            if x < r:
                                r = x
                            x = share_l[nn2 + rd]
                            if x < r:
                                r = x
                    rate = r if r > 1.0 else 1.0
                    if nfree:
                        slot_f = nfree.pop()
                    else:
                        slot_f = net._alloc()
                        f_active = net.f_active  # grow may swap stores
                        f_rate = net.f_rate
                        f_si = net.f_si
                        f_di = net.f_di
                    net.last_slot = slot_f
                    f_si[slot_f] = si
                    f_di[slot_f] = di
                    f_active[slot_f] = True
                    net.n_flows += 1
                    key2 = (src2, dst)
                    plist = pair.get(key2)
                    if plist is None:
                        pair[key2] = [slot_f]
                    else:
                        plist.append(slot_f)
                    net._stale = True
                else:
                    rate = net.open_flow(src2, dst)
                ss.fetch_srcs[m2] = src2
                job2 = prod.job
                size = psizes.get(job2)
                if size is None:
                    size = psizes[job2] = job2.spec.partition_bytes()
                dt = size / rate
                if dt < 1e-3:
                    dt = 1e-3
                if free:
                    tok = free.pop()
                    objs[tok] = a
                else:
                    tok = q._n
                    if tok == len(q.recs):
                        q._grow()
                        kind_v = q._kind
                        dep_v = q._dep
                        time_v = q._time
                        row_v = q._row
                        pay_v = q._payload
                    q._n = tok + 1
                    objs.append(a)
                t2 = lt + dt
                kind_v[tok] = K_FETCH_DONE
                time_v[tok] = t2
                row_v[tok] = a.row
                dep_v[tok] = j
                pay_v[tok] = node_pos[src2]
                push(lheap, (t2, eng._seq, tok))
                eng._seq += 1
                inflight[m2] = tok
                if tok_rate is not None:
                    tok_rate[tok] = (net.last_slot, rate)
                n_slots += 1
                budget -= 1
                changed = True
            if changed:
                dirty[a] = None
            if budget > 0:
                if not ss.parked:
                    ss.parked = True
                    idle.setdefault(a.task.job, {})[ss] = None
            elif ss.parked:
                ss.parked = False
                d = idle.get(a.task.job)
                if d is not None:
                    d.pop(ss, None)
        prof = self.profile
        prof.lane_records += n_records
        prof.heap_pops += n_pops
        prof.slots_filled += n_slots
        prof.try_calls += n_try
        q.applied += n_records
        return paused

    # -- deferred columnar write-through -----------------------------------
    def _arr_sh(self, a: "SimAttempt", ss: ShuffleState) -> None:
        if self.batches.in_drain:
            self._dirty[a] = None
        elif a.row >= 0:
            arr = self.sim.arrays
            arr.fetched[a.row] = len(ss.fetched)
            arr.sh_ready[a.row] = ss.n_ready
            arr.sh_inflight[a.row] = len(ss.inflight)
            arr.sh_fail[a.row] = len(ss.fail_cycles)

    def _flush_dirty(self) -> None:
        d = self._dirty
        if not d:
            return
        arr = self.sim.arrays
        if arr is not None:
            if len(d) > 3:
                rows = []
                fetched = []
                ready = []
                inflight = []
                fail = []
                for a in d:
                    if a.row < 0:
                        continue
                    ss = a.shuffle
                    rows.append(a.row)
                    fetched.append(len(ss.fetched))
                    ready.append(ss.n_ready)
                    inflight.append(len(ss.inflight))
                    fail.append(len(ss.fail_cycles))
                if rows:
                    arr.write_shuffle_rows(rows, fetched, ready, inflight,
                                           fail)
            else:
                for a in d:
                    if a.row < 0:
                        continue
                    ss = a.shuffle
                    r = a.row
                    arr.fetched[r] = len(ss.fetched)
                    arr.sh_ready[r] = ss.n_ready
                    arr.sh_inflight[r] = len(ss.inflight)
                    arr.sh_fail[r] = len(ss.fail_cycles)
        d.clear()

    # -- candidate selection -----------------------------------------------
    # (The base-class _launch_fetch/_launch_fail_cycle hooks are not
    # overridden: batch mode's only launch sites are the two inlined
    # schedulers in try_start and _drain_run below.)
    def try_start(self, a: "SimAttempt") -> None:
        """EventShuffle.try_start transition-for-transition, with the
        sub-calls (set_status, registry.pick, fetch_throughput, timer
        scheduling) inlined over local binds, the completion-log
        reconcile up front, and the idle-set bookkeeping at the end."""
        ss = a.shuffle
        if a.state != AttemptState.RUNNING or a.compute_started:
            return
        sim = self.sim
        prof = self.profile
        prof.try_calls += 1
        inflight = ss.inflight
        fail_cycles = ss.fail_cycles
        budget = self._pf - len(inflight) - len(fail_cycles)
        if budget <= 0:
            return
        if ss.log_pos < len(ss.log):
            self._reconcile(ss)
        deps = a.task.deps
        ready = ss.ready
        status = ss.status
        task_index = sim._task_index
        live_map = self.registry.live
        nodes = sim.cluster.nodes
        batches = self.batches
        net = self._net
        inline_net = self._inline_flat
        nf = net.node_flows
        node_pos = self._node_pos
        now = sim.engine.now
        dst = a.node_id
        row = a.row
        changed = False
        while budget > 0 and ready:
            i = heapq.heappop(ready)
            prof.heap_pops += 1
            if status[i] != S_READY:
                continue  # stale entry (lazy deletion)
            m = deps[i]
            prod = task_index.get(m)
            if prod is None or prod.state != TaskState.COMPLETED:
                # producer re-enqueued since it went ready; its next
                # completion re-logs it
                status[i] = S_WAITING
                ss.n_ready -= 1
                changed = True
                continue
            src = None
            live = live_map.get(m)
            if live:
                for nid in prod.output_nodes:
                    if nid in live:
                        src = nid
                        break
            if src is None:
                status[i] = S_FAIL_CYCLE
                ss.n_ready -= 1
                fail_cycles[m] = batches.schedule(
                    now + self._cycle, K_FAIL_CYCLE, a, row, i, 0)
                self._fail_subs.setdefault(m, {})[ss] = None
                prof.slots_filled += 1
                budget -= 1
                changed = True
                continue
            status[i] = S_INFLIGHT
            ss.n_ready -= 1
            if inline_net:
                # inline _launch_fetch (the seed-compat flat model's
                # fetch_throughput semantics: quasi-static per-flow
                # rate decided at flow start)
                sn = nodes[src]
                dn = nodes[dst]
                if src == dst:
                    rate = DISK_BW / (sn.active_flows + 1)
                else:
                    sf = sn.active_flows + 1
                    df = dn.active_flows + 1
                    rate = NIC_BW / (sf if sf > df else df)
                sn.active_flows += 1
                dn.active_flows += 1
                nf[node_pos[src]] = sn.active_flows
                nf[node_pos[dst]] = dn.active_flows
            else:
                rate = net.open_flow(src, dst)
            ss.fetch_srcs[m] = src
            dt = self._psize(prod.job) / rate
            if dt < 1e-3:
                dt = 1e-3
            tok = batches.schedule(
                now + dt, K_FETCH_DONE, a, row, i, self._node_pos[src])
            inflight[m] = tok
            tr = self._tok_rate
            if tr is not None:
                tr[tok] = (net.last_slot, rate)
            prof.slots_filled += 1
            budget -= 1
            changed = True
        if changed:
            self._arr_sh(a, ss)
        if budget > 0:
            # candidates exhausted with budget to spare: park for the
            # job's next completion (the event broadcast's re-kick)
            if not ss.parked:
                ss.parked = True
                self._idle.setdefault(a.task.job, {})[ss] = None
        elif ss.parked:
            ss.parked = False
            d = self._idle.get(a.task.job)
            if d is not None:
                d.pop(ss, None)

    def mark_stalled(self, a: "SimAttempt") -> None:
        ss = a.shuffle
        if not ss.parked:
            ss.parked = True
            self._idle.setdefault(a.task.job, {})[ss] = None

    # -- eager notification (the log handles the rest) ---------------------
    def _notify(self, task: "SimTask") -> None:
        """Append to the completion log, then kick only the attempts for
        which the event broadcast's visit is observable *now*: failure
        cycles against this producer are cancelled (their timer must
        not fire), and parked attempts with free budget re-select (the
        event engine would launch at notify time). Everyone else picks
        the completion up from the log on their next selection."""
        m = task.task_id
        self._logs.setdefault(task.job, []).append(task.index)
        targets = self._idle.pop(task.job, None) or {}
        for ss in targets:
            ss.parked = False  # consumed; try_start below re-parks
        fs = self._fail_subs.get(m)
        if fs:
            targets = dict(targets)
            targets.update(fs)
        if not targets:
            return
        pf = self._pf
        for ss in sorted(targets, key=lambda s: s.key):
            a = ss.attempt
            if a.state != AttemptState.RUNNING:
                continue
            i = a.task.dep_pos[m]
            if ss.status[i] == S_FAIL_CYCLE:
                # fresh MOF: drop the pending failure cycle so the retry
                # is immediate rather than waiting out the timeout
                ss.fail_cycles.pop(m, None)
                if fs is not None:
                    fs.pop(ss, None)
                ss.set_status(i, S_READY)
                heapq.heappush(ss.ready, i)
                self._arr_sh(a, ss)
            if pf - len(ss.inflight) - len(ss.fail_cycles) > 0:
                self.try_start(a)

    def _requeue(self, ss: ShuffleState, i: int, m: str) -> None:
        ss.set_status(i, S_READY)
        heapq.heappush(ss.ready, i)

    # -- registries / lifecycle --------------------------------------------
    def _drop_subscriptions(self, ss: ShuffleState) -> None:
        deps = ss.attempt.task.deps
        for i in np.flatnonzero(ss.status == S_FAIL_CYCLE):
            d = self._fail_subs.get(deps[i])
            if d is not None:
                d.pop(ss, None)
        if ss.parked:
            ss.parked = False
            d = self._idle.get(ss.attempt.task.job)
            if d is not None:
                d.pop(ss, None)

    def _drop_producer_subs(self, task_id: str) -> None:
        self._fail_subs.pop(task_id, None)

    def on_job_done(self, job) -> None:
        ShuffleEngine.on_job_done(self, job)
        self._logs.pop(job, None)
        self._idle.pop(job, None)
        self._psizes.pop(job, None)

    # -- consistency ---------------------------------------------------------
    def verify_state(self, a: "SimAttempt") -> None:
        ShuffleEngine.verify_state(self, a)
        ss = a.shuffle
        deps = a.task.deps
        in_heap = set(ss.ready)
        for i in np.flatnonzero(ss.status == S_READY):
            assert int(i) in in_heap, (a.attempt_id, deps[i])
        # the cursor never outruns the log
        log = self._logs.get(a.task.job)
        assert log is not None and log is ss.log, a.attempt_id
        assert ss.log_pos <= len(log), (a.attempt_id, ss.log_pos)
        # the parked flag mirrors idle-set membership exactly
        assert ss.parked == (
            ss in self._idle.get(a.task.job, {})), (a.attempt_id, ss.parked)
        # a WAITING dep whose producer is COMPLETED must have its
        # completion still pending in the log delta (else it could
        # never become READY again)
        sim = self.sim
        pending = set(log[ss.log_pos:])
        for i in np.flatnonzero(ss.status == S_WAITING):
            prod = sim._task(deps[i])
            if prod is not None and prod.state == TaskState.COMPLETED:
                assert int(i) in pending, (a.attempt_id, deps[i])
        if a.state == AttemptState.RUNNING:
            for i in np.flatnonzero(ss.status == S_FAIL_CYCLE):
                assert ss in self._fail_subs.get(deps[i], {}), \
                    (a.attempt_id, deps[i])
        # every live timer token references a pending, matching record
        q = self.batches
        for src_map, want in ((ss.inflight, K_FETCH_DONE),
                              (ss.fail_cycles, K_FAIL_CYCLE)):
            for m, tok in src_map.items():
                assert isinstance(tok, int), (a.attempt_id, m, tok)
                assert 0 <= tok < q._n, (a.attempt_id, m, tok, q._n)
                assert q.objs[tok] is a, (a.attempt_id, m)
                assert int(q._kind[tok]) == want, (a.attempt_id, m)
                assert int(q._dep[tok]) == a.task.dep_pos[m], \
                    (a.attempt_id, m)


class KernelShuffle(BatchShuffle):
    """Bulk-launch drain (DESIGN.md §17): BatchShuffle with the three
    residual per-record Python paths kernelized.

    1. **Map milestones as lane records** (``K_MILESTONE``): the ladder
       advances through typed ``(row, frac-index, kind)`` records on the
       calendar lane instead of per-attempt ``engine.after`` callbacks.
       Records draw from the same global seq counter the heap uses, so
       on the count-based networks (flat/topo) the merged event order —
       and therefore every trace — is byte-identical to BatchShuffle.
    2. **Background ticks as lane records** (``K_TICK``): heartbeat and
       NM-expiry scans ride the lane too, removing the last per-sim-
       second heap events. Drains then span whole heap-event gaps,
       which under ``FairNetwork`` coarsens the recompute cadence — the
       documented trace-shift waiver (§17.3); flat/topo are unaffected
       (rates there read live counts, not drain-frozen shares).
    3. **Bulk flow accounting** on a ``FairNetwork`` in drain mode:
       per-flow open/close bookkeeping is staged during the drain
       (shares are frozen, so the tables are dead until end-of-drain
       anyway) and applied in one vectorized step by ``end_drain``;
       the water-fill solve itself sits behind a pluggable bulk
       backend (``repro/accel/bulk.py``: numpy / jax / pallas).

    Everything else — record layout, the fused drain loop's fetch hot
    path, cancellation discipline — is inherited; the differential
    fuzzer pins kernel ≡ batch byte-for-byte on flat/topo.
    """

    mode = "kernel"

    def __init__(self, sim: "Simulation") -> None:
        super().__init__(sim)
        net = self._net
        if getattr(net, "supports_bulk", False):
            net.enable_bulk()
            if net.realloc:
                # §17.4 waiver: opt-in re-pricing of in-flight transfers
                # at every drain boundary that re-solved the shares.
                # Traces shift by design (completion times move), so the
                # fuzz matrix excludes realloc runs from byte-equivalence
                # and pins invariants instead.
                self._tok_rate = {}
                self.batches.on_begin = self._realloc_begin

    def _realloc_begin(self) -> None:
        """begin_drain plus §17.4 re-allocation: when the solve actually
        ran (shares moved), re-price every live in-flight fetch with the
        batch pricing rule (``BulkBackend.price`` — one vectorized step,
        the Pallas kernel's production call site) and slide its lane
        record: remaining bytes at the old rate, completion at the new.
        Token-forgetting does the cancellation — the superseded record
        stale-drops at pop because ``ss.inflight`` now maps to the new
        token."""
        net = self._net
        before = net.n_recomputes
        net.begin_drain()
        tr = self._tok_rate
        if net.n_recomputes == before or not tr:
            return
        q = self.batches
        kind_v = q._kind
        dep_v = q._dep
        time_v = q._time
        objs = q.objs
        now = q.engine.now
        live = []
        for tok, (slot, rate_old) in list(tr.items()):
            # A registry entry can outlive its record (normal pops and
            # stale drops don't clean it): validate against the live
            # store. A recycled token is either overwritten at its next
            # fetch launch or fails these checks.
            a = objs[tok] if kind_v[tok] == K_FETCH_DONE else None
            ss = a.shuffle if a is not None else None
            if ss is None or \
                    ss.inflight.get(a.task.deps[dep_v[tok]]) != tok:
                del tr[tok]
                continue
            # capture the record fields now: scheduling the replacement
            # records below may grow (and swap) the column stores
            live.append((tok, slot, rate_old, a, ss, float(time_v[tok]),
                         int(dep_v[tok]), int(q._payload[tok])))
        if not live:
            return
        slots = np.fromiter((e[1] for e in live), dtype=np.int64,
                            count=len(live))
        links = net.f_links[slots]
        rates = net._backend.price(net.link_share, links, links >= 0)
        for k, (tok, slot, rate_old, a, ss, t_done, i, pay) in \
                enumerate(live):
            r_new = float(rates[k])
            if r_new == rate_old:
                continue
            rem = (t_done - now) * rate_old
            if rem < 0.0:
                rem = 0.0
            dt = rem / r_new
            if dt < 1e-3:
                dt = 1e-3
            new_tok = q.schedule(now + dt, K_FETCH_DONE, a, a.row, i, pay)
            ss.inflight[a.task.deps[i]] = new_tok
            del tr[tok]
            tr[new_tok] = (slot, r_new)
            self.n_reallocs += 1

    # -- simulation-side timers as lane records (DESIGN.md §17) -----------
    def schedule_milestone(self, a: "SimAttempt", dt: float, idx: int,
                           frac: float, kind: str):
        eng = self.sim.engine
        t = eng.now + (dt if dt > 0.0 else 0.0)
        return self.batches.schedule(t, K_MILESTONE, a, a.row, idx, 0)

    def schedule_tick(self, dt: float, which: int) -> None:
        eng = self.sim.engine
        t = eng.now + (dt if dt > 0.0 else 0.0)
        self.batches.schedule(t, K_TICK, None, -1, which, 0)

    def verify_timer(self, a: "SimAttempt") -> None:
        tok = a._milestone
        if not isinstance(tok, int):
            return  # reduce-completion timers stay heap EventHandles
        q = self.batches
        assert 0 <= tok < q._n, (a.attempt_id, tok, q._n)
        assert q.objs[tok] is a, a.attempt_id
        assert int(q._kind[tok]) == K_MILESTONE, a.attempt_id


def make_engine(sim: "Simulation", mode: str) -> ShuffleEngine:
    if mode == "batch":
        return BatchShuffle(sim)
    if mode == "kernel":
        return KernelShuffle(sim)
    if mode == "event":
        return EventShuffle(sim)
    if mode == "rescan":
        return RescanShuffle(sim)
    raise ValueError(f"unknown shuffle mode: {mode!r}")
