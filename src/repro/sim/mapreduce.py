"""YARN MapReduce execution semantics over the discrete-event engine.

Faithfully models the YARN 2.7.1 behaviours that drive the paper's effects:

- NodeManager liveness: RM expires a silent node after ``nm_expiry``
  (default 600 s) — the long fuse behind Fig. 1's small-job slowdowns;
- on node expiry the AM re-runs completed MAP tasks whose MOFs lived only
  there (standard YARN), and reschedules running attempts;
- shuffle fetch failures: a reducer fetching a lost MOF burns a
  ``fetch_cycle`` (Hadoop's 180 s connect/read timeout), reports to the AM,
  and retries; the AM re-runs the producer map after
  ``am_fetch_threshold`` (3) reports — the dependency-oblivious stall;
- reduce slowstart at 5 % map completion; parallel fetchers per reducer;
- speculative attempts ride the pluggable policy (``repro.core``):
  YarnLateSpeculator reproduces the baseline, BinocularSpeculator the paper.

The policy sees the cluster only through ``ClusterSnapshot`` ticks and acts
only through SpeculateTask/KillAttempt/MarkNodeFailed — the same interface
the live training runtime drives.

Layering (DESIGN.md §12): this module owns task/attempt lifecycle and the
AM/RM control decisions. Fetch mechanics live in ``repro.sim.shuffle``
(per-producer ready queues + MOF registry, with the seed's rescan path as
the equivalence reference) and container scheduling in
``repro.sim.dispatch``.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections.abc import Mapping as _Mapping
from typing import (Callable, Dict, List, Optional, Sequence, Set, Tuple,
                    Union)

import numpy as np

from repro.core.arrays import SHUFFLE_FRACTION, ArraySnapshot
from repro.core.rollback import ProgressLog
from repro.core.speculator import BinocularSpeculator, Speculator
from repro.core.types import (
    AttemptState,
    AttemptView,
    ClusterSnapshot,
    FetchFailure,
    KillAttempt,
    MarkNodeFailed,
    NodeView,
    SpeculateTask,
    TaskKind,
    TaskState,
    TaskView,
)
from repro.net.base import make_network
from repro.obs.trace import (
    ACT_KILL,
    ACT_MARK_FAILED,
    ACT_SPECULATE,
    END_COMPLETED,
    END_FAILED,
    END_KILLED,
    FAULT_CODES,
    K_ACTION,
    K_ATT_END,
    K_ATT_START,
    K_DETECT,
    K_FAULT,
    K_ROLLBACK,
    TraceRecorder,
)
from repro.sim.cluster import Cluster, HEARTBEAT_PERIOD
from repro.sim.dispatch import Dispatcher, LaunchRequest
from repro.sim.engine import Engine, EventHandle
from repro.sim.job import JobResult, JobSpec
from repro.sim.shuffle import (ShuffleState, TICK_EXPIRY, TICK_HB,
                               make_engine)

__all__ = [
    "BINO_PARAMS", "LaunchRequest", "SimAttempt", "SimJob", "SimParams",
    "SimTask", "Simulation",
]


@dataclasses.dataclass(frozen=True)
class SimParams:
    """YARN-calibrated timing constants (overridden per policy)."""

    nm_expiry: float = 600.0          # RM NodeManager liveness expiry
    expiry_check: float = 10.0
    heartbeat: float = HEARTBEAT_PERIOD
    spec_interval: float = 1.0        # speculator tick
    slowstart: float = 0.05           # reduce slowstart (fraction of maps)
    am_startup: float = 12.0          # AM negotiation before first launch
    task_overhead: float = 3.0        # container + JVM spin-up per attempt
    fetch_cycle: float = 180.0        # one failed-fetch timeout+report cycle
    am_fetch_threshold: int = 3       # AM re-runs map after N reports...
    # ...but only once ≥ this fraction of the job's RUNNING reduce tasks
    # have reported (Hadoop's too-many-fetch-failures quorum). With few
    # stragglers the quorum shrinks to the running set — the slow fuse.
    am_fetch_quorum: float = 0.5
    # A reduce attempt aborts itself after this many failed fetch cycles
    # (Shuffle EXCEEDED_MAX_FAILURES) — its re-attempt re-shuffles from
    # scratch and "cannot help but wait and encounter several fetch
    # failures again" (§II.D.1).
    reduce_abort_cycles: int = 2
    parallel_fetches: int = 5         # fetchers per reduce attempt
    work_noise: float = 0.08          # lognormal σ on per-attempt work
    max_running_attempts: int = 2     # original + 1 speculative copy
    sim_time_cap: float = 36_000.0


# Binocular speculation pairs its dependency-aware re-execution with
# aggressive shuffle timeouts ("short timeouts", §IV.B.1): a false positive
# only costs one map re-run, whereas YARN's 180 s default guards its
# whole-job churn. The AM threshold stays at YARN's 3; Bino's dependency
# tracker fires first at 2 consecutive failures.
BINO_PARAMS = SimParams(fetch_cycle=60.0)


# Reduce progress split (1/3 shuffle, 2/3 sort+reduce). Single source of
# truth lives next to the columnar progress query it must mirror exactly.
_SHUFFLE_FRAC = SHUFFLE_FRACTION


class SimAttempt:
    def __init__(self, sim: "Simulation", task: "SimTask", node_id: str,
                 *, speculative: bool, rollback: bool, start_offset: float):
        self.sim = sim
        self.task = task
        # Per-simulation counter (not process-global): attempt ids are then
        # reproducible run-to-run, so action traces from two simulations in
        # one process can be compared verbatim (the equivalence gate).
        self.attempt_id = f"{task.task_id}_a{next(sim._attempt_seq)}"
        self.node_id = node_id
        self.state = AttemptState.RUNNING
        self.start_time = sim.engine.now
        self.is_speculative = speculative
        self.is_rollback = rollback
        noise = float(np.exp(sim.rng.normal(0.0, sim.params.work_noise)))
        self.work_total = task.work_seconds * noise + sim.params.task_overhead
        self.work_done = start_offset * self.work_total
        self.last_sync = sim.engine.now
        # Pending milestone/completion timer: an EventHandle on the heap,
        # or an int calendar-lane token for batch-mode map milestones.
        self._milestone: Optional[Union[EventHandle, int]] = None
        # Map-only: progress point where an injected disk exception fires.
        self.disk_exception_at: Optional[float] = None
        # Milestone-ladder cache: (disk_exception_at, points) — the
        # ladder only changes when a disk exception is injected, so the
        # per-spill rescheduling stops rebuilding and re-sorting it.
        self._milestones_cache: Optional[Tuple[Optional[float], list]] = None
        # Reduce-only: shuffle bookkeeping, attached by the shuffle engine.
        self.shuffle: Optional[ShuffleState] = None
        self.compute_started = False
        self.end_time: Optional[float] = None  # completion/failure/kill
        # Columnar mirror row (−1 when the sim runs without ArraySnapshot).
        self.row = -1

    # ------------------------------------------------------------------
    @property
    def node(self):
        return self.sim.cluster.nodes[self.node_id]

    def sync(self) -> None:
        """Fold linear work accrual into ``work_done`` — called at EVENTS
        only (milestones, speed changes, completion), never on reads.
        Keeping reads pure means the simulation's float state is identical
        no matter how often progress is observed, which is what lets the
        columnar mirror stay bit-equal to the object fields."""
        if self.state != AttemptState.RUNNING:
            return  # progress (and last_sync) frozen at end state
        now = self.sim.engine.now
        if self.task.kind == TaskKind.MAP or self.compute_started:
            self.work_done += (now - self.last_sync) * self.node.speed
            self.work_done = min(self.work_done, self.work_total)
        self.last_sync = now
        if self.row >= 0:
            self.sim.arrays.sync_row(self.row, self.work_done, self.last_sync)

    def _work_done_now(self) -> float:
        """Pure read of current work: accrual projected from the last
        event fold, without mutating it."""
        if self.state == AttemptState.RUNNING and (
                self.task.kind == TaskKind.MAP or self.compute_started):
            now = self.sim.engine.now
            return min(self.work_done + (now - self.last_sync)
                       * self.node.speed, self.work_total)
        return self.work_done

    def progress(self) -> float:
        wd = self._work_done_now()
        if self.task.kind == TaskKind.MAP:
            return wd / self.work_total
        n_deps = max(1, len(self.task.deps))
        n_fetched = len(self.shuffle.fetched) if self.shuffle else 0
        shuffle = n_fetched / n_deps
        compute = wd / self.work_total
        return _SHUFFLE_FRAC * shuffle + (1 - _SHUFFLE_FRAC) * compute

    def view(self) -> AttemptView:
        return AttemptView(
            attempt_id=self.attempt_id, task_id=self.task.task_id,
            node_id=self.node_id, state=self.state,
            start_time=self.start_time, progress=self.progress(),
            is_speculative=self.is_speculative,
            is_rollback=self.is_rollback)


class SimTask:
    def __init__(self, sim: "Simulation", job: "SimJob", kind: TaskKind,
                 index: int, work_seconds: float,
                 deps: Tuple[str, ...] = ()):
        self.sim = sim
        self.job = job
        self.kind = kind
        self.index = index
        # Global creation order — the canonical sort key of the columnar
        # rows (matches the reference snapshot's task iteration order).
        self.order = next(sim._task_seq)
        self.task_id = f"{job.spec.job_id}_{kind.value}{index:04d}"
        self.work_seconds = work_seconds
        self.deps = deps
        self._dep_pos: Optional[Dict[str, int]] = None
        self.state = TaskState.PENDING
        self.attempts: List[SimAttempt] = []
        self.output_nodes: List[str] = []
        self.output_available = False
        self.first_start: Optional[float] = None
        self.completed_at: Optional[float] = None
        # AM-side fetch-failure reports against this producer.
        self.fetch_reports = 0
        # One-shot injected disk exception: (progress_fraction,) or None.
        self.inject_disk_exception_at: Optional[float] = None

    @property
    def dep_pos(self) -> Dict[str, int]:
        """Producer task_id → dependency index, shared by every attempt."""
        if self._dep_pos is None:
            self._dep_pos = {m: i for i, m in enumerate(self.deps)}
        return self._dep_pos

    def running_attempts(self) -> List[SimAttempt]:
        return [a for a in self.attempts if a.state == AttemptState.RUNNING]

    def view(self) -> TaskView:
        return TaskView(
            task_id=self.task_id, job_id=self.job.spec.job_id,
            kind=self.kind, state=self.state,
            attempts=[a.view() for a in self.attempts],
            deps=self.deps, output_nodes=tuple(self.output_nodes),
            output_available=self.output_available)


class SimJob:
    def __init__(self, sim: "Simulation", spec: JobSpec):
        self.sim = sim
        self.spec = spec
        self.maps: List[SimTask] = []
        self.reduces: List[SimTask] = []
        self.reduces_scheduled = False
        self.done = False
        self.result: Optional[JobResult] = None
        self.n_spec_attempts = 0
        self.n_attempts = 0
        self.n_fetch_failures = 0
        # COMPLETED map-task count, maintained at the three task-state
        # flip sites (first completion, re-activation of a completed
        # producer in Dispatcher.enqueue / _apply_speculate) so slowstart
        # and the fault triggers stop recounting the map list; verified
        # against a recount in verify_arrays.
        self.n_maps_done = 0
        # Map-progress triggers for fault injection (fraction → callbacks).
        self.map_progress_triggers: List[Tuple[float, Callable]] = []

    @property
    def tasks(self) -> List[SimTask]:
        return self.maps + self.reduces

    def maps_completed(self) -> int:
        return self.n_maps_done

    def map_phase_progress(self) -> float:
        if not self.maps:
            return 1.0
        total = 0.0
        for t in self.maps:
            if t.state == TaskState.COMPLETED:
                total += 1.0
            elif t.running_attempts():
                total += max(a.progress() for a in t.running_attempts())
        return total / len(self.maps)


class _LazyTasks(_Mapping):
    """Materializes ``TaskView`` objects one key at a time.

    The vectorized policies read ``snap.arrays`` and touch this mapping
    only for the rare straggler/dependency cases, so a healthy assessment
    tick allocates no views at all; the per-object reference policies can
    still iterate it and see exactly the eager snapshot (same key order:
    active jobs in submission order, each job's maps then reduces)."""

    def __init__(self, sim: "Simulation"):
        self._sim = sim
        self._cache: Dict[str, TaskView] = {}
        self._keys: Optional[List[str]] = None

    def __getitem__(self, task_id: str) -> TaskView:
        v = self._cache.get(task_id)
        if v is None:
            t = self._sim._task_index.get(task_id)
            if t is None or t.job.spec.job_id not in self._sim.active_jobs:
                raise KeyError(task_id)
            v = t.view()
            self._cache[task_id] = v
        return v

    def _key_list(self) -> List[str]:
        if self._keys is None:
            self._keys = [t.task_id
                          for job in self._sim.active_jobs.values()
                          for t in job.tasks]
        return self._keys

    def __iter__(self):
        return iter(self._key_list())

    def __len__(self) -> int:
        return len(self._key_list())


class _LazyNodes(_Mapping):
    def __init__(self, sim: "Simulation"):
        self._sim = sim
        self._cache: Dict[str, NodeView] = {}

    def __getitem__(self, node_id: str) -> NodeView:
        v = self._cache.get(node_id)
        if v is None:
            n = self._sim.cluster.nodes[node_id]
            v = NodeView(
                node_id=node_id, last_heartbeat=n.last_heartbeat,
                total_containers=n.n_containers,
                free_containers=n.free_containers,
                marked_failed=node_id in self._sim._marked_failed)
            self._cache[node_id] = v
        return v

    def __iter__(self):
        return iter(self._sim.cluster.node_ids)

    def __len__(self) -> int:
        return len(self._sim.cluster.node_ids)


class Simulation:
    """One cluster + one speculation policy + any number of jobs.

    ``columnar=True`` (the default) maintains an incremental
    :class:`~repro.core.arrays.ArraySnapshot` mirror of attempt/node state
    and hands the policies lazy snapshots, activating their vectorized
    assessment paths; ``columnar=False`` rebuilds eager per-object
    snapshots each tick — the reference path the equivalence tests compare
    against. ``shuffle="batch"`` (the default) selects the macro-event
    fetch plane — the indexed ready-queue substrate with fetch timers
    coalesced into the engine's calendar lane (DESIGN.md §14);
    ``shuffle="event"`` the PR 2 per-event substrate; ``shuffle="rescan"``
    the seed's poll-and-rescan reference. All three emit byte-identical
    traces (DESIGN.md §12.3/§14.3, fuzzed in
    tests/test_fuzz_equivalence.py).
    ``assess_backend`` selects the assessment-compute backend for the
    vectorized policies ("numpy" default, "jax", "pallas" — DESIGN.md
    §13). ``net`` selects the network model ("flat" default: the
    seed-exact quasi-static per-NIC share; "topo": rack-aware with
    oversubscribed uplinks; "fair": batched ε-fair flows re-solved per
    BatchQueue drain — DESIGN.md §15), with ``racks``/``net_opts``
    parameterizing it. ``record_actions=True`` keeps the policy-action
    rail (read back lazily via the ``action_trace`` property) for those
    comparisons; ``obs=TraceRecorder(...)`` additionally wires the
    flight recorder through every subsystem emit site (DESIGN.md §18) —
    glance verdicts with their Eq. 1–4 inputs, attempt lifecycle, drain
    brackets, flow events, fault injections."""

    def __init__(self, *, policy: str = "yarn",
                 policy_factory: Optional[Callable[[Sequence[str]], Speculator]] = None,
                 n_workers: int = 20, n_containers: int = 8,
                 params: Optional[SimParams] = None, seed: int = 0,
                 columnar: bool = True, shuffle: str = "batch",
                 assess_backend: Optional[str] = None,
                 net: object = "flat", racks: int = 0,
                 net_opts: Optional[Dict] = None,
                 dispatch_opts: Optional[Dict] = None,
                 record_actions: bool = False,
                 obs: Optional[TraceRecorder] = None):
        self.engine = Engine()
        # Pluggable network substrate (DESIGN.md §15): "flat" is the
        # seed-exact default; "topo"/"fair" add rack topology and the
        # batched ε-fair flow model. ``racks``/``net_opts`` parameterize
        # the named models; a NetworkModel instance passes through.
        self.cluster = Cluster(
            n_workers, n_containers,
            network=make_network(net, racks=racks, **(net_opts or {})))
        # Nodes whose network link is currently cut (link_cut_at /
        # rack_partition_at) — shared with the MOF registry so cut
        # sources drop out of every engine's candidate scan. Overlapping
        # cut windows union via a per-node depth counter; ``_cut_hb``
        # records the heartbeat-suppression window the active cut owns
        # (so healing never cancels a foreign outage's window).
        self._link_down: Set[str] = set()
        self._cut_depth: Dict[str, int] = {}
        self._cut_hb: Dict[str, float] = {}
        # Active uplink-degrade windows per rack: list of (end, factor);
        # the effective factor is the min over live windows (the
        # strongest degrade), maintained by faults.rack_switch_degrade_at.
        self._degrade_windows: Dict[int, List[Tuple[float, float]]] = {}
        self.rng = np.random.default_rng(seed)
        self.policy_name = policy
        self._attempt_seq = itertools.count()
        self._task_seq = itertools.count()
        self._task_index: Dict[str, SimTask] = {}
        self.arrays: Optional[ArraySnapshot] = (
            ArraySnapshot(self.cluster.node_ids, n_containers)
            if columnar else None)
        if self.arrays is not None:
            self.arrays.init_net(self.cluster.net)
        self.record_actions = record_actions
        # Flight recorder (DESIGN.md §18). An explicitly-passed recorder
        # is wired through every subsystem emit site after construction;
        # record_actions=True alone gets a private actions-only recorder
        # backing the lazy ``action_trace`` property (the seed's
        # unbounded repr-string list is retired — reprs materialize only
        # when an equivalence test reads the property).
        self.obs = obs
        self._act_rec = obs
        if obs is None and record_actions:
            self._act_rec = TraceRecorder()
        if self._act_rec is not None:
            self._act_rec.time_fn = lambda: self.engine.now
        # Assessment-path profiling (benchmarks/perf_scale.py).
        self.assess_ticks = 0
        self.assess_wall = 0.0
        self.actions_emitted = 0
        if params is None:
            params = BINO_PARAMS if policy == "bino" else SimParams()
        self.params = params
        self.assess_backend = assess_backend
        if policy_factory is not None:
            self.speculator = policy_factory(self.cluster.node_ids)
        elif policy == "bino":
            self.speculator = BinocularSpeculator(
                self.cluster.node_ids, assess_backend=assess_backend)
        elif policy == "budgeted":
            # Cross-job speculation under a cluster-wide slot budget
            # (Xu & Lau admission — DESIGN.md §19.3).
            from repro.core.speculator import BudgetedSpeculator
            self.speculator = BudgetedSpeculator(
                total_slots=n_workers * n_containers,
                assess_backend=assess_backend)
        elif policy == "clone":
            # Upfront cloning for small jobs, LATE for the rest
            # (Xu & Lau task-cloning — DESIGN.md §19.3).
            from repro.core.speculator import CloneSmallJobs
            self.speculator = CloneSmallJobs(
                total_slots=n_workers * n_containers,
                assess_backend=assess_backend)
        elif policy == "predictor":
            # Learned straggler nomination over the columnar mirror
            # (DESIGN.md §20); untrained default params degenerate to
            # reap + silent-window failure detection.
            if self.arrays is None:
                raise ValueError(
                    "policy='predictor' requires columnar=True "
                    "(features live in the ArraySnapshot mirror)")
            from repro.predict.policy import PredictorPolicy
            self.speculator = PredictorPolicy(
                self.cluster.node_ids,
                total_slots=n_workers * n_containers,
                assess_backend=assess_backend)
        else:
            from repro.core.speculator import YarnLateSpeculator
            self.speculator = YarnLateSpeculator(
                assess_backend=assess_backend)
        self.jobs: Dict[str, SimJob] = {}
        self.active_jobs: Dict[str, SimJob] = {}
        self.sched = Dispatcher(self, **(dispatch_opts or {}))
        self.shuffle = make_engine(self, shuffle)
        self.attempts: Dict[str, SimAttempt] = {}
        self._fetch_failures: List[FetchFailure] = []
        self._marked_failed: Set[str] = set()
        self.results: List[JobResult] = []
        # ground truth for the Fig. 7(b) accuracy metric
        self.truth_crashed: Set[str] = set()
        self.policy_failed_calls: List[Tuple[float, str]] = []
        self._started = False
        if obs is not None:
            self._wire_obs(obs)

    def _wire_obs(self, rec: TraceRecorder) -> None:
        """Thread the flight recorder through every subsystem emit site
        (DESIGN.md §18.2). Each site pays one ``is not None`` branch when
        a recorder is absent; nothing else changes — the obs-on ≡ obs-off
        byte-identity gate in tests/test_obs.py pins that."""
        rec.time_fn = lambda: self.engine.now
        self.cluster.net.obs = rec
        sp = self.speculator
        sp.obs = rec
        glance = getattr(sp, "glance", None)
        if glance is not None:
            glance.obs = rec
        coll = getattr(sp, "collective", None)
        if coll is not None:
            coll.obs = rec
        lane = getattr(self.shuffle, "batches", None)
        if lane is not None:
            lane.obs = rec

    @property
    def action_trace(self) -> List[Tuple[float, str]]:
        """Lazy ``(time, repr(action))`` materialization from the
        recorder's action rail — read by the trace-equivalence tests;
        empty unless ``record_actions`` (or an ``obs`` recorder) was
        requested."""
        if self._act_rec is None:
            return []
        return [(t, repr(a)) for t, a in self._act_rec.actions()]

    @property
    def pending(self) -> List[LaunchRequest]:
        return self.sched.pending

    # ------------------------------------------------------------------
    # Lifecycle
    # ------------------------------------------------------------------
    # --- columnar write-through helpers --------------------------------
    def _arr_task_state(self, task: "SimTask") -> None:
        arr = self.arrays
        if arr is not None and task.attempts:
            arr.set_task_state([a.row for a in task.attempts], task.state)

    def _arr_node_free(self, node_id: str) -> None:
        # Free-slot count changed (either direction): refresh the columnar
        # mirror and re-arm the cluster's free-container index.
        self.cluster.note_free(node_id)
        arr = self.arrays
        if arr is not None:
            arr.node_free[arr.node_index[node_id]] = \
                self.cluster.nodes[node_id].free_containers

    def _arr_node_supp(self, node_id: str) -> None:
        # Heartbeat-suppression window changed: refresh the columnar
        # mirror the vectorized RM tick masks against.
        arr = self.arrays
        if arr is not None:
            arr.node_supp[arr.node_index[node_id]] = \
                self.cluster.nodes[node_id].hb_suppressed_until

    def _start_background(self) -> None:
        if self._started:
            return
        self._started = True
        for nid in self.cluster.node_ids:
            self.cluster.nodes[nid].last_heartbeat = self.engine.now
        if self.arrays is not None:
            self.arrays.node_hb[:] = self.engine.now
        # Heartbeat/expiry are high-volume fixed-rate ticks: the shuffle
        # engine decides whether they live on the heap or in the calendar
        # lane (batch mode folds them into the lane as typed records —
        # DESIGN.md §17). The speculator stays on the heap: its actions
        # can complete attempts and flip run(stop=...), which lane
        # records must never do.
        self.shuffle.schedule_tick(self.params.heartbeat, TICK_HB)
        self.engine.after(self.params.spec_interval, self._speculator_tick)
        self.shuffle.schedule_tick(self.params.expiry_check, TICK_EXPIRY)

    def submit(self, spec: JobSpec) -> SimJob:
        job = SimJob(self, spec)
        self.jobs[spec.job_id] = job
        self.engine.at(spec.submit_time, self._launch_job, job)
        return job

    def _launch_job(self, job: SimJob) -> None:
        self._start_background()
        self.active_jobs[job.spec.job_id] = job
        if self.arrays is not None:
            jidx = self.arrays.job_started(job.spec.job_id)
        for i in range(job.spec.n_maps):
            t = SimTask(self, job, TaskKind.MAP, i,
                        job.spec.map_work_seconds())
            job.maps.append(t)
        map_ids = tuple(t.task_id for t in job.maps)
        for i in range(job.spec.reduces):
            t = SimTask(self, job, TaskKind.REDUCE, i,
                        job.spec.reduce_work_seconds(), deps=map_ids)
            job.reduces.append(t)
        for t in job.tasks:
            self._task_index[t.task_id] = t
            if self.arrays is not None:
                self.arrays.task_created(jidx)
        def go():
            for t in job.maps:
                self._enqueue(LaunchRequest(t))
            self._dispatch()
        # AM container negotiation + startup before the first task launches
        self.engine.after(self.params.am_startup, go)

    def run(self) -> List[JobResult]:
        self.engine.run(until=self.params.sim_time_cap,
                        stop=lambda: not self.active_jobs and
                        len(self.results) == len(self.jobs))
        return self.results

    # ------------------------------------------------------------------
    # Scheduling (decisions live in repro.sim.dispatch)
    # ------------------------------------------------------------------
    def _enqueue(self, req: LaunchRequest) -> None:
        self.sched.enqueue(req)

    def _dispatch(self) -> None:
        self.sched.dispatch()

    def _start_attempt(self, req: LaunchRequest, node_id: str) -> None:
        task = req.task
        offset = 0.0
        rollback = False
        if req.rollback and req.rollback_node == node_id:
            node = self.cluster.nodes[node_id]
            offset = node.spill_logs.get(task.task_id, 0.0)
            rollback = offset > 0.0
        a = SimAttempt(self, task, node_id, speculative=req.speculative,
                       rollback=rollback, start_offset=offset)
        if task.kind == TaskKind.MAP and task.inject_disk_exception_at is not None:
            a.disk_exception_at = task.inject_disk_exception_at
            task.inject_disk_exception_at = None  # one-shot
        task.attempts.append(a)
        self.attempts[a.attempt_id] = a
        if task.state == TaskState.PENDING:
            task.state = TaskState.RUNNING
        if task.first_start is None:
            task.first_start = self.engine.now
        task.job.n_attempts += 1
        if req.speculative:
            task.job.n_spec_attempts += 1
        self.cluster.nodes[node_id].busy.add(a.attempt_id)
        if self.obs is not None:
            self.obs.emit(
                K_ATT_START, a=self.cluster._node_pos[node_id],
                b=(1 if req.speculative else 0) | (2 if rollback else 0),
                obj=a.attempt_id)
        arr = self.arrays
        if arr is not None:
            a.row = arr.add_attempt(
                a, a.attempt_id, task.task_id, task.order,
                len(task.attempts) - 1,
                arr.job_index[task.job.spec.job_id],
                arr.node_index[node_id], task.kind, a.is_speculative,
                a.start_time, a.work_done, a.work_total,
                len(task.deps), task.state)
            self._arr_task_state(task)
            self._arr_node_free(node_id)
        if task.kind == TaskKind.MAP:
            self._schedule_map_milestone(a)
        else:
            self.shuffle.attach(a)
            self.shuffle.try_start(a)

    # ------------------------------------------------------------------
    # Map execution: spill milestones, disk exceptions, completion
    # ------------------------------------------------------------------
    def _map_milestones(self, a: SimAttempt) -> List[Tuple[float, str]]:
        cache = a._milestones_cache
        if cache is not None and cache[0] == a.disk_exception_at:
            return cache[1]
        n = a.task.job.spec.n_spills
        pts = [(k / n, "spill") for k in range(1, n)]
        if a.disk_exception_at is not None:
            pts.append((a.disk_exception_at, "disk_exception"))
        pts.append((1.0, "complete"))
        pts.sort()
        a._milestones_cache = (a.disk_exception_at, pts)
        return pts

    def _cancel_timer(self, a: SimAttempt) -> None:
        """Cancel an attempt's pending milestone/completion timer. The
        timer is either a heap EventHandle or (batch-mode map milestones)
        an int lane token — lane cancellation is just forgetting the
        token; the record's applier drops it as stale."""
        h = a._milestone
        if h is not None:
            a._milestone = None
            if type(h) is not int:
                h.cancel()

    def _schedule_map_milestone(self, a: SimAttempt) -> None:
        self._cancel_timer(a)
        if a.state != AttemptState.RUNNING:
            return
        a.sync()
        speed = a.node.speed
        if speed <= 0.0:
            return  # frozen; node death/expiry will clean up
        frac_done = a.work_done / a.work_total
        pts = self._map_milestones(a)
        for idx, (frac, kind) in enumerate(pts):
            if frac > frac_done + 1e-12:
                dt = (frac * a.work_total - a.work_done) / speed
                a._milestone = self.shuffle.schedule_milestone(
                    a, dt, idx, frac, kind)
                return
        # everything already passed (e.g. rollback at 100%): complete now
        a._milestone = self.shuffle.schedule_milestone(
            a, 0.0, pts.index((1.0, "complete")), 1.0, "complete")

    def _map_milestone_fired_idx(self, a: SimAttempt, idx: int) -> None:
        """Lane-record entry point: the record carries the ladder index;
        resolve it against the (cached, stable for a fixed
        disk_exception_at) milestone list."""
        frac, kind = self._map_milestones(a)[idx]
        self._map_milestone_fired(a, frac, kind)

    def _map_milestone_fired(self, a: SimAttempt, frac: float, kind: str) -> None:
        if a.state != AttemptState.RUNNING:
            return
        a.sync()
        if a.work_done + 1e-9 < frac * a.work_total:
            # node slowed down since this event was scheduled; recompute
            self._schedule_map_milestone(a)
            return
        a.work_done = max(a.work_done, frac * a.work_total)
        if a.row >= 0:
            self.arrays.sync_row(a.row, a.work_done, a.last_sync)
        if kind == "spill":
            a.node.spill_logs[a.task.task_id] = max(
                a.node.spill_logs.get(a.task.task_id, 0.0), frac)
            if isinstance(self.speculator, BinocularSpeculator):
                self.speculator.record_progress_log(ProgressLog(
                    task_id=a.task.task_id, node_id=a.node_id, offset=frac))
            self._schedule_map_milestone(a)
        elif kind == "disk_exception":
            self._attempt_failed(a, reason="disk_exception")
        else:
            self._map_completed(a)

    def _obs_att_end(self, a: SimAttempt, code: int) -> None:
        # _work_done_now() is the pure read: the emit must not perturb
        # float state (obs-on/off byte identity, §18.2).
        self.obs.emit(
            K_ATT_END, a=self.cluster._node_pos[a.node_id], b=code,
            f0=a.start_time, f1=a._work_done_now(),
            f2=1.0 if a.is_speculative else 0.0, obj=a.attempt_id)

    def _map_completed(self, a: SimAttempt) -> None:
        task = a.task
        a.state = AttemptState.COMPLETED
        a.end_time = self.engine.now
        if self.obs is not None:
            self._obs_att_end(a, END_COMPLETED)
        a.node.busy.discard(a.attempt_id)
        self._arr_node_free(a.node_id)
        a.node.mofs[task.task_id] = task.job.spec.mof_bytes()
        if a.node_id not in task.output_nodes:
            task.output_nodes.append(a.node_id)
        first_completion = task.state != TaskState.COMPLETED
        if first_completion:
            task.job.n_maps_done += 1
        task.state = TaskState.COMPLETED
        task.output_available = True
        task.fetch_reports = 0
        if task.completed_at is None:
            task.completed_at = self.engine.now
        if a.row >= 0:
            self.arrays.set_attempt_state(a.row, a.state)
            self._arr_task_state(task)
        self._kill_siblings(task, keep=a.attempt_id)
        self.sched.task_done(task)
        # fresh MOF: register the source and notify waiting fetchers
        self.shuffle.on_producer_completed(task, a.node_id)
        if first_completion:
            self._maybe_schedule_reduces(task.job)
            self._check_map_progress_triggers(task.job)
        self._dispatch()

    # ------------------------------------------------------------------
    # Reduce execution: AM-side shuffle hooks, compute
    # (fetch mechanics live in repro.sim.shuffle)
    # ------------------------------------------------------------------
    def _maybe_schedule_reduces(self, job: SimJob) -> None:
        if job.reduces_scheduled or not job.reduces:
            return
        frac = job.maps_completed() / max(1, len(job.maps))
        if frac + 1e-12 >= self.params.slowstart:
            job.reduces_scheduled = True
            for t in job.reduces:
                self._enqueue(LaunchRequest(t))
            self._dispatch()

    def _report_fetch_failure(self, a: SimAttempt, m: str) -> None:
        """A reduce attempt burned a fetch cycle against producer ``m``:
        record it and, past Hadoop's too-many-fetch-failures quorum, give
        up on the MOF and re-run the map."""
        a.task.job.n_fetch_failures += 1
        prod = self._task(m)
        self._fetch_failures.append(FetchFailure(
            time=self.engine.now, consumer_task_id=a.task.task_id,
            producer_task_id=m))
        if prod is not None:
            prod.fetch_reports += 1
            running_reduces = sum(
                1 for t in a.task.job.reduces
                if t.state == TaskState.RUNNING)
            quorum = max(self.params.am_fetch_threshold,
                         int(self.params.am_fetch_quorum * running_reduces))
            if prod.fetch_reports >= quorum and not prod.running_attempts():
                # AM finally gives up on the MOF and re-runs the map.
                prod.fetch_reports = 0
                self._enqueue(LaunchRequest(prod, reason="am-fetch-failures"))
                self._dispatch()

    def _start_compute(self, a: SimAttempt) -> None:
        a.compute_started = True
        a.last_sync = self.engine.now
        if a.row >= 0:
            self.arrays.compute[a.row] = True
            self.arrays.sync_row(a.row, a.work_done, a.last_sync)
        self._schedule_reduce_completion(a)

    def _schedule_reduce_completion(self, a: SimAttempt) -> None:
        # Reduce completions stay on the heap in every mode: completing
        # the last reduce flips run(stop=...), which lane records must
        # never do (BatchQueue contract).
        self._cancel_timer(a)
        if a.state != AttemptState.RUNNING or not a.compute_started:
            return
        a.sync()
        speed = a.node.speed
        if speed <= 0.0:
            return
        dt = (a.work_total - a.work_done) / speed
        a._milestone = self.engine.after(dt, self._reduce_completed, a)

    def _reduce_completed(self, a: SimAttempt) -> None:
        if a.state != AttemptState.RUNNING:
            return
        a.sync()
        if a.work_done < a.work_total - 1e-9:
            self._schedule_reduce_completion(a)
            return
        task = a.task
        a.state = AttemptState.COMPLETED
        a.end_time = self.engine.now
        if self.obs is not None:
            self._obs_att_end(a, END_COMPLETED)
        a.node.busy.discard(a.attempt_id)
        self._arr_node_free(a.node_id)
        task.state = TaskState.COMPLETED
        if task.completed_at is None:
            task.completed_at = self.engine.now
        if a.row >= 0:
            self.arrays.set_attempt_state(a.row, a.state)
            self._arr_task_state(task)
        self._kill_siblings(task, keep=a.attempt_id)
        self.sched.task_done(task)
        self._check_job_done(task.job)
        self._dispatch()

    # ------------------------------------------------------------------
    # Failure/kill handling
    # ------------------------------------------------------------------
    def _attempt_failed(self, a: SimAttempt, reason: str) -> None:
        if a.state != AttemptState.RUNNING:
            return
        if self.obs is not None:
            self._obs_att_end(a, END_FAILED)
        a.state = AttemptState.FAILED
        a.end_time = self.engine.now
        if a.row >= 0:
            self.arrays.set_attempt_state(a.row, a.state)
        self._teardown_attempt(a)
        task = a.task
        if task.state == TaskState.COMPLETED or task.job.done:
            return
        if not task.running_attempts():
            # AM failover: policy decides the recovery shape (rollback
            # race for Bino, plain re-attempt for YARN).
            for req in self._recovery_requests(task, a, reason):
                self._enqueue(req)
            self._dispatch()

    def _recovery_requests(self, task: SimTask, failed: SimAttempt,
                           reason: str) -> List[LaunchRequest]:
        node = self.cluster.nodes[failed.node_id]
        use_rollback = (
            isinstance(self.speculator, BinocularSpeculator)
            and self.speculator.cfg.rollback_enabled
            and task.kind == TaskKind.MAP
            and node.alive
            and failed.node_id not in self._marked_failed
            and node.spill_logs.get(task.task_id, 0.0) > 0.0)
        if use_rollback:
            if self.obs is not None:
                self.obs.emit(
                    K_ROLLBACK, a=self.cluster._node_pos[failed.node_id],
                    f0=node.spill_logs.get(task.task_id, 0.0),
                    obj=task.task_id)
            return [
                LaunchRequest(task, placement=(failed.node_id,),
                              rollback=True, rollback_node=failed.node_id,
                              reason=reason + "+rollback"),
                LaunchRequest(task, reason=reason),
            ]
        return [LaunchRequest(task, reason=reason)]

    def _kill_attempt(self, a: SimAttempt, reason: str = "") -> None:
        if a.state != AttemptState.RUNNING:
            return
        if self.obs is not None:
            self._obs_att_end(a, END_KILLED)
        a.state = AttemptState.KILLED
        a.end_time = self.engine.now
        if a.row >= 0:
            self.arrays.set_attempt_state(a.row, a.state)
        self._teardown_attempt(a)

    def _kill_siblings(self, task: SimTask, keep: str) -> None:
        for a in task.attempts:
            if a.attempt_id != keep:
                self._kill_attempt(a, "sibling completed")

    def _teardown_attempt(self, a: SimAttempt) -> None:
        a.node.busy.discard(a.attempt_id)
        self._arr_node_free(a.node_id)
        self._cancel_timer(a)
        self.shuffle.detach(a)

    # ------------------------------------------------------------------
    # Node lifecycle (RM view)
    # ------------------------------------------------------------------
    def node_lost(self, node_id: str, *, by_policy: bool = False) -> None:
        """RM declares a node dead (NM expiry or MarkNodeFailed action)."""
        if node_id in self._marked_failed:
            return
        self._marked_failed.add(node_id)
        if self.obs is not None:
            self.obs.emit(K_DETECT, a=self.cluster._node_pos[node_id],
                          b=1 if by_policy else 0)
        node = self.cluster.nodes[node_id]
        # Its MOF copies stop being fetchable the moment the RM marks it.
        self.shuffle.registry.drop_node_sources(node)
        if self.arrays is not None:
            self.arrays.node_marked[self.arrays.node_index[node_id]] = True
        if by_policy:
            self.policy_failed_calls.append((self.engine.now, node_id))
        # Running attempts there are gone.
        for a in list(self.attempts.values()):
            if a.node_id == node_id and a.state == AttemptState.RUNNING:
                self._attempt_failed(a, reason="node-lost")
            # In-flight fetches FROM the dead node fail over to a cycle.
            if a.state == AttemptState.RUNNING and a.shuffle is not None:
                for m, src in list(a.shuffle.fetch_srcs.items()):
                    if src == node_id:
                        self.shuffle.abort_fetch(a, m)
                        self.shuffle.try_start(a)
        # Completed maps whose only MOF copies lived there must re-run
        # (standard YARN on node expiry) — unless every reducer already
        # fetched that partition. The placement index yields exactly the
        # producers with an output copy here, in map creation order.
        reg = self.shuffle.registry
        for t in reg.take_placed(node_id):
            if t.state != TaskState.COMPLETED:
                reg.keep_placed(node_id, t)  # re-running; not YARN's case
                continue
            t.output_nodes = [n for n in t.output_nodes if n != node_id]
            if not t.output_nodes:
                t.output_available = False
                if self.shuffle.someone_still_needs(t) and \
                        not t.running_attempts():
                    self._enqueue(LaunchRequest(
                        t, reason="node-lost-mof"))
        node.mofs.clear()
        node.spill_logs.clear()
        if isinstance(self.speculator, BinocularSpeculator):
            self.speculator.rollback.drop_node(node_id)
        self._dispatch()

    def lose_mof(self, prod: SimTask) -> None:
        """Silently delete every copy of a completed map's MOF (disk-level
        loss; the node stays healthy). In-flight transfers of that
        partition abort; task bookkeeping still believes the output exists
        — only subsequent fetches discover the loss."""
        if self.obs is not None:
            self.obs.emit(K_FAULT, a=-1, b=FAULT_CODES["mof"],
                          obj=prod.task_id)
        for nid in list(prod.output_nodes):
            self.cluster.nodes[nid].mofs.pop(prod.task_id, None)
        self.shuffle.registry.drop_producer(prod.task_id)
        for a in list(self.attempts.values()):
            if a.state != AttemptState.RUNNING or a.shuffle is None \
                    or prod.task_id not in a.shuffle.inflight:
                continue
            self.shuffle.abort_fetch(a, prod.task_id)
            self.shuffle.try_start(a)  # rediscovers via a failure cycle

    def cut_link(self, node_id: str,
                 duration: Optional[float] = None) -> None:
        """Network link fault (DESIGN.md §15.5): the node keeps computing
        but its fetch paths and heartbeats are gone. In-flight transfers
        touching the node abort — consumers fall into failure cycles
        (the recovery machinery the paper studies) rather than stretching
        a transfer toward infinity — and its MOF copies leave every
        engine's candidate set until :meth:`restore_link`. Overlapping
        cut windows union: the link heals only when every window has
        been restored (depth counter), and heartbeat suppression only
        ever extends — a cut never shortens a window someone else
        (an outage, an earlier cut) already installed."""
        node = self.cluster.nodes[node_id]
        if self.obs is not None:
            self.obs.emit(K_FAULT, a=self.cluster._node_pos[node_id],
                          b=FAULT_CODES["cut"],
                          f0=duration if duration is not None else 0.0)
        target = (self.engine.now + duration if duration is not None
                  else float("inf"))
        if target > node.hb_suppressed_until:
            node.hb_suppressed_until = target
            self._arr_node_supp(node_id)
            # remember the window this cut owns so restore can tell it
            # apart from a foreign (outage-installed) window
            self._cut_hb[node_id] = target
        depth = self._cut_depth.get(node_id, 0)
        self._cut_depth[node_id] = depth + 1
        if depth:
            return  # already down: deepen the window, effects already ran
        self._link_down.add(node_id)
        self.cluster.net.cut(node_id)
        # Its MOF copies stop being fetchable while the link is down.
        self.shuffle.registry.drop_node_sources(node)
        # The cut host's own in-flight fetches stall out silently (same
        # shape as crash_node: no immediate retry — the next producer
        # completion in the job re-kicks the attempt).
        for a in self.attempts.values():
            if a.node_id == node_id and a.state == AttemptState.RUNNING \
                    and a.shuffle is not None and a.shuffle.inflight:
                for m in list(a.shuffle.inflight):
                    self.shuffle.abort_fetch(a, m)
                self.shuffle.mark_stalled(a)
        # Fetches streaming FROM the cut node stall into failure cycles.
        for a in self.attempts.values():
            if a.state != AttemptState.RUNNING or a.node_id == node_id \
                    or a.shuffle is None:
                continue
            for m, src in list(a.shuffle.fetch_srcs.items()):
                if src == node_id:
                    self.shuffle.abort_fetch(a, m)
                    self.shuffle.try_start(a)

    def restore_link(self, node_id: str) -> None:
        """One cut window ends: the link heals only once every
        overlapping window is restored. Heartbeats resume on the next
        RM tick — unless a foreign suppression (a heartbeat outage, or
        a longer window installed mid-cut) still owns the clock — and
        the node's surviving MOF copies rejoin the registry (waiting
        reducers rediscover them on their next failure-cycle retry —
        no eager notify, matching the reference scan's behavior)."""
        depth = self._cut_depth.get(node_id, 0)
        if depth == 0:
            return
        if depth > 1:
            self._cut_depth[node_id] = depth - 1
            return
        del self._cut_depth[node_id]
        self._link_down.discard(node_id)
        self.cluster.net.restore_link(node_id)
        node = self.cluster.nodes[node_id]
        owned = self._cut_hb.pop(node_id, None)
        if owned is not None and node.hb_suppressed_until == owned \
                and owned > self.engine.now:
            node.hb_suppressed_until = self.engine.now
            self._arr_node_supp(node_id)
        if node.alive:
            for task_id in node.mofs:
                t = self._task(task_id)
                if t is not None and t.state == TaskState.COMPLETED \
                        and node_id in t.output_nodes:
                    self.shuffle.registry.add(t, node_id)

    def set_node_speed(self, node_id: str, speed: float) -> None:
        """Sync every hosted attempt at the OLD speed, flip, reschedule."""
        node = self.cluster.nodes[node_id]
        if self.obs is not None and 0.0 < speed < 1.0:
            # A slowdown fault (crash emits its own record at speed 0;
            # restoring to 1.0 is recovery, not a fault).
            self.obs.emit(K_FAULT, a=self.cluster._node_pos[node_id],
                          b=FAULT_CODES["slow"], f0=speed)
        hosted = [a for a in self.attempts.values()
                  if a.node_id == node_id and a.state == AttemptState.RUNNING]
        for a in hosted:
            a.sync()
        node.speed = speed
        if self.arrays is not None:
            self.arrays.node_speed[self.arrays.node_index[node_id]] = speed
        for a in hosted:
            if a.task.kind == TaskKind.MAP:
                self._schedule_map_milestone(a)
            elif a.compute_started:
                self._schedule_reduce_completion(a)

    def crash_node(self, node_id: str) -> None:
        """Ground-truth crash: heartbeats stop, disk contents gone.
        Attempts keep their frozen progress; RM/policy must DISCOVER the
        death (that discovery latency is the paper's whole subject)."""
        node = self.cluster.nodes[node_id]
        if self.obs is not None:
            self.obs.emit(K_FAULT, a=self.cluster._node_pos[node_id],
                          b=FAULT_CODES["crash"])
        self.truth_crashed.add(node_id)
        self.set_node_speed(node_id, 0.0)
        self.shuffle.registry.drop_node_sources(node)
        node.fail()
        if self.arrays is not None:
            self.arrays.node_alive[self.arrays.node_index[node_id]] = False
        self._arr_node_free(node_id)
        # The crashed host's own in-flight fetches stall out silently: no
        # immediate retry — the next producer completion in the job
        # re-kicks the attempt (mark_stalled keeps the event engine's
        # notification set equal to the rescan broadcast here).
        for a in self.attempts.values():
            if a.node_id == node_id and a.state == AttemptState.RUNNING \
                    and a.shuffle is not None and a.shuffle.inflight:
                for m in list(a.shuffle.inflight):
                    self.shuffle.abort_fetch(a, m)
                self.shuffle.mark_stalled(a)
        # Fetches streaming FROM the crashed node stall into failure cycles.
        for a in self.attempts.values():
            if a.state != AttemptState.RUNNING or a.node_id == node_id \
                    or a.shuffle is None:
                continue
            for m, src in list(a.shuffle.fetch_srcs.items()):
                if src == node_id:
                    self.shuffle.abort_fetch(a, m)
                    self.shuffle.try_start(a)

    def restore_node(self, node_id: str) -> None:
        node = self.cluster.nodes[node_id]
        # Whatever was running there is long gone.
        for a in list(self.attempts.values()):
            if a.node_id == node_id and a.state == AttemptState.RUNNING:
                self._attempt_failed(a, reason="node-restarted")
        node.restore()
        node.last_heartbeat = self.engine.now
        self.cluster.net.node_reset(node_id)
        self.cluster.note_free(node_id)
        self._marked_failed.discard(node_id)
        self.truth_crashed.discard(node_id)
        if self.arrays is not None:
            i = self.arrays.node_index[node_id]
            self.arrays.node_speed[i] = node.speed
            self.arrays.node_hb[i] = node.last_heartbeat
            self.arrays.node_marked[i] = False
            self.arrays.node_alive[i] = True
            self.arrays.node_free[i] = node.free_containers
        if hasattr(self.speculator, "glance"):
            self.speculator.glance.reset_node(node_id)
        self._dispatch()

    # ------------------------------------------------------------------
    # Background ticks
    # ------------------------------------------------------------------
    def _heartbeat_tick(self) -> None:
        now = self.engine.now
        arr = self.arrays
        marked = self._marked_failed
        if arr is not None and not marked:
            # Vectorized RM tick (DESIGN.md §17.5): the all-healthy
            # common case is one mask over the liveness/suppression
            # mirrors; only the heartbeating rows' python attrs sync.
            idx = np.flatnonzero(arr.node_alive & (arr.node_supp <= now))
            arr.node_hb[idx] = now
            nodes = self.cluster.nodes
            ids = self.cluster.node_ids
            for i in idx.tolist():
                nodes[ids[i]].last_heartbeat = now
        else:
            # Reference loop: no columnar mirror, or a misjudged-dead
            # node whose rejoin needs the per-node ``marked`` check.
            hb = arr.node_hb if arr is not None else None
            for i, node in enumerate(self.cluster.nodes.values()):
                if node.alive and now >= node.hb_suppressed_until:
                    node.last_heartbeat = now
                    if hb is not None:
                        hb[i] = now
                    if marked and node.node_id in marked:
                        # transient outage misjudged as failure: NM rejoins
                        marked.discard(node.node_id)
                        if arr is not None:
                            arr.node_marked[i] = False
        if self.active_jobs or len(self.results) < len(self.jobs):
            self.shuffle.schedule_tick(self.params.heartbeat, TICK_HB)

    def _expiry_tick(self) -> None:
        now = self.engine.now
        arr = self.arrays
        if arr is not None:
            # Columnar fast path: ``node_hb`` mirrors every node's
            # last_heartbeat, so the common all-healthy tick is one
            # vectorized comparison; stale rows fall back to the exact
            # per-node checks in index (= dict) order.
            stale = np.flatnonzero(now - arr.node_hb > self.params.nm_expiry)
            nodes = [self.cluster.node_ids[i] for i in stale]
        else:
            nodes = self.cluster.nodes
        for nid in nodes:
            node = self.cluster.nodes[nid]
            if node.node_id in self._marked_failed:
                continue
            if now - node.last_heartbeat > self.params.nm_expiry:
                self.node_lost(node.node_id)
        if self.active_jobs or len(self.results) < len(self.jobs):
            self.shuffle.schedule_tick(self.params.expiry_check, TICK_EXPIRY)

    def _speculator_tick(self) -> None:
        self.sched.watchdog()
        t0 = time.perf_counter()
        snap = self._snapshot()
        actions = self.speculator.assess(snap)
        self.assess_wall += time.perf_counter() - t0
        self.assess_ticks += 1
        self.actions_emitted += len(actions)
        rec = self._act_rec
        if rec is not None and actions:
            pos = self.cluster._node_pos
            for act in actions:
                if isinstance(act, MarkNodeFailed):
                    code, nid = ACT_MARK_FAILED, act.node_id
                elif isinstance(act, SpeculateTask):
                    code, nid = ACT_SPECULATE, self._spec_victim(act)
                else:
                    code = ACT_KILL
                    att = self.attempts.get(act.attempt_id)
                    nid = att.node_id if att is not None else None
                rec.emit(K_ACTION, a=pos.get(nid, -1), b=code, obj=act)
        self._fetch_failures.clear()
        for act in actions:
            if isinstance(act, MarkNodeFailed):
                self.node_lost(act.node_id, by_policy=True)
            elif isinstance(act, KillAttempt):
                a = self.attempts.get(act.attempt_id)
                if a is not None:
                    self._kill_attempt(a, act.reason)
            elif isinstance(act, SpeculateTask):
                self._apply_speculate(act)
        self._dispatch()
        if self.active_jobs or len(self.results) < len(self.jobs):
            self.engine.after(self.params.spec_interval,
                              self._speculator_tick)

    def _spec_victim(self, act: SpeculateTask) -> Optional[str]:
        """Node a SpeculateTask implicates: where the task's current
        attempt runs (trace labeling only — never feeds decisions)."""
        task = self._task(act.task_id)
        if task is None:
            return None
        running = task.running_attempts()
        return running[0].node_id if running else None

    def _apply_speculate(self, act: SpeculateTask) -> None:
        task = self._task(act.task_id)
        if task is None or task.job.done:
            return
        if self.sched.has_queued(task):
            return  # a launch for this task is already queued
        if task.state == TaskState.COMPLETED:
            # dependency-aware re-execution of a completed producer;
            # both outputs are kept until job completion (§III.B).
            if task.running_attempts():
                return
            if task.kind == TaskKind.MAP:
                task.job.n_maps_done -= 1
            task.state = TaskState.RUNNING
            self._arr_task_state(task)
            self._enqueue(LaunchRequest(
                task, placement=act.placement_hint, reason=act.reason))
            return
        if len(task.running_attempts()) >= self.params.max_running_attempts:
            return
        self._enqueue(LaunchRequest(
            task, placement=act.placement_hint, speculative=True,
            rollback=act.rollback, rollback_node=act.rollback_node,
            reason=act.reason))

    # ------------------------------------------------------------------
    # Snapshot + bookkeeping
    # ------------------------------------------------------------------
    def _task(self, task_id: str) -> Optional[SimTask]:
        return self._task_index.get(task_id)

    def _snapshot(self) -> ClusterSnapshot:
        if self.arrays is not None:
            # Columnar tick: the policies read the incrementally-maintained
            # arrays; the mappings materialize per-object views only if a
            # (rare) straggler/dependency path actually touches them.
            return ClusterSnapshot(
                now=self.engine.now, nodes=_LazyNodes(self),
                tasks=_LazyTasks(self),
                fetch_failures=tuple(self._fetch_failures),
                arrays=self.arrays)
        nodes = {}
        for nid, n in self.cluster.nodes.items():
            nodes[nid] = NodeView(
                node_id=nid, last_heartbeat=n.last_heartbeat,
                total_containers=n.n_containers,
                free_containers=n.free_containers,
                marked_failed=nid in self._marked_failed)
        tasks = {}
        for job in self.active_jobs.values():
            for t in job.tasks:
                tasks[t.task_id] = t.view()
        return ClusterSnapshot(
            now=self.engine.now, nodes=nodes, tasks=tasks,
            fetch_failures=tuple(self._fetch_failures))

    def verify_arrays(self) -> None:
        """Assert the incrementally-maintained columns equal a from-scratch
        rebuild from the object state (the equivalence gate's second half;
        tests call this mid-run after each event type)."""
        arr = self.arrays
        assert arr is not None, "simulation runs without columnar mirror"
        from repro.core.arrays import ASTATE, KIND, TSTATE
        for i, nid in enumerate(self.cluster.node_ids):
            node = self.cluster.nodes[nid]
            assert arr.node_hb[i] == node.last_heartbeat, nid
            assert arr.node_speed[i] == node.speed, nid
            assert arr.node_free[i] == node.free_containers, nid
            assert bool(arr.node_marked[i]) == (nid in self._marked_failed), nid
            assert bool(arr.node_alive[i]) == node.alive, nid
            assert arr.node_supp[i] == node.hb_suppressed_until, nid
            assert arr.node_flows[i] == node.active_flows, nid
            assert bool(arr.node_link_up[i]) == (nid not in self._link_down), \
                nid
        self.verify_network()
        for job in self.active_jobs.values():
            recount = sum(1 for t in job.maps
                          if t.state == TaskState.COMPLETED)
            assert job.n_maps_done == recount, \
                (job.spec.job_id, job.n_maps_done, recount)
        expected = [(a, t, job) for job in self.active_jobs.values()
                    for t in job.tasks for a in t.attempts]
        live = arr.rows_where(arr.active[:arr.n])
        assert len(live) == len(expected), (len(live), len(expected))
        now = self.engine.now
        prog = arr.progress_at(now, live)
        for k, (r, (a, t, job)) in enumerate(zip(live, expected)):
            assert arr.attempt_ids[r] == a.attempt_id
            assert arr.task_ids[r] == t.task_id
            assert a.row == r
            assert arr.a_state[r] == ASTATE[a.state]
            assert arr.t_state[r] == TSTATE[t.state]
            assert arr.kind[r] == KIND[t.kind]
            assert arr.job_ids[arr.job[r]] == job.spec.job_id
            assert arr.node_ids[arr.node[r]] == a.node_id
            assert bool(arr.spec[r]) == a.is_speculative
            assert arr.start[r] == a.start_time
            assert arr.work_done[r] == a.work_done
            assert arr.work_total[r] == a.work_total
            assert arr.last_sync[r] == a.last_sync
            assert arr.deps[r] == max(1, len(t.deps))
            assert bool(arr.compute[r]) == a.compute_started
            ss = a.shuffle
            if ss is not None:
                assert arr.fetched[r] == len(ss.fetched)
                assert arr.sh_ready[r] == ss.n_ready
                assert arr.sh_inflight[r] == len(ss.inflight)
                assert arr.sh_fail[r] == len(ss.fail_cycles)
                if a.state == AttemptState.RUNNING:
                    self.shuffle.verify_state(a)
            else:
                assert arr.fetched[r] == 0
                assert arr.sh_ready[r] == 0
                assert arr.sh_inflight[r] == 0
                assert arr.sh_fail[r] == 0
            if a.state == AttemptState.RUNNING:
                self.shuffle.verify_timer(a)
            assert prog[k] == a.progress(), (a.attempt_id, prog[k],
                                             a.progress())

    def verify_network(self) -> None:
        """Assert the network model's incrementally-maintained flow and
        link counters equal a from-scratch recount of the live transfers
        (the §15 half of the write-through gate; works with or without
        the columnar mirror)."""
        flows = []
        for a in self.attempts.values():
            if a.state == AttemptState.RUNNING and a.shuffle is not None:
                for src in a.shuffle.fetch_srcs.values():
                    flows.append((src, a.node_id))
        self.cluster.net.verify(flows, self._link_down)

    def _check_map_progress_triggers(self, job: SimJob) -> None:
        if not job.map_progress_triggers:
            return
        frac = job.maps_completed() / max(1, len(job.maps))
        fired = [x for x in job.map_progress_triggers if frac + 1e-12 >= x[0]]
        job.map_progress_triggers = [
            x for x in job.map_progress_triggers if frac + 1e-12 < x[0]]
        for _, fn in fired:
            fn()

    def _check_job_done(self, job: SimJob) -> None:
        if job.done:
            return
        # YARN job completion = every reduce task committed. Outstanding
        # map re-runs (lost-MOF recoveries) are moot once consumers are
        # done; they are killed below.
        if all(t.state == TaskState.COMPLETED for t in job.reduces):
            job.done = True
            self.sched.job_done(job.spec.job_id)
            for t in job.tasks:
                for a in t.running_attempts():
                    self._kill_attempt(a, "job done")
            durations = [
                (t.completed_at - t.first_start)
                for t in job.tasks
                if t.completed_at is not None and t.first_start is not None]
            job.result = JobResult(
                job_id=job.spec.job_id, bench=job.spec.bench,
                input_gb=job.spec.input_gb,
                submit_time=job.spec.submit_time,
                finish_time=self.engine.now,
                n_spec_attempts=job.n_spec_attempts,
                n_attempts=job.n_attempts,
                n_fetch_failures=job.n_fetch_failures,
                task_durations=durations)
            self.results.append(job.result)
            self.active_jobs.pop(job.spec.job_id, None)
            if self.arrays is not None:
                self.arrays.job_finished(job.spec.job_id)
            self.shuffle.on_job_done(job)
            self.speculator.job_done(job.spec.job_id)
            # Prune the global attempt index (stress runs submit hundreds
            # of jobs; node_lost scans this dict).
            for t in job.tasks:
                for a in t.attempts:
                    self.attempts.pop(a.attempt_id, None)
