"""Synthetic production workloads.

``pacman_workload`` (§IV.D): the PACMan job-size mix with Poisson
arrivals — 85 % of jobs at 1 GB, 8 % at 10 GB, 5 % at 50 GB, 2 % at
100 GB, over Terasort/Wordcount/Secondarysort/Grep.

``fleet_workload`` (ISSUE 9): the multi-tenant dispatch plane's stress
mix — a heavier tail (rank^-alpha size frequencies over eight sizes up
to 100 GB) with *bursty* arrivals from a two-phase Markov-modulated
Poisson process: the arrival rate alternates between a burst phase
(``burst_factor`` × the base rate) and an idle phase, with
exponentially distributed phase lengths. Hundreds of concurrent jobs
at realistic burstiness instead of a memoryless trickle.

``trace_workload``: replay ``(time, gb[, bench])`` rows from a real
trace as JobSpecs.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.sim.job import JobSpec

PACMAN_SIZES = (1.0, 10.0, 50.0, 100.0)
PACMAN_PROBS = (0.85, 0.08, 0.05, 0.02)
STRESS_BENCHES = ("terasort", "wordcount", "secondarysort", "grep")


def pacman_workload(n_jobs: int, *, mean_interarrival: float = 30.0,
                    seed: int = 0,
                    benches: Sequence[str] = STRESS_BENCHES,
                    start: float = 0.0) -> List[JobSpec]:
    rng = np.random.default_rng(seed)
    t = start
    jobs = []
    for i in range(n_jobs):
        t += float(rng.exponential(mean_interarrival))
        size = float(rng.choice(PACMAN_SIZES, p=PACMAN_PROBS))
        bench = str(rng.choice(list(benches)))
        jobs.append(JobSpec(job_id=f"j{i:04d}", bench=bench,
                            input_gb=size, submit_time=t))
    return jobs


# Heavy-tailed size grid for the fleet mix: P(size rank r) ∝ r^-alpha.
FLEET_SIZES = (0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0)
FLEET_ALPHA = 1.8


def _fleet_probs(alpha: float = FLEET_ALPHA) -> np.ndarray:
    w = np.arange(1, len(FLEET_SIZES) + 1, dtype=np.float64) ** -alpha
    return w / w.sum()


def fleet_workload(n_jobs: int, *, mean_interarrival: float = 10.0,
                   burst_factor: float = 8.0, burst_len: float = 120.0,
                   idle_len: float = 480.0, alpha: float = FLEET_ALPHA,
                   seed: int = 0, benches: Sequence[str] = STRESS_BENCHES,
                   start: float = 0.0) -> List[JobSpec]:
    """Heavy-tailed sizes + MMPP(2) bursty arrivals.

    Phase lengths are exponential(``burst_len``/``idle_len``); within a
    phase, gaps are exponential with mean ``mean_interarrival`` (idle)
    or ``mean_interarrival / burst_factor`` (burst). A gap that would
    cross the phase boundary is re-drawn from the boundary at the new
    phase's rate — valid because the exponential is memoryless.
    Deterministic for a given seed.
    """
    rng = np.random.default_rng(seed)
    probs = _fleet_probs(alpha)
    t = start
    in_burst = False
    phase_end = t + float(rng.exponential(idle_len))
    jobs = []
    for i in range(n_jobs):
        while True:
            mean = (mean_interarrival / burst_factor if in_burst
                    else mean_interarrival)
            gap = float(rng.exponential(mean))
            if t + gap <= phase_end:
                t += gap
                break
            t = phase_end
            in_burst = not in_burst
            phase_end = t + float(rng.exponential(
                burst_len if in_burst else idle_len))
        size = float(rng.choice(FLEET_SIZES, p=probs))
        bench = str(rng.choice(list(benches)))
        jobs.append(JobSpec(job_id=f"f{i:05d}", bench=bench,
                            input_gb=size, submit_time=t))
    return jobs


def trace_workload(trace: Sequence[Sequence], *, prefix: str = "t",
                   default_bench: str = "terasort",
                   n_reduces: Optional[int] = None) -> List[JobSpec]:
    """Map ``(submit_time, input_gb[, bench])`` trace rows to JobSpecs,
    sorted by submit time (real traces are not always ordered)."""
    jobs = []
    for i, row in enumerate(sorted(trace, key=lambda r: float(r[0]))):
        bench = str(row[2]) if len(row) > 2 else default_bench
        jobs.append(JobSpec(job_id=f"{prefix}{i:05d}", bench=bench,
                            input_gb=float(row[1]),
                            submit_time=float(row[0]),
                            n_reduces=n_reduces))
    return jobs
