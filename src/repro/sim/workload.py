"""Synthetic production workload (§IV.D): PACMan job-size mix with Poisson
arrivals — 85 % of jobs at 1 GB, 8 % at 10 GB, 5 % at 50 GB, 2 % at 100 GB,
over Terasort/Wordcount/Secondarysort/Grep.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.sim.job import JobSpec

PACMAN_SIZES = (1.0, 10.0, 50.0, 100.0)
PACMAN_PROBS = (0.85, 0.08, 0.05, 0.02)
STRESS_BENCHES = ("terasort", "wordcount", "secondarysort", "grep")


def pacman_workload(n_jobs: int, *, mean_interarrival: float = 30.0,
                    seed: int = 0,
                    benches: Sequence[str] = STRESS_BENCHES,
                    start: float = 0.0) -> List[JobSpec]:
    rng = np.random.default_rng(seed)
    t = start
    jobs = []
    for i in range(n_jobs):
        t += float(rng.exponential(mean_interarrival))
        size = float(rng.choice(PACMAN_SIZES, p=PACMAN_PROBS))
        bench = str(rng.choice(list(benches)))
        jobs.append(JobSpec(job_id=f"j{i:04d}", bench=bench,
                            input_gb=size, submit_time=t))
    return jobs
