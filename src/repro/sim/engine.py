"""Deterministic discrete-event engine.

No wall-clock, no threads: a single heap of (time, seq, callback) with a
monotone sequence number for stable ordering of simultaneous events. All
randomness in the simulator flows through one seeded ``numpy`` Generator,
so every benchmark row is bit-reproducible.

Beside the heap there is an optional **calendar lane** of typed
macro-event records (:class:`BatchQueue`, DESIGN.md §14): high-volume
homogeneous events (the shuffle's fetch completions and failure cycles)
are stored as structured numpy records instead of per-event heap entries
with callback tuples and cancellation handles. The run loop drains every
lane record whose ``(time, seq)`` key precedes the heap head in one
step, so a whole burst of fetch-state transitions is applied without
re-entering the generic event machinery, then flushes the consumer's
deferred column write-through before the next heap event can observe it.
Both sources draw their tiebreak sequence from the same counter, so the
merged order is exactly the order a heap-only engine would produce.
"""
from __future__ import annotations

import heapq
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.obs.trace import K_DRAIN as _K_DRAIN


class Cancelled(Exception):
    pass


class EventHandle:
    __slots__ = ("cancelled",)

    def __init__(self):
        self.cancelled = False

    def cancel(self) -> None:
        self.cancelled = True


class BatchQueue:
    """Calendar lane of typed macro-event records beside the engine heap.

    A record is ``(kind, time, row, dep, payload)`` in one structured
    numpy array (plus a parallel python rail holding the owning object,
    like the id rails of ``ArraySnapshot``); ordering lives in a small
    heap of ``(time, seq, slot)`` keys whose ``seq`` comes from the
    engine's global counter. Records carry no cancellation handle: the
    consumer's ``apply`` callback re-validates each record against its
    authoritative state (the shuffle engine matches the record's token
    against its inflight/fail-cycle maps) and silently drops stale ones
    — cancellation is just forgetting the token.

    Contract for appliers (what lets the run loop amortize per-event
    work): a record application must not complete a job or otherwise
    flip a ``run(stop=...)`` condition — the loop only re-checks
    ``stop`` per *heap* event. Appliers may defer column write-through
    while ``in_drain`` is set; ``flush`` runs before every heap event
    and before ``run`` returns, so no reader can observe deferred state.
    """

    DTYPE = np.dtype([("kind", np.int8), ("time", np.float64),
                      ("row", np.int32), ("dep", np.int32),
                      ("payload", np.int32)])

    __slots__ = ("engine", "recs", "objs", "_heap", "_n", "_free",
                 "_apply", "_flush", "_drain_impl", "_kind", "_time",
                 "_row", "_dep", "_payload", "in_drain", "applied",
                 "on_begin", "on_end", "obs")

    def __init__(self, engine: "Engine", apply: Callable, flush: Callable,
                 drain: Optional[Callable] = None, cap: int = 1024):
        self.engine = engine
        self.recs = np.zeros(cap, dtype=self.DTYPE)
        self.objs: List[object] = []
        self._heap: List[Tuple[float, int, int]] = []
        self._n = 0
        # Popped slots are recycled (a slot is reusable the moment its
        # record leaves the heap: every live token is a *pending* record,
        # so no consumer can still hold a freed slot's token). Without
        # this the store could only reset when the lane fully drained —
        # impossible once self-rescheduling tick records live here.
        self._free: List[int] = []
        self._apply = apply
        self._flush = flush
        # Consumers may supply a fused drain loop (the shuffle engine
        # binds its hot state once per drain run instead of once per
        # record); the generic loop below is the reference — the two
        # must apply identical transitions (tests pin this by running
        # the same seeded simulation under both).
        self._drain_impl = drain if drain is not None else \
            self._generic_drain
        self._cache_views()
        self.in_drain = False
        self.applied = 0  # records applied (profiling; incl. stale drops)
        # Optional drain brackets (consumer-set): the ε-fair network
        # model re-solves its share tables once per drain run here
        # (DESIGN.md §15.3) — shared by the fused and generic loops, so
        # the drain-parity tests exercise identical rate schedules.
        self.on_begin: Optional[Callable] = None
        self.on_end: Optional[Callable] = None
        # Optional flight recorder (repro.obs): one drain-summary record
        # per drain run, no per-record cost.
        self.obs = None
        engine.attach_lane(self)

    def _cache_views(self) -> None:
        r = self.recs
        self._kind = r["kind"]
        self._time = r["time"]
        self._row = r["row"]
        self._dep = r["dep"]
        self._payload = r["payload"]

    def _grow(self) -> None:
        new = np.zeros(2 * len(self.recs), dtype=self.DTYPE)
        new[:self._n] = self.recs[:self._n]
        self.recs = new
        self._cache_views()

    def __len__(self) -> int:
        return len(self._heap)

    def schedule(self, t: float, kind: int, obj: object, row: int,
                 dep: int, payload: int) -> int:
        """Append one record; returns its slot id — the *token* the
        consumer stores wherever it would have stored an EventHandle.
        Slots are unique for the life of the pending set: a slot is
        freed (and may be reissued) only when its record pops off the
        lane heap, at which point any dangling copy of the token has
        already been forgotten or invalidated by the applier."""
        eng = self.engine
        assert t >= eng.now - 1e-9, (t, eng.now)
        if self._free:
            slot = self._free.pop()
            self.objs[slot] = obj
        else:
            slot = self._n
            if slot == len(self.recs):
                self._grow()
            self._n = slot + 1
            self.objs.append(obj)
        self._kind[slot] = kind
        self._time[slot] = t
        self._row[slot] = row
        self._dep[slot] = dep
        self._payload[slot] = payload
        heapq.heappush(self._heap, (t, eng._seq, slot))
        eng._seq += 1
        return slot

    def drain(self, heap: list, until: Optional[float]) -> bool:
        """Apply every record whose ``(time, seq)`` key precedes the
        engine heap's head event (re-peeking the heap per record, since
        an application may schedule an earlier event), advancing
        ``engine.now`` per record. Returns True when the drain paused at
        ``until`` (records beyond it stay queued). Deferred write-through
        is flushed on every exit path; the record store resets once the
        lane fully drains (every live token is a pending record, so an
        empty heap means no token dangles)."""
        self.in_drain = True
        rec = self.obs
        if rec is not None:
            t0 = self.engine.now
            n0 = self.applied
        if self.on_begin is not None:
            self.on_begin()
        try:
            paused = self._drain_impl(heap, until)
        finally:
            self.in_drain = False
            if self.on_end is not None:
                self.on_end()
            self._flush()
            if rec is not None:
                rec.emit(_K_DRAIN, b=self.applied - n0, f0=t0)
        if not self._heap:
            self._n = 0
            self.objs.clear()
            self._free.clear()
        return paused

    def _generic_drain(self, heap: list, until: Optional[float]) -> bool:
        """Reference drain loop: one ``apply(kind, obj, dep, payload,
        token)`` call per due record."""
        lheap = self._heap
        eng = self.engine
        apply = self._apply
        objs = self.objs
        kind_v = self._kind
        dep_v = self._dep
        pay_v = self._payload
        pop = heapq.heappop
        while lheap:
            l0 = lheap[0]
            lt = l0[0]
            if heap:
                h0 = heap[0]
                ht = h0[0]
                if lt > ht or (lt == ht and l0[1] > h0[1]):
                    break
            if until is not None and lt > until:
                return True
            eng.now = lt
            slot = pop(lheap)[2]
            if kind_v is not self._kind:  # store grew mid-drain
                kind_v = self._kind
                dep_v = self._dep
                pay_v = self._payload
            obj = objs[slot]
            objs[slot] = None  # release the ref for GC
            kind = int(kind_v[slot])
            dep = int(dep_v[slot])
            pay = int(pay_v[slot])
            self._free.append(slot)
            self.applied += 1
            apply(kind, obj, dep, pay, slot)
        return False


class Engine:
    def __init__(self):
        self.now: float = 0.0
        self._heap: List[Tuple[float, int, EventHandle, Callable, tuple]] = []
        self._seq = 0
        self._lane: Optional[BatchQueue] = None

    def attach_lane(self, lane: BatchQueue) -> None:
        assert self._lane is None, "one calendar lane per engine"
        self._lane = lane

    def at(self, t: float, fn: Callable, *args) -> EventHandle:
        assert t >= self.now - 1e-9, (t, self.now)
        h = EventHandle()
        heapq.heappush(self._heap, (t, self._seq, h, fn, args))
        self._seq += 1
        return h

    def after(self, delay: float, fn: Callable, *args) -> EventHandle:
        return self.at(self.now + max(delay, 0.0), fn, *args)

    def run(self, until: Optional[float] = None,
            stop: Optional[Callable[[], bool]] = None) -> None:
        if self._lane is not None:
            return self._run_with_lane(until, stop)
        while self._heap:
            if stop is not None and stop():
                return
            item = heapq.heappop(self._heap)
            t, _, h, fn, args = item
            if h.cancelled:
                continue
            if until is not None and t > until:
                # Put it back *unchanged*; the caller may resume later.
                # Re-pushing with a fresh seq would demote the deferred
                # event behind same-timestamp events already in (or later
                # added to) the heap — the ordering regression pinned by
                # tests/test_engine.py.
                heapq.heappush(self._heap, item)
                self.now = until
                return
            self.now = t
            fn(*args)
        if until is not None:
            self.now = until

    def _run_with_lane(self, until: Optional[float],
                       stop: Optional[Callable[[], bool]]) -> None:
        """Heap loop merged with the calendar lane: drain every lane
        record due before the heap head, then process one heap event.
        ``stop`` is checked per heap event only — lane records cannot
        flip it (see the BatchQueue contract), and the lane flushes its
        deferred write-through on every drain exit, so no flush is
        needed on the return paths here."""
        heap = self._heap
        lane = self._lane
        lheap = lane._heap
        while heap or lheap:
            if stop is not None and stop():
                return
            if lheap and lane.drain(heap, until):
                self.now = until
                return
            if not heap:
                continue
            item = heapq.heappop(heap)
            t, _, h, fn, args = item
            if h.cancelled:
                continue
            if until is not None and t > until:
                heapq.heappush(heap, item)  # unchanged: seq preserved
                self.now = until
                return
            self.now = t
            fn(*args)
        if until is not None:
            self.now = until
