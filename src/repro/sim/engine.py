"""Deterministic discrete-event engine.

No wall-clock, no threads: a single heap of (time, seq, callback) with a
monotone sequence number for stable ordering of simultaneous events. All
randomness in the simulator flows through one seeded ``numpy`` Generator,
so every benchmark row is bit-reproducible.
"""
from __future__ import annotations

import heapq
from typing import Callable, List, Optional, Tuple


class Cancelled(Exception):
    pass


class EventHandle:
    __slots__ = ("cancelled",)

    def __init__(self):
        self.cancelled = False

    def cancel(self) -> None:
        self.cancelled = True


class Engine:
    def __init__(self):
        self.now: float = 0.0
        self._heap: List[Tuple[float, int, EventHandle, Callable, tuple]] = []
        self._seq = 0

    def at(self, t: float, fn: Callable, *args) -> EventHandle:
        assert t >= self.now - 1e-9, (t, self.now)
        h = EventHandle()
        heapq.heappush(self._heap, (t, self._seq, h, fn, args))
        self._seq += 1
        return h

    def after(self, delay: float, fn: Callable, *args) -> EventHandle:
        return self.at(self.now + max(delay, 0.0), fn, *args)

    def run(self, until: Optional[float] = None,
            stop: Optional[Callable[[], bool]] = None) -> None:
        while self._heap:
            if stop is not None and stop():
                return
            t, _, h, fn, args = heapq.heappop(self._heap)
            if h.cancelled:
                continue
            if until is not None and t > until:
                # put it back; caller may resume later
                heapq.heappush(self._heap, (t, self._seq, h, fn, args))
                self._seq += 1
                self.now = until
                return
            self.now = t
            fn(*args)
        if until is not None:
            self.now = until
