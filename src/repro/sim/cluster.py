"""Simulated cluster: nodes with containers, disks, NICs, heartbeats.

Calibrated to the paper's testbed (§IV.A): 21 nodes (one dedicated to
RM/NameNode → 20 workers), 1 GbE, one 500 GB disk, 24 GB RAM / 24 cores
per node. Containers default to 8 per worker — the YARN 2.7-era
(24 GB, 2–3 GB/container) sizing that lets an 8-map 1 GB job land entirely
on ONE node, which is exactly the co-location behind the paper's
scope-limited myopia (§II.D.2).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Set

# 1 GbE effective goodput and a single SATA disk — the single source now
# lives in the network layer (repro.net.base); re-exported here for the
# seed API (shuffle imports these names from this module).
from repro.net.base import DISK_BW, NIC_BW, NetworkModel  # noqa: F401
from repro.net.flat import FlatNetwork

HEARTBEAT_PERIOD = 1.0  # NodeManager → ResourceManager (s)


@dataclasses.dataclass
class SimNode:
    node_id: str
    n_containers: int = 8
    # Execution-speed multiplier: 1 = healthy, <1 = delayed, 0 = dead.
    speed: float = 1.0
    alive: bool = True
    # Containers in use (attempt ids).
    busy: Set[str] = dataclasses.field(default_factory=set)
    # MOFs present on the local disk: producer task_id → bytes.
    mofs: Dict[str, float] = dataclasses.field(default_factory=dict)
    # Spill logs for speculative rollback: task_id → offset fraction.
    spill_logs: Dict[str, float] = dataclasses.field(default_factory=dict)
    # Active network flows touching this node (for bandwidth sharing).
    active_flows: int = 0
    last_heartbeat: float = 0.0
    # Transient network outage: heartbeats suppressed until this time
    # (node keeps computing — the Fig. 7(b) delay-vs-crash confusion).
    hb_suppressed_until: float = 0.0

    def heartbeat_suppressed(self, now: float) -> bool:
        # Single source of the suppression rule. The per-second RM tick
        # (Simulation._heartbeat_tick) inlines this comparison over its
        # 1000-node loop — keep the two in sync if the rule changes.
        return now < self.hb_suppressed_until

    @property
    def free_containers(self) -> int:
        if not self.alive:
            return 0
        return self.n_containers - len(self.busy)

    def fail(self) -> None:
        """Node crash: heartbeats stop, local MOFs and spill logs are gone."""
        self.alive = False
        self.speed = 0.0
        self.mofs.clear()
        self.spill_logs.clear()

    def restore(self) -> None:
        self.alive = True
        self.speed = 1.0
        self.busy.clear()
        self.active_flows = 0


class Cluster:
    def __init__(self, n_workers: int = 20, n_containers: int = 8,
                 network: Optional[NetworkModel] = None):
        self.nodes: Dict[str, SimNode] = {
            f"n{i:02d}": SimNode(f"n{i:02d}", n_containers)
            for i in range(n_workers)
        }
        self.node_ids: List[str] = list(self.nodes)
        self._node_pos: Dict[str, int] = {
            n: i for i, n in enumerate(self.node_ids)}
        # Pluggable network substrate (DESIGN.md §15): owns the flow
        # accounting and every rate decision. The flat model is the
        # seed's quasi-static per-NIC share, extracted verbatim.
        self.net: NetworkModel = network if network is not None \
            else FlatNetwork()
        self.net.bind(self)
        # Free-container index: a lazy min-heap of node positions that MAY
        # have a free container. Invariant: every alive node with a free
        # container is flagged in the heap; stale entries (consumed slots,
        # dead nodes) are dropped at pop time. ``note_free`` re-arms a node
        # whenever an event can open a slot (complete/kill/crash-teardown/
        # restore), so the global placement scan is O(log n) per launch
        # instead of O(n_workers).
        self._free_heap: List[int] = list(range(n_workers))
        self._in_heap: List[bool] = [True] * n_workers

    def fetch_throughput(self, src: str, dst: str) -> float:
        """Quasi-static rate a new shuffle fetch would get right now —
        answered by the pluggable network model (the seed formula lives
        on as ``repro.net.flat.FlatNetwork.rate_probe``)."""
        return self.net.rate_probe(src, dst)

    def note_free(self, node_id: str) -> None:
        """Re-arm ``node_id`` in the free-container index. Called by the
        substrate wherever a container may have opened (attempt complete/
        kill/fail teardown, node restore); a no-op while the node has no
        free slot or is already armed."""
        i = self._node_pos[node_id]
        if self._in_heap[i]:
            return
        n = self.nodes[node_id]
        if n.alive and n.free_containers > 0:
            heapq.heappush(self._free_heap, i)
            self._in_heap[i] = True

    def pick_container(self, preference: List[str],
                       exclude: Optional[Set[str]] = None) -> Optional[str]:
        """First node with a free container: preference order first, then
        pack-first over the cluster (deterministic; co-locates small jobs).

        The pack-first scan pops the free-container heap instead of
        walking every node: the heap yields candidates in node order, so
        the choice matches the seed's linear scan exactly (property-tested
        in tests/test_cluster_index.py). Excluded-but-free nodes are
        re-pushed after the query — exclusion is per-call state."""
        exclude = exclude or set()
        for nid in preference:
            n = self.nodes.get(nid)
            if n is not None and n.alive and nid not in exclude \
                    and n.free_containers > 0:
                return nid
        chosen: Optional[str] = None
        excluded_free: List[int] = []
        heap = self._free_heap
        while heap:
            i = heap[0]
            nid = self.node_ids[i]
            n = self.nodes[nid]
            if not n.alive or n.free_containers <= 0:
                heapq.heappop(heap)          # stale entry: slot consumed
                self._in_heap[i] = False
                continue
            if nid in exclude:
                heapq.heappop(heap)          # still free; restore below
                excluded_free.append(i)
                continue
            chosen = nid
            break
        for i in excluded_free:
            heapq.heappush(heap, i)
        return chosen
