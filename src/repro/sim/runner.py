"""Experiment helpers shared by the paper-figure benchmarks.

``run_single`` runs one job under one policy with an optional fault
callback; ``baseline_jct`` caches fault-free runs; ``slowdown`` is the
paper's metric (JCT with fault / fault-free JCT, same policy-free
baseline).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.sim.job import JobResult, JobSpec
from repro.sim.mapreduce import SimJob, SimParams, Simulation

FaultFn = Callable[[Simulation, SimJob], None]


def run_single(policy: str, spec: JobSpec, fault: Optional[FaultFn] = None,
               *, seed: int = 0, n_workers: int = 20, n_containers: int = 8,
               params: Optional[SimParams] = None,
               assess_backend: Optional[str] = None,
               policy_factory=None) -> JobResult:
    sim = Simulation(policy=policy, seed=seed, n_workers=n_workers,
                     n_containers=n_containers, params=params,
                     assess_backend=assess_backend,
                     policy_factory=policy_factory)
    job = sim.submit(spec)
    if fault is not None:
        fault(sim, job)
    results = sim.run()
    assert results, f"job did not finish within the sim cap ({spec})"
    return results[0]


@functools.lru_cache(maxsize=4096)
def _baseline_cached(bench: str, input_gb: float, seed: int,
                     n_workers: int, n_containers: int) -> float:
    spec = JobSpec(job_id="base", bench=bench, input_gb=input_gb)
    # Fault-free baseline is policy-independent (no speculation triggers);
    # run under the YARN substrate defaults.
    return run_single("yarn", spec, None, seed=seed, n_workers=n_workers,
                      n_containers=n_containers).jct


def baseline_jct(bench: str, input_gb: float, *, seed: int = 0,
                 n_workers: int = 20, n_containers: int = 8) -> float:
    return _baseline_cached(bench, float(input_gb), seed, n_workers,
                            n_containers)


def slowdown(policy: str, spec: JobSpec, fault: Optional[FaultFn],
             *, seed: int = 0, n_workers: int = 20,
             n_containers: int = 8, params: Optional[SimParams] = None,
             assess_backend: Optional[str] = None,
             policy_factory=None) -> Tuple[float, JobResult]:
    res = run_single(policy, spec, fault, seed=seed, n_workers=n_workers,
                     n_containers=n_containers, params=params,
                     assess_backend=assess_backend,
                     policy_factory=policy_factory)
    base = baseline_jct(spec.bench, spec.input_gb, seed=seed,
                        n_workers=n_workers, n_containers=n_containers)
    return res.jct / base, res


def run_workload(policy: str, specs: Sequence[JobSpec],
                 fault_script: Optional[Callable[[Simulation], None]] = None,
                 *, seed: int = 0, n_workers: int = 20,
                 n_containers: int = 8,
                 params: Optional[SimParams] = None,
                 assess_backend: Optional[str] = None,
                 policy_factory=None,
                 dispatch_opts: Optional[Dict] = None) -> List[JobResult]:
    sim = Simulation(policy=policy, seed=seed, n_workers=n_workers,
                     n_containers=n_containers, params=params,
                     assess_backend=assess_backend,
                     policy_factory=policy_factory,
                     dispatch_opts=dispatch_opts)
    for spec in specs:
        sim.submit(spec)
    if fault_script is not None:
        fault_script(sim)
    return sim.run()
