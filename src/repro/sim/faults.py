"""Fault injectors (§IV.A: "To emulate temporary system faults, we
introduce delays in the progress of MapReduce tasks. To emulate node
failures, we disconnect the targeted compute nodes.").

All injectors are deterministic given the simulation's seed; triggers can
fire at absolute times or at job map-progress fractions (Fig. 4a injects a
node failure at 10 %..100 % of map progress).
"""
from __future__ import annotations

from typing import Optional

from repro.core.types import TaskState
from repro.obs.trace import FAULT_CODES, K_FAULT
from repro.sim.mapreduce import SimJob, Simulation


# ---------------------------------------------------------------------------
# Declarative fault scripts (DESIGN.md §16.4): one script, two worlds.
#
# A script is a list of plain tuples ``(kind, idx, x, y)`` — printable,
# picklable, and identical across every engine of the differential fuzz
# matrix AND across the sim/runtime boundary: ``apply_script`` interprets
# a script against the discrete-event simulator, while
# ``repro.runtime.chaos.ChaosController`` interprets the *same* tuples
# against live coordinator/host threads. ``idx`` selects the victim node
# (modulo cluster size) or rack/map index, ``x`` is a time or progress
# fraction in [0, 1], ``y`` a magnitude/duration scale in [0, 1].
#
# Runtime-only kinds (message-plane faults the discrete-event simulator
# has no wire for) degrade to their nearest sim-visible equivalent — the
# equivalence waivers are tabulated in DESIGN.md §16.4:
#   drop     -> link cut (messages lost both ways)
#   dup      -> no-op    (sim event delivery is exactly-once by construction)
#   reorder  -> no-op    (sim events are totally ordered by the calendar)
#   delay_hb -> heartbeat outage (late heartbeats look silent, then resume)
#   hang     -> slowdown to ~0 (compute stops, heartbeats continue)
# ---------------------------------------------------------------------------
SCRIPT_KINDS = ("crash", "crash_restore", "slow", "hb", "mof", "disk",
                "degrade", "cut", "part",
                # runtime-first kinds with sim waivers:
                "drop", "dup", "reorder", "delay_hb", "hang")


def apply_script(sim: Simulation, job: Optional[SimJob], script) -> None:
    """Arm every step of a declarative fault script against ``sim``."""
    for step in script:
        kind, idx, x, y = step
        nid = sim.cluster.node_ids[idx % len(sim.cluster.node_ids)]
        at = 10.0 + x * 200.0
        if kind == "degrade":
            # rack-switch degradation (no-op on flat: no uplinks)
            rack_switch_degrade_at(sim, idx, at, factor=0.02 + 0.2 * y,
                                   duration=45.0 + y * 150.0)
        elif kind in ("cut", "drop"):
            link_cut_at(sim, nid, at, duration=25.0 + y * 120.0)
        elif kind == "part":
            rack_partition_at(sim, idx, at, duration=20.0 + y * 90.0)
        elif kind == "crash":
            crash_node_at(sim, nid, at)
        elif kind == "crash_restore":
            crash_node_at(sim, nid, at, restore_after=20.0 + y * 100.0)
        elif kind == "slow":
            slow_node_at(sim, nid, at, factor=0.02 + 0.06 * y,
                         duration=30.0 + y * 150.0)
        elif kind == "hang":
            # compute stops while heartbeats continue: the liar node
            slow_node_at(sim, nid, at, factor=1e-3,
                         duration=30.0 + y * 150.0)
        elif kind in ("hb", "delay_hb"):
            heartbeat_outage_at(sim, nid, at, duration=15.0 + y * 60.0)
        elif kind == "mof":
            lose_mof_at_map_progress(sim, job, max(x, 0.05),
                                     max_stragglers=2 + int(y * 14))
        elif kind == "disk":
            disk_exception_on_map(sim, job, idx % 8, at_spill=1 + int(y * 3))
        elif kind in ("dup", "reorder"):
            pass  # exactly-once / totally-ordered by construction (§16.4)
        else:  # pragma: no cover - strategy bug guard
            raise ValueError(kind)


def crash_node_at(sim: Simulation, node_id: str, at: float,
                  restore_after: Optional[float] = None) -> None:
    sim.engine.at(at, sim.crash_node, node_id)
    if restore_after is not None:
        sim.engine.at(at + restore_after, sim.restore_node, node_id)


def slow_node_at(sim: Simulation, node_id: str, at: float, factor: float,
                 duration: Optional[float] = None) -> None:
    sim.engine.at(at, sim.set_node_speed, node_id, factor)
    if duration is not None:
        sim.engine.at(at + duration, sim.set_node_speed, node_id, 1.0)


def heartbeat_outage_at(sim: Simulation, node_id: str, at: float,
                        duration: float) -> None:
    """Transient network delay: the node keeps computing but its heartbeats
    vanish for ``duration`` — indistinguishable from a crash until it
    resumes (the Fig. 7(b) confusion matrix). Suppression windows only
    ever extend (overlapping outages — or an outage during a link cut —
    union; a short outage must not resume a severed link's heartbeats)."""
    def start():
        # Emit inside the existing callback — scheduling a separate obs
        # event would shift engine seq allocation and break the
        # obs-on ≡ obs-off byte-identity gate (DESIGN.md §18.2).
        if sim.obs is not None:
            sim.obs.emit(K_FAULT, a=sim.cluster._node_pos[node_id],
                         b=FAULT_CODES["hb"], f0=duration)
        node = sim.cluster.nodes[node_id]
        node.hb_suppressed_until = max(node.hb_suppressed_until,
                                       sim.engine.now + duration)
        sim._arr_node_supp(node_id)
    sim.engine.at(at, start)


def rack_switch_degrade_at(sim: Simulation, rack: int, at: float,
                           factor: float,
                           duration: Optional[float] = None) -> None:
    """Network-level fault (DESIGN.md §15.5): the rack's uplink switch
    degrades to ``factor`` of its capacity — every future inter-rack
    fetch touching the rack prices against the shrunken uplink, so the
    whole rack's shuffle health sags while its nodes stay perfectly
    alive (the degraded-network scenario the paper's glance ζ-scores
    must separate from a sick node). Overlapping windows on one rack
    union — the strongest active degrade wins, and the uplink heals
    only when every window has elapsed (same discipline as link cuts
    and heartbeat outages). No-op on topology-free networks
    (``net="flat"`` has no uplinks)."""
    net = sim.cluster.net
    key = rack % max(1, net.n_racks)

    def eff() -> float:
        reg = sim._degrade_windows.get(key, [])
        return min((f for _e, f in reg), default=1.0)

    def start():
        if sim.obs is not None:
            sim.obs.emit(K_FAULT, a=-1, b=FAULT_CODES["degrade"],
                         f0=factor, f1=float(key))
        end = (sim.engine.now + duration if duration is not None
               else float("inf"))
        sim._degrade_windows.setdefault(key, []).append((end, factor))
        net.set_uplink_factor(rack, eff())

    def stop():
        reg = sim._degrade_windows.get(key, [])
        now = sim.engine.now
        reg[:] = [(e, f) for e, f in reg if e > now + 1e-9]
        net.set_uplink_factor(rack, eff())

    sim.engine.at(at, start)
    if duration is not None:
        sim.engine.at(at + duration, stop)


def link_cut_at(sim: Simulation, node_id: str, at: float,
                duration: Optional[float] = None) -> None:
    """The node's network link goes down: fetch paths to/from it are
    lost (in-flight transfers abort into failure cycles, its MOF copies
    leave the candidate set) and its heartbeats vanish — while the node
    keeps computing. Restores after ``duration`` if given."""
    sim.engine.at(at, sim.cut_link, node_id, duration)
    if duration is not None:
        sim.engine.at(at + duration, sim.restore_link, node_id)


def rack_partition_at(sim: Simulation, rack: int, at: float,
                      duration: Optional[float] = None) -> None:
    """Whole-rack network partition: every node in the rack gets its
    link cut at ``at`` (coarse model: the MOF-availability index is
    consumer-independent, so intra-rack fetches are suppressed along
    with inter-rack ones — the §15.5 fidelity waiver), healing together
    after ``duration``."""
    def start():
        for nid in sim.cluster.net.rack_nodes(rack):
            sim.cut_link(nid, duration)
    def end():
        for nid in sim.cluster.net.rack_nodes(rack):
            sim.restore_link(nid)
    sim.engine.at(at, start)
    if duration is not None:
        sim.engine.at(at + duration, end)


def crash_busiest_node_at_map_progress(sim: Simulation, job: SimJob,
                                       frac: float,
                                       restore_after: Optional[float] = None
                                       ) -> None:
    """Fig. 1/4a scenario: when ``job`` reaches ``frac`` of map completions,
    disconnect the node hosting the most of its map work (attempts first,
    then MOFs) — the co-located small-job killer."""
    def fire():
        counts = {}
        for t in job.maps:
            for a in t.running_attempts():
                counts[a.node_id] = counts.get(a.node_id, 0) + 1
            for n in t.output_nodes:
                counts[n] = counts.get(n, 0) + 1
        if not counts:  # map phase fully drained; hit a MOF holder
            for t in job.maps:
                for n in t.output_nodes:
                    counts[n] = counts.get(n, 0) + 1
        if not counts:
            return
        victim = max(sorted(counts), key=lambda n: counts[n])
        sim.crash_node(victim)
        if restore_after is not None:
            sim.engine.after(restore_after, sim.restore_node, victim)
    if frac <= 0.0:
        # fire as soon as the job has placed its attempts
        sim.engine.at(job.spec.submit_time + 1.0, fire)
    else:
        job.map_progress_triggers.append((frac, fire))


def lose_mof_at_map_progress(sim: Simulation, job: SimJob, frac: float,
                             max_stragglers: int = 2) -> None:
    """Fig. 4b scenario: silently delete one completed map's MOF (node stays
    healthy) — pure dependency-oblivious territory.

    The paper post-selects runs "when there is at least one fetch failure of
    MOF but no map task failure": qualifying losses are ones some reducer
    still needs. We pre-select deterministically: the victim is a completed
    map whose partition ≥1 but ≤``max_stragglers`` running reducers have not
    fetched yet — few reporters means the AM's 3-report fuse burns through
    multiple full fetch cycles, the Hadoop stall behind the 4× slowdown.
    If no qualifying map exists yet, the injector re-arms shortly after.
    """
    def fire():
        # Wait until the shuffle is mostly drained, then hit the map with
        # the fewest (≥1) still-waiting reducers.
        need = done = 0
        for r in job.reduces:
            for a in r.running_attempts():
                need += len(a.task.deps)
                done += len(a.shuffle.fetched)
        unfinished = any(r.state != TaskState.COMPLETED
                         for r in job.reduces)
        if need == 0 or done / need < 0.75:
            if unfinished:
                sim.engine.after(1.0, fire)
            return
        best = None
        for t in job.maps:
            if t.state != TaskState.COMPLETED or not t.output_nodes:
                continue
            waiting = 0
            for r in job.reduces:
                for a in r.running_attempts():
                    # only original consumers count: a speculative copy
                    # that dies with its sibling can't produce the paper's
                    # qualifying fetch-failure condition
                    if not a.is_speculative \
                            and t.task_id not in a.shuffle.fetched:
                        waiting += 1
            if waiting >= 1 and (best is None or waiting < best[0]):
                best = (waiting, t)
        if best is None:
            if unfinished:
                sim.engine.after(1.0, fire)
            return
        sim.lose_mof(best[1])
    job.map_progress_triggers.append((frac, fire))


def disk_exception_on_map(sim: Simulation, job: SimJob, map_index: int,
                          at_spill: int) -> None:
    """Fig. 9 scenario: the map's attempt dies with a disk write exception
    right after producing ``at_spill`` spills (progress log survives)."""
    n = job.spec.n_spills
    # fail just past the at_spill-th spill boundary
    frac = min((at_spill + 0.02) / n, 0.999)

    def arm():
        if map_index >= len(job.maps):
            return
        t = job.maps[map_index]
        if sim.obs is not None:
            sim.obs.emit(K_FAULT, a=-1, b=FAULT_CODES["disk"],
                         f0=frac, obj=t.task_id)
        t.inject_disk_exception_at = frac
        # The first attempt may already be running (dispatch happens in the
        # submit event): inject directly and recompute its milestones.
        for a in t.running_attempts():
            if a.disk_exception_at is None:
                a.disk_exception_at = frac
                t.inject_disk_exception_at = None
                sim._schedule_map_milestone(a)
            break
    sim.engine.at(job.spec.submit_time, arm)
