"""Deterministic discrete-event MapReduce simulator — the faithful-semantics
substrate for reproducing the paper's experiments (Figs. 1–9). The policy
engine under test is ``repro.core``; the simulator supplies YARN 2.7.1
execution semantics (NM expiry, shuffle fetch-failure cycles, slowstart,
container packing) and seeded fault injection.
"""
from repro.sim.cluster import Cluster, SimNode
from repro.sim.engine import Engine
from repro.sim.job import BENCHMARKS, BenchProfile, JobResult, JobSpec
from repro.sim.mapreduce import BINO_PARAMS, SimParams, Simulation
from repro.sim import faults, runner, workload

__all__ = [
    "BENCHMARKS", "BINO_PARAMS", "BenchProfile", "Cluster", "Engine",
    "JobResult", "JobSpec", "SimNode", "SimParams", "Simulation",
    "faults", "runner", "workload",
]
