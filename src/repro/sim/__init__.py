"""Deterministic discrete-event MapReduce simulator — the faithful-semantics
substrate for reproducing the paper's experiments (Figs. 1–9). The policy
engine under test is ``repro.core``; the simulator supplies YARN 2.7.1
execution semantics (NM expiry, shuffle fetch-failure cycles, slowstart,
container packing) and seeded fault injection.
"""
from repro.sim.cluster import Cluster, SimNode
from repro.sim.dispatch import Dispatcher, LaunchRequest
from repro.sim.engine import Engine
from repro.sim.job import BENCHMARKS, BenchProfile, JobResult, JobSpec
from repro.sim.mapreduce import BINO_PARAMS, SimParams, Simulation
from repro.sim.shuffle import (
    BatchShuffle,
    EventShuffle,
    MofRegistry,
    RescanShuffle,
)
from repro.sim import dispatch, faults, runner, shuffle, workload

__all__ = [
    "BENCHMARKS", "BINO_PARAMS", "BatchShuffle", "BenchProfile", "Cluster",
    "Dispatcher", "Engine", "EventShuffle", "JobResult", "JobSpec",
    "LaunchRequest", "MofRegistry", "RescanShuffle", "SimNode", "SimParams",
    "Simulation", "dispatch", "faults", "runner", "shuffle", "workload",
]
