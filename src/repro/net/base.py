"""Network substrate for the simulated cluster (DESIGN.md §15).

The seed modeled the shuffle network as one quasi-static per-node NIC
share with no topology: every fetch launch read the endpoints' live flow
counts, divided, and scheduled the transfer at that frozen rate
(``Cluster.fetch_throughput``). That model is byte-for-byte preserved as
:class:`~repro.net.flat.FlatNetwork` — the default and the bit-exactness
anchor — while this package makes the network *pluggable*:

- :class:`~repro.net.topo.TopoNetwork` — rack-aware: nodes grouped into
  racks, per-NIC plus per-rack-uplink capacities with configurable
  oversubscription, same quasi-static discipline (1-rack topo is
  byte-identical to flat);
- :class:`~repro.net.fair.FairNetwork` — batched ε-fair shares: flow
  rates come from a max-min water-fill over columnar flow/link tables,
  recomputed **once per BatchQueue drain** instead of per launch — the
  opt-in fidelity trade that removes the per-flow sequential core the
  ROADMAP measured at 1000 nodes.

Every model owns the authoritative flow bookkeeping (``SimNode.
active_flows`` plus the columnar ``node_flows``/``rack_flows``/... ride
the §11 write-through discipline: ``ArraySnapshot.init_net`` aliases the
model's arrays so one store serves both, and ``verify_arrays``/
``Simulation.verify_network`` check them against a from-scratch recount
of the live transfers).

Link faults (``sim/faults.py``): ``rack_switch_degrade_at`` scales a
rack uplink's capacity for future rate decisions; ``link_cut_at`` /
``rack_partition_at`` take fetch paths down entirely — modeled as
aborted transfers plus MOF-source suppression (an unreachable copy must
*not* schedule an almost-infinite transfer; it must burn failure
cycles, which is the recovery machinery the paper studies).
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.trace import K_FLOW_CLOSE, K_FLOW_OPEN

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.sim.cluster import Cluster

# 1 GbE effective goodput and a single SATA disk (the paper's testbed,
# §IV.A). ``repro.sim.cluster`` re-exports these — the net layer sits
# below the simulator and must not import it.
NIC_BW = 117e6          # bytes/s
DISK_BW = 100e6         # bytes/s (local MOF read)

# Datacenter-typical rack oversubscription: uplink capacity defaults to
# (nodes-per-rack × NIC) / OVERSUB.
DEFAULT_OVERSUB = 4.0

# Floor for degraded uplink factors: a zero-capacity link would schedule
# infinite transfers; total loss is expressed via link cuts instead.
MIN_FACTOR = 1e-3


class NetworkModel:
    """Pluggable flow-level network model.

    Contract (shared by all implementations):

    - ``open_flow(src, dst) -> rate`` registers one shuffle transfer and
      returns its quasi-static rate (bytes/s, decided at flow start —
      the engine schedules the completion event from it);
    - ``close_flow(src, dst)`` releases one transfer of that pair;
    - ``rate_probe(src, dst)`` answers what a new flow would get *now*
      without registering anything (the seed ``fetch_throughput`` API);
    - ``begin_drain``/``end_drain`` bracket a BatchQueue drain run —
      only :class:`FairNetwork` uses them (``wants_drain_hook``);
    - ``cut``/``restore_link`` maintain the link-down mirror; the
      simulation layer owns the recovery semantics (aborts, MOF-source
      suppression);
    - ``node_reset`` re-syncs a node's columns after ``SimNode.restore``.

    ``inline_flat`` gates BatchShuffle's hand-inlined flat fast path:
    only the seed-compat flat model may claim it (the inline code *is*
    the seed arithmetic).
    """

    name = "base"
    inline_flat = False
    wants_drain_hook = False
    # Optional flight recorder (repro.obs); Simulation._wire_obs sets it.
    # Class-level None keeps the per-flow branch one attribute load.
    obs = None
    # Models that can stage flow bookkeeping across a drain and apply it
    # in one vectorized end-of-drain step (FairNetwork's bulk mode,
    # DESIGN.md §17.2) advertise it here; the kernel drain engine calls
    # ``enable_bulk()`` when True.
    supports_bulk = False

    def __init__(self, *, nic_bw: float = NIC_BW, disk_bw: float = DISK_BW,
                 seed_compat: bool = True):
        self.nic_bw = float(nic_bw)
        self.disk_bw = float(disk_bw)
        # Seed-compat flow accounting: the seed registered a *local*
        # fetch on "both" endpoints — i.e. twice on the one node (the
        # asymmetric double-count ISSUE 5 flags). ``seed_compat=False``
        # counts each flow once per distinct endpoint (the fix); traces
        # shift wherever reducers fetch co-located MOFs, so the compat
        # behavior stays the default (DESIGN.md §15.4).
        self.seed_compat = bool(seed_compat)
        self.nodes: Dict[str, object] = {}
        self.node_ids: List[str] = []
        self._node_pos: Dict[str, int] = {}
        self.n_racks = 1
        # Columnar write-through arrays (aliased into ArraySnapshot by
        # ``init_net`` — one store serves model and snapshot).
        self.node_flows = np.zeros(0, dtype=np.int32)
        self.node_link_up = np.ones(0, dtype=bool)
        self.node_rack = np.zeros(0, dtype=np.int32)
        self.rack_flows = np.zeros(1, dtype=np.int32)
        self.rack_factor = np.ones(1)

    # -- wiring ----------------------------------------------------------
    def bind(self, cluster: "Cluster") -> None:
        self.nodes = cluster.nodes
        self.node_ids = cluster.node_ids
        self._node_pos = cluster._node_pos
        n = len(self.node_ids)
        self.node_flows = np.zeros(n, dtype=np.int32)
        self.node_link_up = np.ones(n, dtype=bool)
        self.node_rack = self._rack_layout(n)
        self.rack_flows = np.zeros(self.n_racks, dtype=np.int32)
        self.rack_factor = np.ones(self.n_racks)
        self._post_bind()

    def _rack_layout(self, n: int) -> np.ndarray:
        """Contiguous rack blocks: rack r = nodes[r*k:(r+1)*k]."""
        if self.n_racks <= 1:
            return np.zeros(n, dtype=np.int32)
        per = -(-n // self.n_racks)  # ceil
        return (np.arange(n, dtype=np.int32) // per).astype(np.int32)

    def _post_bind(self) -> None:
        """Model-specific capacity tables (after the layout exists)."""

    # -- topology queries -------------------------------------------------
    def rack_of(self, node_id: str) -> int:
        return int(self.node_rack[self._node_pos[node_id]])

    def rack_nodes(self, rack: int) -> List[str]:
        rack = rack % max(1, self.n_racks)
        return [self.node_ids[i]
                for i in np.flatnonzero(self.node_rack == rack)]

    # -- flow lifecycle ---------------------------------------------------
    def open_flow(self, src: str, dst: str) -> float:
        raise NotImplementedError

    def close_flow(self, src: str, dst: str) -> None:
        raise NotImplementedError

    def rate_probe(self, src: str, dst: str) -> float:
        raise NotImplementedError

    # -- drain bracketing (FairNetwork) -----------------------------------
    def begin_drain(self) -> None:  # pragma: no cover - trivial default
        pass

    def end_drain(self) -> None:  # pragma: no cover - trivial default
        pass

    # -- fault hooks ------------------------------------------------------
    def set_uplink_factor(self, rack: int, factor: float) -> None:
        """Scale a rack uplink's capacity (switch degradation). Future
        rate decisions see the new capacity; in-flight transfers keep
        their quasi-static rates. No-op on topology-free models."""
        if self.n_racks <= 1:
            return
        rack = rack % self.n_racks
        self.rack_factor[rack] = max(float(factor), MIN_FACTOR)
        self._capacity_changed()

    def _capacity_changed(self) -> None:
        pass

    def cut(self, node_id: str) -> None:
        self.node_link_up[self._node_pos[node_id]] = False

    def restore_link(self, node_id: str) -> None:
        self.node_link_up[self._node_pos[node_id]] = True

    def link_down(self, node_id: str) -> bool:
        return not bool(self.node_link_up[self._node_pos[node_id]])

    def node_reset(self, node_id: str) -> None:
        """Node restored after a crash: its flow bookkeeping restarts
        from the (already torn down) clean slate."""
        self.node_flows[self._node_pos[node_id]] = \
            self.nodes[node_id].active_flows

    # -- shared accounting helpers ---------------------------------------
    def _count_open(self, src: str, dst: str) -> None:
        """Register one flow on the per-node counters + mirror. In
        seed-compat mode a local flow (src == dst) counts twice on its
        one node — the seed behavior; symmetric mode counts once per
        distinct endpoint."""
        pos = self._node_pos
        nf = self.node_flows
        s = self.nodes[src]
        if self.obs is not None:
            self.obs.emit(K_FLOW_OPEN, a=pos[src], b=pos[dst])
        if src == dst:
            s.active_flows += 2 if self.seed_compat else 1
            nf[pos[src]] = s.active_flows
            return
        d = self.nodes[dst]
        s.active_flows += 1
        d.active_flows += 1
        nf[pos[src]] = s.active_flows
        nf[pos[dst]] = d.active_flows

    def _count_close(self, src: str, dst: str) -> None:
        pos = self._node_pos
        nf = self.node_flows
        s = self.nodes[src]
        if self.obs is not None:
            self.obs.emit(K_FLOW_CLOSE, a=pos[src], b=pos[dst])
        if src == dst:
            k = 2 if self.seed_compat else 1
            s.active_flows = max(0, s.active_flows - k)
            nf[pos[src]] = s.active_flows
            return
        d = self.nodes[dst]
        s.active_flows = max(0, s.active_flows - 1)
        d.active_flows = max(0, d.active_flows - 1)
        nf[pos[src]] = s.active_flows
        nf[pos[dst]] = d.active_flows

    # -- consistency ------------------------------------------------------
    def expected_node_counts(
            self, flows: Sequence[Tuple[str, str]]) -> np.ndarray:
        """Per-node flow counts a from-scratch recount of ``flows``
        (live (src, dst) transfers) yields under this model's
        accounting rules."""
        pos = self._node_pos
        counts = np.zeros(len(self.node_ids), dtype=np.int64)
        local_k = 2 if self.seed_compat else 1
        for src, dst in flows:
            if src == dst:
                counts[pos[src]] += local_k
            else:
                counts[pos[src]] += 1
                counts[pos[dst]] += 1
        return counts

    def verify(self, flows: Sequence[Tuple[str, str]],
               link_down: Optional[set] = None) -> None:
        """Assert the incrementally-maintained counters equal a recount
        from the authoritative transfer list (the §11 gate's network
        half; conftest.check_invariants calls this mid-run)."""
        expect = self.expected_node_counts(flows)
        for i, nid in enumerate(self.node_ids):
            got = self.nodes[nid].active_flows
            assert got == expect[i], (nid, got, int(expect[i]))
            assert int(self.node_flows[i]) == got, (nid, got)
        if link_down is not None:
            for i, nid in enumerate(self.node_ids):
                assert bool(self.node_link_up[i]) == (nid not in link_down), \
                    nid
        self._verify_extra(flows)

    def _verify_extra(self, flows: Sequence[Tuple[str, str]]) -> None:
        pass


def make_network(spec, *, racks: int = 0, **opts) -> NetworkModel:
    """Resolve a network spec: an instance passes through; ``"flat"``
    (default), ``"topo"`` and ``"fair"`` build the named model. ``racks``
    sets the rack count for the topology-aware models (``topo`` defaults
    to 4 racks, ``fair`` to 1)."""
    if isinstance(spec, NetworkModel):
        return spec
    from repro.net.fair import FairNetwork
    from repro.net.flat import FlatNetwork
    from repro.net.topo import TopoNetwork
    if spec in (None, "flat"):
        return FlatNetwork(**opts)
    if spec == "topo":
        return TopoNetwork(racks=racks or 4, **opts)
    if spec == "fair":
        return FairNetwork(racks=max(racks, 1), **opts)
    raise ValueError(f"unknown network model: {spec!r}")
