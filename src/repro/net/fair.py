"""Batched ε-fair flow model (DESIGN.md §15.3) — the opt-in fidelity
trade that removes the per-flow sequential core of the quasi-static rule.

The flat/topo models decide every launch rate from the endpoints' *live*
flow counts, so each fetch launch must observe the previous completion's
bookkeeping — the measured 1000-node bottleneck (ROADMAP): the batch
lane's fused drain cannot reorder or coalesce around that dependency.
``FairNetwork`` replaces the per-launch observation with an **ε-fair
(max-min) allocation over columnar flow/link tables**, recomputed
vectorized **once per BatchQueue drain** (``begin_drain``); every launch
inside the drain prices against the drain-start equilibrium — O(links
per flow) array reads, no recompute, no sequential observation.

Links: one NIC per node, one disk per node (local reads), one uplink
per rack (capacity ``nodes-per-rack × NIC / oversub`` × degradation
factor). A flow crosses its endpoint NICs plus, when inter-rack, both
rack uplinks; local flows cross the disk only. The water-fill freezes
all links within ``(1+ε)`` of each round's bottleneck share together
(ε=0 → exact max-min); per-flow equilibrium rates and per-link shares
come out of the same solve. Properties (capacity, work conservation,
monotonicity under removal, flat agreement on degenerate 1-rack
patterns) are hypothesis-tested in tests/test_net.py.

``recompute="flow"`` re-solves before *every* launch — the per-flow
accounting baseline the ``perf_net`` benchmark gates the drained mode
against (≥ 1.5× end-to-end at 1000 nodes on the batch engine).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.net.base import DEFAULT_OVERSUB, NetworkModel
from repro.obs.trace import K_FLOW_BULK

_INIT_FLOWS = 256


class FairNetwork(NetworkModel):
    name = "fair"
    wants_drain_hook = True

    def __init__(self, racks: int = 1, oversub: float = DEFAULT_OVERSUB,
                 uplink_bw: float = None, eps: float = 0.05,
                 recompute: str = "drain", bulk: bool = True,
                 bulk_backend="numpy", realloc: bool = False, **kw):
        # The fair model carries no seed-compat burden: flows count once
        # per distinct endpoint (the symmetric accounting).
        kw.setdefault("seed_compat", False)
        super().__init__(**kw)
        assert racks >= 1, racks
        assert recompute in ("drain", "flow"), recompute
        self.n_racks = int(racks)
        self.oversub = float(oversub)
        self._uplink_bw = uplink_bw
        self.eps = float(eps)
        self.recompute_mode = recompute
        # Columnar flow table (grow-by-doubling + freelist; a slot's
        # links row is the flow's full link membership, -1 padded).
        cap = _INIT_FLOWS
        self.f_links = np.full((cap, 4), -1, dtype=np.int32)
        self.f_active = np.zeros(cap, dtype=bool)
        self.f_rate = np.zeros(cap)
        self.f_si = np.zeros(cap, dtype=np.int32)   # endpoint positions:
        self.f_di = np.zeros(cap, dtype=np.int32)   # the bulk link source
        self._free: List[int] = []
        self._hi = 0                      # slots ever touched
        self.n_flows = 0
        self._pair: Dict[Tuple[str, str], List[int]] = {}
        # Link tables (built at bind: [node NICs | node disks | uplinks]).
        self.link_cap = np.zeros(0)
        self.link_share = np.zeros(0)
        self.link_nflows = np.zeros(0, dtype=np.int32)
        self._dirty = True
        self._frozen = False
        self._lane_seen = False           # a BatchQueue drain ever ran
        self.n_recomputes = 0             # solver invocations (profiling)
        # Bulk mode (DESIGN.md §17.2): while a drain holds the shares
        # frozen, opens/closes stage only the scalar flow-table fields
        # (si/di/active/pair) and ``end_drain`` rebuilds the link/count
        # tables in one vectorized step; the water-fill delegates to a
        # repro.accel.bulk backend. Armed by ``enable_bulk()`` — only
        # the kernel drain calls it, so batch-engine traces (the perf
        # baseline) never change. ``bulk=False`` in net_opts keeps even
        # the kernel engine on the incremental path (the differential
        # bulk-vs-incremental pin in the fuzz suite).
        self._bulk_opt = bool(bulk)
        self._bulk_backend_spec = bulk_backend
        self._bulk = False
        self._backend = None
        self._stale = False               # staged table updates pending
        # Staged open/close tallies since the last end_drain — the bulk
        # path bypasses ``_count_open``/``_count_close`` (and thus their
        # per-flow obs records); end_drain emits one K_FLOW_BULK summary.
        self._staged_opens = 0
        self._staged_closes = 0
        self.last_slot = -1               # slot of the latest open_flow
        # Drain-boundary re-allocation of in-flight transfers (§17.4
        # waiver): opt-in; consumed by KernelShuffle, not by this class.
        self.realloc = bool(realloc)

    @property
    def supports_bulk(self) -> bool:
        # flow-mode recomputes *inside* every open: incompatible with
        # staging the tables until end-of-drain
        return self._bulk_opt and self.recompute_mode == "drain"

    def enable_bulk(self) -> None:
        assert self.recompute_mode == "drain", self.recompute_mode
        assert self.n_flows == 0, "enable_bulk() before any traffic"
        if self._bulk:
            return
        from repro.accel.bulk import get_bulk_backend
        self._backend = get_bulk_backend(self._bulk_backend_spec)
        self._bulk = True

    # ------------------------------------------------------------------
    def _post_bind(self) -> None:
        n = len(self.node_ids)
        if self._uplink_bw is not None:
            up = float(self._uplink_bw)
        else:
            per_rack = -(-n // self.n_racks)
            up = per_rack * self.nic_bw / self.oversub
        self.link_cap = np.concatenate([
            np.full(n, self.nic_bw),          # 0..n-1     node NICs
            np.full(n, self.disk_bw),         # n..2n-1    node disks
            np.full(self.n_racks, up),        # 2n..       rack uplinks
        ])
        self.link_share = self._eff_cap()
        self.link_nflows = np.zeros(len(self.link_cap), dtype=np.int32)
        # Python-scalar rack lookup for the kernel drain's inlined
        # staged-open pricing (the layout is fixed after bind).
        self._rack_py = self.node_rack.tolist()
        self._dirty = True

    def _eff_cap(self) -> np.ndarray:
        eff = self.link_cap.copy()
        n2 = 2 * len(self.node_ids)
        eff[n2:] *= self.rack_factor
        return eff

    def _capacity_changed(self) -> None:
        self._dirty = True

    # ------------------------------------------------------------------
    def _flow_link_list(self, src: str, dst: str) -> List[int]:
        pos = self._node_pos
        si = pos[src]
        n = len(self.node_ids)
        if src == dst:
            return [n + si]                   # local read: disk only
        di = pos[dst]
        rs = int(self.node_rack[si])
        rd = int(self.node_rack[di])
        links = [si, di]
        if rs != rd:
            links.append(2 * n + rs)
            links.append(2 * n + rd)
        return links

    def _alloc(self) -> int:
        if self._free:
            return self._free.pop()
        slot = self._hi
        if slot == len(self.f_active):
            cap = 2 * len(self.f_active)
            links = np.full((cap, 4), -1, dtype=np.int32)
            links[:slot] = self.f_links[:slot]
            self.f_links = links
            for name in ("f_active", "f_rate", "f_si", "f_di"):
                col = getattr(self, name)
                new = np.zeros(cap, dtype=col.dtype)
                new[:slot] = col[:slot]
                setattr(self, name, new)
        self._hi = slot + 1
        return slot

    # ------------------------------------------------------------------
    def open_flow(self, src: str, dst: str) -> float:
        pos = self._node_pos
        si = pos[src]
        di = si if src == dst else pos[dst]
        if self._frozen and self._bulk:
            # Staged open: the drain prices against frozen shares, so
            # the link/count tables are dead until ``end_drain`` rebuilds
            # them — record only the endpoints and the frozen price.
            slot = self._alloc()
            self.last_slot = slot
            self.f_si[slot] = si
            self.f_di[slot] = di
            self.f_active[slot] = True
            self.n_flows += 1
            self._pair.setdefault((src, dst), []).append(slot)
            self._stale = True
            self._staged_opens += 1
            share = self.link_share
            n = len(self.node_ids)
            if si == di:
                r = share[n + si]
            else:
                r = share[si]
                x = share[di]
                if x < r:
                    r = x
                rs = self.node_rack[si]
                rd = self.node_rack[di]
                if rs != rd:
                    n2 = 2 * n
                    x = share[n2 + rs]
                    if x < r:
                        r = x
                    x = share[n2 + rd]
                    if x < r:
                        r = x
            return float(r) if r > 1.0 else 1.0
        links = self._flow_link_list(src, dst)
        slot = self._alloc()
        self.last_slot = slot
        row = self.f_links[slot]
        row[:] = -1
        row[:len(links)] = links
        self.f_si[slot] = si
        self.f_di[slot] = di
        self.f_active[slot] = True
        self.n_flows += 1
        n2 = 2 * len(self.node_ids)
        for l in links:
            self.link_nflows[l] += 1
            if l >= n2:
                self.rack_flows[l - n2] += 1
        self._pair.setdefault((src, dst), []).append(slot)
        self._count_open(src, dst)
        self._dirty = True
        if self.recompute_mode == "flow":
            # per-flow accounting: re-solve with the new flow included
            # and charge it its exact equilibrium rate
            self._recompute()
            return max(float(self.f_rate[slot]), 1.0)
        if self._dirty and not self._frozen and not self._lane_seen:
            # no calendar lane drives this model (rescan/event engines):
            # fall back to per-event recompute so shares never go stale
            self._recompute()
        return max(float(self.link_share[links].min()), 1.0)

    def close_flow(self, src: str, dst: str) -> None:
        slots = self._pair.get((src, dst))
        assert slots, (src, dst)
        slot = slots.pop()
        if not slots:
            del self._pair[(src, dst)]
        if self._frozen and self._bulk:
            # Staged close (see open_flow): only the slot dies now; the
            # count tables catch up in the end_drain rebuild.
            self.f_active[slot] = False
            self.f_rate[slot] = 0.0
            self.n_flows -= 1
            self._free.append(slot)
            self._stale = True
            self._staged_closes += 1
            return
        row = self.f_links[slot]
        n2 = 2 * len(self.node_ids)
        for l in row:
            if l < 0:
                break
            self.link_nflows[l] -= 1
            if l >= n2:
                self.rack_flows[l - n2] -= 1
        self.f_active[slot] = False
        self.f_rate[slot] = 0.0
        self.n_flows -= 1
        self._free.append(slot)
        self._count_close(src, dst)
        self._dirty = True

    def rate_probe(self, src: str, dst: str) -> float:
        if self._dirty and not self._frozen:
            self._recompute()
        links = self._flow_link_list(src, dst)
        return max(float(self.link_share[links].min()), 1.0)

    # ------------------------------------------------------------------
    def begin_drain(self) -> None:
        self._lane_seen = True
        if self._dirty:
            self._recompute()
        self._frozen = True

    def end_drain(self) -> None:
        self._frozen = False
        if self._stale:
            self._stale = False
            self._rebuild_tables()
            # flows changed during the drain: the next begin_drain (or
            # rate_probe) re-solves — the incremental path's cadence
            self._dirty = True
            if self.obs is not None:
                self.obs.emit(K_FLOW_BULK, b=self.n_flows,
                              f0=float(self._staged_opens),
                              f1=float(self._staged_closes))
        self._staged_opens = 0
        self._staged_closes = 0

    def _rebuild_tables(self) -> None:
        """Catch the link/count tables up with the drain's staged
        opens/closes in one vectorized pass over the active flows:
        derive every flow's link row from its endpoints, bincount the
        per-link/rack loads, and diff-sync the per-node counters (the
        ``node_flows``/``rack_flows`` stores are aliased into
        ``ArraySnapshot`` — all writes in place). Runs between the
        drain and the next heap event, so no reader can observe the
        mid-drain staleness."""
        n = len(self.node_ids)
        n2 = 2 * n
        idx = np.flatnonzero(self.f_active[: self._hi])
        si = self.f_si[idx]
        di = self.f_di[idx]
        local = si == di
        rs = self.node_rack[si]
        rd = self.node_rack[di]
        inter = ~local & (rs != rd)
        L = np.empty((len(idx), 4), dtype=np.int32)
        L[:, 0] = np.where(local, n + si, si)
        L[:, 1] = np.where(local, -1, di)
        L[:, 2] = np.where(inter, n2 + rs, -1)
        L[:, 3] = np.where(inter, n2 + rd, -1)
        self.f_links[idx] = L
        self.link_nflows[:] = np.bincount(L[L >= 0],
                                          minlength=len(self.link_cap))
        self.rack_flows[:] = self.link_nflows[n2:]
        newc = np.bincount(si, minlength=n) + \
            np.bincount(di[~local], minlength=n)
        changed = np.flatnonzero(newc != self.node_flows)
        if len(changed):
            nodes = self.nodes
            ids = self.node_ids
            self.node_flows[changed] = newc[changed]
            for i in changed.tolist():
                nodes[ids[i]].active_flows = int(newc[i])

    # ------------------------------------------------------------------
    def _recompute(self) -> None:
        """ε-fair max-min water-fill, vectorized over the flow/link
        tables. Per round: every live link's equal share is its
        remaining capacity over its unfrozen flow count; the global
        minimum share saturates its link(s) — all links within
        ``(1+ε)`` of it freeze together, their flows pinned at the
        bottleneck share. ≤ one round per distinct bottleneck; ε merges
        near-ties so faulted 1000-node states stay a handful of rounds."""
        self.n_recomputes += 1
        self._dirty = False
        eff = self._eff_cap()
        nL = len(eff)
        idx = np.flatnonzero(self.f_active[: self._hi])
        share = eff.copy()
        if not len(idx):
            self.link_share = share
            return
        L = self.f_links[idx]
        valid = L >= 0
        if self._backend is not None:
            # bulk mode: the water-fill itself sits behind the pluggable
            # solver (numpy backend ≡ the loop below, bit-for-bit)
            share, rate = self._backend.waterfill(eff, L, valid, self.eps)
            self.f_rate[idx] = rate
            self.link_share = share
            return
        flat_links = np.where(valid, L, 0)
        k = len(idx)
        rem = eff.copy()
        rate = np.zeros(k)
        alive = np.ones(k, dtype=bool)
        was_bott = np.zeros(nL, dtype=bool)
        eps1 = 1.0 + self.eps
        while True:
            a_links = flat_links[alive][valid[alive]]
            if not len(a_links):
                break
            cnt = np.bincount(a_links, minlength=nL)
            live = cnt > 0
            s_all = np.where(live, rem / np.maximum(cnt, 1), np.inf)
            s = float(s_all.min())
            bott = live & (s_all <= s * eps1)
            hit = alive & (bott[flat_links] & valid).any(axis=1)
            rate[hit] = s
            h_links = flat_links[hit][valid[hit]]
            rem = np.maximum(rem - np.bincount(h_links, minlength=nL) * s,
                             0.0)
            share[bott] = s
            was_bott |= bott
            alive &= ~hit
        # Links that never bottlenecked expose their residual headroom
        # (what one more flow could claim there before other links bind).
        free = ~was_bott
        share[free] = rem[free]
        self.f_rate[idx] = rate
        self.link_share = share

    # ------------------------------------------------------------------
    def flow_rates(self) -> np.ndarray:
        """Equilibrium rates of the active flows (slot order) as of the
        last recompute — the property-test surface."""
        idx = np.flatnonzero(self.f_active[: self._hi])
        return self.f_rate[idx].copy()

    def active_flow_links(self) -> np.ndarray:
        idx = np.flatnonzero(self.f_active[: self._hi])
        return self.f_links[idx].copy()

    # ------------------------------------------------------------------
    def _verify_extra(self, flows: Sequence[Tuple[str, str]]) -> None:
        assert self.n_flows == len(flows), (self.n_flows, len(flows))
        expect = np.zeros(len(self.link_cap), dtype=np.int64)
        racks = np.zeros(self.n_racks, dtype=np.int64)
        n2 = 2 * len(self.node_ids)
        for src, dst in flows:
            for l in self._flow_link_list(src, dst):
                expect[l] += 1
                if l >= n2:
                    racks[l - n2] += 1
        got = self.link_nflows.astype(np.int64)
        assert (got == expect).all(), \
            (np.flatnonzero(got != expect).tolist())
        assert (self.rack_flows.astype(np.int64) == racks).all(), \
            (self.rack_flows.tolist(), racks.tolist())
        n_pair = sum(len(v) for v in self._pair.values())
        assert n_pair == self.n_flows, (n_pair, self.n_flows)
        assert int(self.f_active[: self._hi].sum()) == self.n_flows
        assert not self._stale, "staged bulk updates leaked past a drain"
        pos = self._node_pos
        for (src, dst), slots in self._pair.items():
            si, di = pos[src], pos[dst]
            for s in slots:
                assert bool(self.f_active[s]), (src, dst, s)
                assert int(self.f_si[s]) == si, (src, dst, s)
                assert int(self.f_di[s]) == di, (src, dst, s)
