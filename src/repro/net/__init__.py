"""Pluggable topology-aware network substrate (DESIGN.md §15).

``flat`` (seed-exact per-NIC shares, the default), ``topo`` (rack-aware
quasi-static with oversubscribed uplinks), ``fair`` (batched ε-fair
max-min shares recomputed per BatchQueue drain). Select per simulation:
``Simulation(net="topo", racks=4)``.
"""
from repro.net.base import (
    DEFAULT_OVERSUB,
    DISK_BW,
    NIC_BW,
    NetworkModel,
    make_network,
)
from repro.net.fair import FairNetwork
from repro.net.flat import FlatNetwork
from repro.net.topo import TopoNetwork

__all__ = [
    "DEFAULT_OVERSUB", "DISK_BW", "FairNetwork", "FlatNetwork", "NIC_BW",
    "NetworkModel", "TopoNetwork", "make_network",
]
