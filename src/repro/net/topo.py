"""Rack-aware quasi-static network model (DESIGN.md §15.2).

Nodes are grouped into contiguous racks; every inter-rack fetch crosses
both rack uplinks in addition to the two endpoint NICs. Each uplink has
capacity ``nodes-per-rack × NIC / oversub`` (datacenter-style
oversubscription) scaled by a per-rack degradation factor
(``rack_switch_degrade_at``), and is shared quasi-statically across the
inter-rack flows touching that rack — the exact per-NIC discipline the
flat model applies per node, lifted to the uplink.

With one rack no flow is ever inter-rack, so the model degenerates to
:class:`~repro.net.flat.FlatNetwork` byte-for-byte (enforced in
tests/test_net.py) — that equivalence also pins the generic
``open_flow`` path against BatchShuffle's inlined flat arithmetic.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

from repro.net.base import DEFAULT_OVERSUB, NetworkModel


class TopoNetwork(NetworkModel):
    name = "topo"

    def __init__(self, racks: int = 4, oversub: float = DEFAULT_OVERSUB,
                 uplink_bw: Optional[float] = None, **kw):
        super().__init__(**kw)
        assert racks >= 1, racks
        self.n_racks = int(racks)
        self.oversub = float(oversub)
        self._uplink_bw = uplink_bw
        self.uplink_cap = np.zeros(self.n_racks)

    def _post_bind(self) -> None:
        if self._uplink_bw is not None:
            cap = float(self._uplink_bw)
        else:
            per_rack = -(-len(self.node_ids) // self.n_racks)
            cap = per_rack * self.nic_bw / self.oversub
        self.uplink_cap = np.full(self.n_racks, cap)

    # ------------------------------------------------------------------
    def rate_probe(self, src: str, dst: str) -> float:
        if src == dst:
            return self.disk_bw / max(1, self.nodes[src].active_flows + 1)
        rate = min(
            self.nic_bw / max(1, self.nodes[src].active_flows + 1),
            self.nic_bw / max(1, self.nodes[dst].active_flows + 1))
        pos = self._node_pos
        rs = int(self.node_rack[pos[src]])
        rd = int(self.node_rack[pos[dst]])
        if rs != rd:
            up = self.uplink_cap * self.rack_factor
            flows = self.rack_flows
            rate = min(rate,
                       up[rs] / max(1, int(flows[rs]) + 1),
                       up[rd] / max(1, int(flows[rd]) + 1))
        return rate

    def open_flow(self, src: str, dst: str) -> float:
        rate = self.rate_probe(src, dst)
        self._count_open(src, dst)
        if src != dst:
            pos = self._node_pos
            rs = int(self.node_rack[pos[src]])
            rd = int(self.node_rack[pos[dst]])
            if rs != rd:
                self.rack_flows[rs] += 1
                self.rack_flows[rd] += 1
        return rate

    def close_flow(self, src: str, dst: str) -> None:
        self._count_close(src, dst)
        if src != dst:
            pos = self._node_pos
            rs = int(self.node_rack[pos[src]])
            rd = int(self.node_rack[pos[dst]])
            if rs != rd:
                self.rack_flows[rs] = max(0, int(self.rack_flows[rs]) - 1)
                self.rack_flows[rd] = max(0, int(self.rack_flows[rd]) - 1)

    # ------------------------------------------------------------------
    def _verify_extra(self, flows: Sequence[Tuple[str, str]]) -> None:
        pos = self._node_pos
        expect = np.zeros(self.n_racks, dtype=np.int64)
        for src, dst in flows:
            if src == dst:
                continue
            rs = int(self.node_rack[pos[src]])
            rd = int(self.node_rack[pos[dst]])
            if rs != rd:
                expect[rs] += 1
                expect[rd] += 1
        got = self.rack_flows.astype(np.int64)
        assert (got == expect).all(), (got.tolist(), expect.tolist())
