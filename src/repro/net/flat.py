"""The seed's flat per-NIC share model, extracted verbatim (DESIGN.md
§15.1) — the default network and the bit-exactness anchor.

``rate_probe`` is byte-for-byte ``Cluster.fetch_throughput`` from the
seed: local reads hit the disk, remote fetches share each endpoint NIC
across that node's active flows, all quasi-static (decided at flow
start, never re-allocated). ``open_flow`` pairs the probe with the flow
registration the shuffle engines used to do inline.

Seed-compat accounting (default): a local fetch increments the one
node's counter twice — once as "source", once as "destination" — so
co-located flows weigh double in every later share decision (the
asymmetric accounting ISSUE 5 flags). ``seed_compat=False`` applies the
symmetric fix (each flow counts once per distinct endpoint); action
traces shift wherever reducers fetch MOFs from their own node, which is
why the fix ships behind the flag (§15.4).
"""
from __future__ import annotations

from repro.net.base import DISK_BW, NIC_BW, NetworkModel


class FlatNetwork(NetworkModel):
    name = "flat"

    @property
    def inline_flat(self) -> bool:  # type: ignore[override]
        # BatchShuffle's hand-inlined fast path IS the seed-compat
        # arithmetic over the module-constant bandwidths; a symmetric-
        # fix or custom-capacity flat model must take the generic path
        # (the inline code bakes NIC_BW/DISK_BW in).
        return (self.seed_compat and self.nic_bw == NIC_BW
                and self.disk_bw == DISK_BW)

    def rate_probe(self, src: str, dst: str) -> float:
        """Quasi-static per-flow rate for a shuffle fetch, decided at
        flow start (the seed ``Cluster.fetch_throughput``)."""
        if src == dst:
            return self.disk_bw / max(1, self.nodes[src].active_flows + 1)
        s = self.nic_bw / max(1, self.nodes[src].active_flows + 1)
        d = self.nic_bw / max(1, self.nodes[dst].active_flows + 1)
        return min(s, d)

    def open_flow(self, src: str, dst: str) -> float:
        rate = self.rate_probe(src, dst)
        self._count_open(src, dst)
        return rate

    def close_flow(self, src: str, dst: str) -> None:
        self._count_close(src, dst)
