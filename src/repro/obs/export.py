"""Trace exporters + diff tooling (DESIGN.md §18.4).

``to_chrome_trace`` renders a :class:`~repro.obs.trace.TraceRecorder`
into the Chrome trace-event JSON format, loadable in Perfetto
(https://ui.perfetto.dev) or ``chrome://tracing`` — attempts become
duration slices on their node's track, everything else becomes instant
events. ``trace_diff`` compares two recorders record-for-record, the
trace-plane sibling of the action-trace equivalence gate.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Sequence

from repro.obs.trace import (
    K_ATT_END,
    K_ATT_START,
    K_DRAIN,
    KIND_NAMES,
    TraceRecorder,
)

_US = 1e6  # chrome trace timestamps are microseconds


def to_chrome_trace(rec: TraceRecorder, *,
                    node_names: Optional[Sequence[str]] = None,
                    process_name: str = "repro") -> Dict[str, Any]:
    """Render the recorder into a chrome://tracing / Perfetto document.

    Tracks (``tid``) are node indices; attempt lifecycle records pair
    into complete ("X") slices keyed by attempt id, drains become slices
    on a dedicated engine track, and every other kind becomes an instant
    ("i") event carrying its numeric fields as args."""
    events: List[Dict[str, Any]] = [{
        "name": "process_name", "ph": "M", "pid": 0, "tid": 0,
        "args": {"name": process_name},
    }]
    if node_names:
        for i, nid in enumerate(node_names):
            events.append({"name": "thread_name", "ph": "M", "pid": 0,
                           "tid": i, "args": {"name": str(nid)}})
    open_attempts: Dict[Any, Any] = {}
    for r, obj in rec.iter_with_objs():
        kind = int(r["kind"])
        t_us = float(r["time"]) * _US
        a = int(r["a"])
        if kind == K_ATT_START:
            open_attempts[obj] = (t_us, a, int(r["b"]))
        elif kind == K_ATT_END:
            start = open_attempts.pop(obj, None)
            t0 = start[0] if start is not None else float(r["f0"]) * _US
            events.append({
                "name": str(obj), "cat": "attempt", "ph": "X",
                "pid": 0, "tid": a, "ts": t0,
                "dur": max(t_us - t0, 0.0),
                "args": {"state": int(r["b"]),
                         "work": float(r["f1"]),
                         "speculative": bool(r["f2"])},
            })
        elif kind == K_DRAIN:
            t0 = float(r["f0"]) * _US
            events.append({
                "name": "drain", "cat": "engine", "ph": "X",
                "pid": 1, "tid": 0, "ts": t0,
                "dur": max(t_us - t0, 0.0),
                "args": {"records": int(r["b"])},
            })
        else:
            args = {"a": a, "b": int(r["b"]),
                    "f0": float(r["f0"]), "f1": float(r["f1"]),
                    "f2": float(r["f2"]), "f3": float(r["f3"])}
            if obj is not None:
                args["obj"] = repr(obj)
            events.append({
                "name": KIND_NAMES.get(kind, str(kind)),
                "cat": "obs", "ph": "i", "s": "g",
                "pid": 0, "tid": max(a, 0), "ts": t_us, "args": args,
            })
    # attempts still open at export time: emit as zero-duration starts
    for obj, (t0, a, flags) in open_attempts.items():
        events.append({"name": str(obj), "cat": "attempt", "ph": "X",
                       "pid": 0, "tid": a, "ts": t0, "dur": 0.0,
                       "args": {"state": 0, "flags": flags}})
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"dropped_records": rec.dropped}}


def write_chrome_trace(rec: TraceRecorder, path: str, **kw) -> str:
    with open(path, "w") as f:
        json.dump(to_chrome_trace(rec, **kw), f)
    return path


def trace_diff(a: TraceRecorder, b: TraceRecorder, *,
               kinds: Optional[Sequence[int]] = None,
               time_tol: float = 0.0) -> Dict[str, Any]:
    """Record-for-record comparison of two traces.

    Compares ``(kind, a, b, f0..f3)`` plus (within ``time_tol``) the
    timestamps, ignoring ``seq``/``o`` (recorder-local). Returns a
    summary dict; ``equal`` is True when both streams match end to end.
    Restrict to ``kinds`` to diff one plane (e.g. only actions)."""
    ra, rb = a.records(), b.records()
    if kinds is not None:
        import numpy as np
        ra = ra[np.isin(ra["kind"], list(kinds))]
        rb = rb[np.isin(rb["kind"], list(kinds))]
    n = min(len(ra), len(rb))
    first = None
    for i in range(n):
        x, y = ra[i], rb[i]
        same = (int(x["kind"]) == int(y["kind"])
                and int(x["a"]) == int(y["a"])
                and int(x["b"]) == int(y["b"])
                and abs(float(x["time"]) - float(y["time"])) <= time_tol
                and all(float(x[f]) == float(y[f])
                        for f in ("f0", "f1", "f2", "f3")))
        if not same:
            first = i
            break
    equal = first is None and len(ra) == len(rb)
    out = {"equal": equal, "n_a": len(ra), "n_b": len(rb),
           "first_diff": first}
    if first is not None:
        out["detail"] = (f"record {first}: "
                         f"a={_fmt(ra[first])} b={_fmt(rb[first])}")
    elif len(ra) != len(rb):
        out["detail"] = f"length mismatch: {len(ra)} vs {len(rb)}"
    return out


def _fmt(r) -> str:
    name = KIND_NAMES.get(int(r["kind"]), str(int(r["kind"])))
    return (f"{name}(t={float(r['time']):.4f}, a={int(r['a'])}, "
            f"b={int(r['b'])}, f0={float(r['f0']):.4g})")
