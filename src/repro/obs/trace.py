"""Flight recorder: typed structured-numpy trace segments (DESIGN.md §18).

One record schema, two worlds: the simulator and the live runtime emit
the same fixed-width records — speculation verdicts with their Eq. 1–4
inputs at decision time, attempt lifecycle, drain brackets, fair-net
flow events, fault injections, rollbacks — into a
:class:`TraceRecorder`. The recorder follows the PR 4 ``BatchQueue``
idiom: a numeric rail of structured-numpy records plus a parallel
python object rail for the few kinds that carry an object (policy
actions for lazy ``repr``, attempt ids for lifecycle pairing).

Cost discipline:

- **Disabled** — every emit site is guarded by one attribute test
  (``if obs is not None``); no recorder, no allocation, no call.
- **Enabled** — an emit is one tuple store into a preallocated segment.
  Memory is bounded: when ``capacity`` records are exceeded the oldest
  *segment* is dropped whole (and counted in :attr:`dropped`), so a
  10 000-node run records the recent window instead of growing without
  bound.

Determinism contract: ``time`` comes from the injected ``time_fn`` (the
engine clock in the sim, ``Clock.time`` in the runtime); ``seq`` is the
recorder's own monotonic counter — it deliberately does NOT draw from
the engine's event counter, which would perturb heap tie-breaking and
break the obs-on ≡ obs-off byte-identity gate (tests/test_obs.py).
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Iterator, List, Optional, Tuple

import numpy as np

# -- record kinds (0 stays invalid, BatchQueue convention) ----------------
K_ACTION = 1            # policy action; o = action object, a = node idx
K_DETECT = 2            # node declared failed; a = node idx, b = 1 if
#                         policy-marked (Eq. 4 / MarkNodeFailed), 0 if
#                         liveness-expiry declared
K_GLANCE_SPATIAL = 3    # Eq. 1 verdict; a = node, f0 = P_i, f1 = mean P,
#                         f2 = sigma threshold, f3 = streak
K_GLANCE_TEMPORAL = 4   # Eq. 2/3 verdict; a = node, f0 = zeta_now,
#                         f1 = zeta_prev, f2 = delta peak, f3 = dt
K_GLANCE_FAIL = 5       # Eq. 4 verdict; a = node, f0 = silent seconds,
#                         f1 = threshold_i, f2 = margin
K_THRESH = 6            # Eq. 4 adaptation; a = node, f0 = new threshold,
#                         f1 = outage length
K_LATE = 7              # LATE victim; a = task row/idx, f0 = rho,
#                         f1 = rho threshold, f2 = est_remaining
K_ATT_START = 8         # o = attempt id; a = node idx, b = flag bits
K_ATT_END = 9           # o = attempt id; a = node idx, b = state code,
#                         f0 = start time, f1 = progress/work,
#                         f2 = 1.0 if speculative
K_DRAIN = 10            # lane drain; b = records applied, f0 = t_begin
K_FLOW_OPEN = 11        # a = src node idx, b = dst node idx, f0 = rate
K_FLOW_CLOSE = 12       # a = src node idx, b = dst node idx
K_FLOW_BULK = 13        # staged bulk rebuild; a = opens, b = closes
K_FAULT = 14            # injected fault fired; a = victim node idx
#                         (-1 if not node-targeted), b = fault code,
#                         f0 = script x, f1 = script y
K_ROLLBACK = 15         # a = node idx / -1, b = retry count
K_CHECKPOINT = 16       # b = step
K_RAMP = 17             # collective ramp; a = task idx, b = n backups,
#                         f0 = rnd draw, f1 = neighborhood budget
K_DISPATCH = 18         # container grant; a = node idx,
#                         b = bit0 speculative | bit1 rollback
K_FETCH_FAIL = 19       # fetch failure cycle burned; a = node idx
K_BUDGET = 20           # cluster-wide speculation-budget tick;
#                         a = slots in use after admission, b = capacity,
#                         f0 = candidates proposed, f1 = admitted,
#                         f2 = denied this tick
K_PREDICT = 21          # learned-policy straggler score; o = task id,
#                         a = node idx, b = 1 if admitted for backup,
#                         f0 = sigmoid score, f1 = decision threshold

KIND_NAMES = {
    K_ACTION: "action", K_DETECT: "detect",
    K_GLANCE_SPATIAL: "glance_spatial", K_GLANCE_TEMPORAL: "glance_temporal",
    K_GLANCE_FAIL: "glance_fail", K_THRESH: "eq4_adapt", K_LATE: "late",
    K_ATT_START: "attempt_start", K_ATT_END: "attempt_end",
    K_DRAIN: "drain", K_FLOW_OPEN: "flow_open", K_FLOW_CLOSE: "flow_close",
    K_FLOW_BULK: "flow_bulk", K_FAULT: "fault", K_ROLLBACK: "rollback",
    K_CHECKPOINT: "checkpoint", K_RAMP: "ramp", K_DISPATCH: "dispatch",
    K_FETCH_FAIL: "fetch_fail", K_BUDGET: "budget", K_PREDICT: "predict",
}

# action codes for K_ACTION.b / attempt-end state codes for K_ATT_END.b
ACT_MARK_FAILED = 1
ACT_SPECULATE = 2
ACT_KILL = 3

END_COMPLETED = 1
END_FAILED = 2
END_KILLED = 3

# fault kind → stable code (union of the sim and chaos vocabularies;
# keep in sync with repro.sim.faults.SCRIPT_KINDS / runtime.chaos)
FAULT_CODES = {
    "crash": 1, "crash_restore": 2, "slow": 3, "hb": 4, "mof": 5,
    "disk": 6, "degrade": 7, "cut": 8, "part": 9, "hang": 10,
    "delay_hb": 11, "drop": 12, "dup": 13, "reorder": 14,
}
# fault codes whose victim is a node (scorecard ground-truth set)
NODE_FAULT_CODES = frozenset(
    FAULT_CODES[k] for k in
    ("crash", "crash_restore", "slow", "hb", "hang", "delay_hb"))

TRACE_DTYPE = np.dtype([
    ("kind", np.int16),
    ("time", np.float64),
    ("seq", np.int64),
    ("a", np.int32),
    ("b", np.int32),
    ("o", np.int32),       # index into the segment's object rail; -1 = none
    ("f0", np.float64),
    ("f1", np.float64),
    ("f2", np.float64),
    ("f3", np.float64),
])


class _Segment:
    __slots__ = ("recs", "n", "objs")

    def __init__(self, size: int):
        self.recs = np.zeros(size, dtype=TRACE_DTYPE)
        self.n = 0
        self.objs: List[Any] = []


class TraceRecorder:
    """Bounded, low-overhead structured-record trace buffer."""

    __slots__ = ("time_fn", "segment_size", "capacity", "dropped",
                 "_segs", "_seq", "_lock")

    def __init__(self, time_fn: Optional[Callable[[], float]] = None, *,
                 capacity: int = 262_144, segment_size: int = 8_192,
                 thread_safe: bool = False):
        self.time_fn = time_fn if time_fn is not None else (lambda: 0.0)
        self.segment_size = int(segment_size)
        self.capacity = max(int(capacity), self.segment_size)
        self.dropped = 0
        self._segs: List[_Segment] = [_Segment(self.segment_size)]
        self._seq = 0
        self._lock = threading.Lock() if thread_safe else None

    # -- hot path ---------------------------------------------------------
    def emit(self, kind: int, a: int = 0, b: int = 0,
             f0: float = 0.0, f1: float = 0.0, f2: float = 0.0,
             f3: float = 0.0, obj: Any = None) -> None:
        if self._lock is not None:
            with self._lock:
                self._emit(kind, a, b, f0, f1, f2, f3, obj)
        else:
            self._emit(kind, a, b, f0, f1, f2, f3, obj)

    def _emit(self, kind, a, b, f0, f1, f2, f3, obj) -> None:
        seg = self._segs[-1]
        if seg.n >= self.segment_size:
            seg = self._grow()
        o = -1
        if obj is not None:
            o = len(seg.objs)
            seg.objs.append(obj)
        seg.recs[seg.n] = (kind, self.time_fn(), self._seq, a, b, o,
                           f0, f1, f2, f3)
        seg.n += 1
        self._seq += 1

    def _grow(self) -> _Segment:
        if len(self._segs) * self.segment_size >= self.capacity:
            victim = self._segs.pop(0)     # drop-oldest, whole segment
            self.dropped += victim.n
        seg = _Segment(self.segment_size)
        self._segs.append(seg)
        return seg

    # -- reads ------------------------------------------------------------
    def __len__(self) -> int:
        return sum(s.n for s in self._segs)

    def records(self) -> np.ndarray:
        """All retained records, oldest first, as one structured array."""
        parts = [s.recs[:s.n] for s in self._segs if s.n]
        if not parts:
            return np.zeros(0, dtype=TRACE_DTYPE)
        return np.concatenate(parts)

    def by_kind(self, kind: int) -> np.ndarray:
        recs = self.records()
        return recs[recs["kind"] == kind]

    def iter_with_objs(self, kind: Optional[int] = None
                       ) -> Iterator[Tuple[np.void, Any]]:
        """Yield ``(record, obj-or-None)`` pairs in emission order."""
        for seg in self._segs:
            recs = seg.recs
            for i in range(seg.n):
                r = recs[i]
                if kind is not None and int(r["kind"]) != kind:
                    continue
                o = int(r["o"])
                yield r, (seg.objs[o] if o >= 0 else None)

    def actions(self) -> Iterator[Tuple[float, Any]]:
        """``(time, action object)`` pairs for every K_ACTION record —
        the lazy-repr backing of ``Simulation.action_trace``."""
        for r, obj in self.iter_with_objs(K_ACTION):
            yield float(r["time"]), obj

    def counts(self) -> dict:
        recs = self.records()
        kinds, n = np.unique(recs["kind"], return_counts=True)
        return {KIND_NAMES.get(int(k), str(int(k))): int(c)
                for k, c in zip(kinds, n)}

    def clear(self) -> None:
        self._segs = [_Segment(self.segment_size)]
        self.dropped = 0
        self._seq = 0
