"""Speculation scorecard: detection quality from a flight-recorder trace
(DESIGN.md §18.5).

The chaos/fault scripts give perfect ground truth — every injected
node fault lands as a ``K_FAULT`` record at its actual fire time, with
the victim's node index. Policy failure verdicts land as ``K_DETECT``
records (``b=1`` policy-marked via Eq. 4 / MarkNodeFailed, ``b=0``
liveness-expiry declared). Joining the two planes yields the
scheduler-survey detection metrics no per-run counter could produce:

- **precision** — of the nodes a policy declared failed, how many were
  actually faulted;
- **recall** — of the faulted nodes, how many the policy caught;
- **time-to-detect** — first detection minus injection, per victim
  (clock-relative: sim seconds in the simulator, virtual Clock seconds
  in the runtime — comparable within a world, waived across worlds,
  §18.5);
- **wasted backup work** — work sunk into speculative attempts that
  lost their race (ended KILLED/FAILED).

``mode="mark"`` restricts detections to node-failure verdicts — the
cross-world comparable core (sim and FakeClock runtime traces of the
same script must agree on tp/fp/fn and precision/recall;
tests/test_obs.py pins this). ``mode="any"`` additionally counts
straggler speculations/kills against the slow node as detections —
the right lens for slowdown faults, where no failure verdict ever
fires.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

from repro.obs.trace import (
    END_COMPLETED,
    END_FAILED,
    END_KILLED,
    K_ACTION,
    K_ATT_END,
    K_DETECT,
    K_FAULT,
    NODE_FAULT_CODES,
    TraceRecorder,
)


def attempt_outcomes(rec: TraceRecorder) -> List[Dict[str, Any]]:
    """Per-attempt ground-truth table from the lifecycle + fault planes.

    One row per ``K_ATT_END`` record, in emission order, classified
    against the injected node faults:

    - ``failed`` — the attempt ended FAILED (its node died under it);
    - ``straggled`` — the attempt was reaped (ended KILLED — a sibling
      won its race) on a node that had a fault injected before it
      ended. The fault anchor matters: a KILLED attempt on a
      never-faulted node merely *lost a race* (the winner launched
      later and tied-or-beat it on equal hardware) and is ``clean`` —
      labeling those as stragglers teaches a predictor that every
      long-running tail task is slow (DESIGN.md §20);
    - ``clean`` — everything else.

    Exactly one of the three flags is set per row. This is the single
    labeling code path shared by predictor dataset generation
    (repro.predict.dataset) and the scorecard's wasted-backup
    accounting — post-hoc trace joins only, never tick-time state
    (DESIGN.md §20 leakage rule).
    """
    victims: Dict[int, float] = {}
    for r in rec.by_kind(K_FAULT):
        if int(r["b"]) in NODE_FAULT_CODES and int(r["a"]) >= 0:
            victims.setdefault(int(r["a"]), float(r["time"]))
    rows: List[Dict[str, Any]] = []
    for r, aid in rec.iter_with_objs(K_ATT_END):
        node = int(r["a"])
        end_code = int(r["b"])
        end = float(r["time"])
        fault_time: Optional[float] = victims.get(node)
        on_faulted = fault_time is not None and fault_time <= end
        failed = end_code == END_FAILED
        straggled = not failed and end_code == END_KILLED and on_faulted
        rows.append({
            "attempt_id": aid,
            "node": node,
            "end_code": end_code,
            "start": float(r["f0"]),
            "end": end,
            "work": float(r["f1"]),
            "speculative": bool(float(r["f2"])),
            "fault_time": fault_time if on_faulted else None,
            "failed": failed,
            "straggled": straggled,
            "clean": not failed and not straggled,
        })
    return rows


def scorecard(rec: TraceRecorder, *, policy: str = "",
              mode: str = "mark") -> Dict[str, Any]:
    """Join fault ground truth against detection records."""
    if mode not in ("mark", "any"):
        raise ValueError(f"unknown scorecard mode: {mode}")
    # ground truth: first injection time per node victim
    victims: Dict[int, float] = {}
    n_faults = 0
    for r in rec.by_kind(K_FAULT):
        n_faults += 1
        if int(r["b"]) in NODE_FAULT_CODES and int(r["a"]) >= 0:
            victims.setdefault(int(r["a"]), float(r["time"]))
    # detections: first verdict time per node
    detections: Dict[int, float] = {}
    for r in rec.by_kind(K_DETECT):
        detections.setdefault(int(r["a"]), float(r["time"]))
    n_speculations = 0
    for r in rec.by_kind(K_ACTION):
        if int(r["b"]) != 1:  # ACT_MARK_FAILED already covered by detect
            n_speculations += 1
            if mode == "any" and int(r["a"]) >= 0:
                detections.setdefault(int(r["a"]), float(r["time"]))
    tp = sorted(set(victims) & set(detections))
    fp = sorted(set(detections) - set(victims))
    fn = sorted(set(victims) - set(detections))
    # vacuous cases score 1.0: no detections ⇒ nothing falsely accused,
    # no victims ⇒ nothing missed
    precision = len(tp) / (len(tp) + len(fp)) if detections else 1.0
    recall = len(tp) / (len(tp) + len(fn)) if victims else 1.0
    ttd = {i: detections[i] - victims[i] for i in tp}
    wasted = 0.0
    n_backups = 0
    for o in attempt_outcomes(rec):
        if o["speculative"]:
            n_backups += 1
            if o["end_code"] != END_COMPLETED:
                wasted += o["work"]
    return {
        "policy": policy,
        "mode": mode,
        "n_faults": n_faults,
        "victims": sorted(victims),
        "tp": tp,
        "fp": fp,
        "fn": fn,
        "precision": round(precision, 6),
        "recall": round(recall, 6),
        "ttd": {int(k): round(v, 6) for k, v in sorted(ttd.items())},
        "mean_ttd": round(sum(ttd.values()) / len(ttd), 6) if ttd
        else None,
        "n_speculations": n_speculations,
        "n_backups": n_backups,
        "wasted_backup_work": round(wasted, 6),
    }


def comparable_core(card: Dict[str, Any]) -> Dict[str, Any]:
    """The cross-world-identical subset of a scorecard: index sets and
    ratios only — time-to-detect and work are clock-relative and waived
    across worlds (DESIGN.md §18.5)."""
    return {k: card[k] for k in
            ("victims", "tp", "fp", "fn", "precision", "recall")}
