"""Speculation scorecard: detection quality from a flight-recorder trace
(DESIGN.md §18.5).

The chaos/fault scripts give perfect ground truth — every injected
node fault lands as a ``K_FAULT`` record at its actual fire time, with
the victim's node index. Policy failure verdicts land as ``K_DETECT``
records (``b=1`` policy-marked via Eq. 4 / MarkNodeFailed, ``b=0``
liveness-expiry declared). Joining the two planes yields the
scheduler-survey detection metrics no per-run counter could produce:

- **precision** — of the nodes a policy declared failed, how many were
  actually faulted;
- **recall** — of the faulted nodes, how many the policy caught;
- **time-to-detect** — first detection minus injection, per victim
  (clock-relative: sim seconds in the simulator, virtual Clock seconds
  in the runtime — comparable within a world, waived across worlds,
  §18.5);
- **wasted backup work** — work sunk into speculative attempts that
  lost their race (ended KILLED/FAILED).

``mode="mark"`` restricts detections to node-failure verdicts — the
cross-world comparable core (sim and FakeClock runtime traces of the
same script must agree on tp/fp/fn and precision/recall;
tests/test_obs.py pins this). ``mode="any"`` additionally counts
straggler speculations/kills against the slow node as detections —
the right lens for slowdown faults, where no failure verdict ever
fires.
"""
from __future__ import annotations

from typing import Any, Dict

from repro.obs.trace import (
    END_COMPLETED,
    K_ACTION,
    K_ATT_END,
    K_DETECT,
    K_FAULT,
    NODE_FAULT_CODES,
    TraceRecorder,
)


def scorecard(rec: TraceRecorder, *, policy: str = "",
              mode: str = "mark") -> Dict[str, Any]:
    """Join fault ground truth against detection records."""
    if mode not in ("mark", "any"):
        raise ValueError(f"unknown scorecard mode: {mode}")
    # ground truth: first injection time per node victim
    victims: Dict[int, float] = {}
    n_faults = 0
    for r in rec.by_kind(K_FAULT):
        n_faults += 1
        if int(r["b"]) in NODE_FAULT_CODES and int(r["a"]) >= 0:
            victims.setdefault(int(r["a"]), float(r["time"]))
    # detections: first verdict time per node
    detections: Dict[int, float] = {}
    for r in rec.by_kind(K_DETECT):
        detections.setdefault(int(r["a"]), float(r["time"]))
    n_speculations = 0
    for r in rec.by_kind(K_ACTION):
        if int(r["b"]) != 1:  # ACT_MARK_FAILED already covered by detect
            n_speculations += 1
            if mode == "any" and int(r["a"]) >= 0:
                detections.setdefault(int(r["a"]), float(r["time"]))
    tp = sorted(set(victims) & set(detections))
    fp = sorted(set(detections) - set(victims))
    fn = sorted(set(victims) - set(detections))
    # vacuous cases score 1.0: no detections ⇒ nothing falsely accused,
    # no victims ⇒ nothing missed
    precision = len(tp) / (len(tp) + len(fp)) if detections else 1.0
    recall = len(tp) / (len(tp) + len(fn)) if victims else 1.0
    ttd = {i: detections[i] - victims[i] for i in tp}
    wasted = 0.0
    n_backups = 0
    for r in rec.by_kind(K_ATT_END):
        if float(r["f2"]):  # speculative attempt
            n_backups += 1
            if int(r["b"]) != END_COMPLETED:
                wasted += float(r["f1"])
    return {
        "policy": policy,
        "mode": mode,
        "n_faults": n_faults,
        "victims": sorted(victims),
        "tp": tp,
        "fp": fp,
        "fn": fn,
        "precision": round(precision, 6),
        "recall": round(recall, 6),
        "ttd": {int(k): round(v, 6) for k, v in sorted(ttd.items())},
        "mean_ttd": round(sum(ttd.values()) / len(ttd), 6) if ttd
        else None,
        "n_speculations": n_speculations,
        "n_backups": n_backups,
        "wasted_backup_work": round(wasted, 6),
    }


def comparable_core(card: Dict[str, Any]) -> Dict[str, Any]:
    """The cross-world-identical subset of a scorecard: index sets and
    ratios only — time-to-detect and work are clock-relative and waived
    across worlds (DESIGN.md §18.5)."""
    return {k: card[k] for k in
            ("victims", "tp", "fp", "fn", "precision", "recall")}
