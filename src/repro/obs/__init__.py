"""repro.obs — flight recorder, metrics plane, exporters, scorecards
(DESIGN.md §18).

One trace schema, two worlds: the simulator and the live runtime emit
identical structured-numpy records through a :class:`TraceRecorder`
(near-zero cost when absent — one ``is not None`` branch per site),
the :class:`MetricsRegistry` replaces scattered benchmark timers, and
the exporters/scorecard turn traces into Perfetto timelines and
detection-quality numbers.
"""
from repro.obs.export import to_chrome_trace, trace_diff, write_chrome_trace
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Timer,
    instrument_drain,
)
from repro.obs.scorecard import attempt_outcomes, comparable_core, scorecard
from repro.obs.trace import (
    ACT_KILL,
    ACT_MARK_FAILED,
    ACT_SPECULATE,
    END_COMPLETED,
    END_FAILED,
    END_KILLED,
    FAULT_CODES,
    K_ACTION,
    K_ATT_END,
    K_ATT_START,
    K_BUDGET,
    K_CHECKPOINT,
    K_DETECT,
    K_DISPATCH,
    K_DRAIN,
    K_FAULT,
    K_FETCH_FAIL,
    K_FLOW_BULK,
    K_FLOW_CLOSE,
    K_FLOW_OPEN,
    K_GLANCE_FAIL,
    K_GLANCE_SPATIAL,
    K_GLANCE_TEMPORAL,
    K_LATE,
    K_PREDICT,
    K_RAMP,
    K_ROLLBACK,
    K_THRESH,
    KIND_NAMES,
    NODE_FAULT_CODES,
    TRACE_DTYPE,
    TraceRecorder,
)

__all__ = [
    "TraceRecorder", "TRACE_DTYPE", "KIND_NAMES", "FAULT_CODES",
    "NODE_FAULT_CODES",
    "K_ACTION", "K_DETECT", "K_GLANCE_SPATIAL", "K_GLANCE_TEMPORAL",
    "K_GLANCE_FAIL", "K_THRESH", "K_LATE", "K_ATT_START", "K_ATT_END",
    "K_DRAIN", "K_FLOW_OPEN", "K_FLOW_CLOSE", "K_FLOW_BULK", "K_FAULT",
    "K_ROLLBACK", "K_CHECKPOINT", "K_RAMP", "K_DISPATCH", "K_FETCH_FAIL",
    "K_BUDGET", "K_PREDICT",
    "ACT_MARK_FAILED", "ACT_SPECULATE", "ACT_KILL",
    "END_COMPLETED", "END_FAILED", "END_KILLED",
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "Timer",
    "instrument_drain",
    "to_chrome_trace", "write_chrome_trace", "trace_diff",
    "scorecard", "comparable_core", "attempt_outcomes",
]
