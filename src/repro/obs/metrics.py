"""Metrics registry: counters / gauges / histograms / timers with one
``snapshot()`` read API (DESIGN.md §18.3).

Replaces the scattered one-off accumulators the perf benchmarks grew —
PR 7's ``attach_drain_timer`` dict lives here now as
:func:`instrument_drain` — and gives the live coordinator a place to
count recovery work that both ``benchmarks/perf_runtime.py`` and tests
can read without reaching into internals.
"""
from __future__ import annotations

import time
from typing import Dict, Optional


class Counter:
    __slots__ = ("n",)

    def __init__(self):
        self.n = 0

    def inc(self, by: int = 1) -> None:
        self.n += by


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Streaming count/sum/min/max — enough for the benchmark tables
    without keeping samples around."""

    __slots__ = ("n", "total", "min", "max")

    def __init__(self):
        self.n = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        self.n += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0


class Timer:
    """Wall-clock accumulator. Use as a context manager or wrap callables
    with :meth:`wrap`."""

    __slots__ = ("s", "n", "_t0")

    def __init__(self):
        self.s = 0.0
        self.n = 0
        self._t0 = 0.0

    def __enter__(self) -> "Timer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.s += time.perf_counter() - self._t0
        self.n += 1

    def wrap(self, fn):
        if fn is None:
            return None

        def timed(*a):
            t0 = time.perf_counter()
            try:
                return fn(*a)
            finally:
                self.s += time.perf_counter() - t0
                self.n += 1
        return timed


class MetricsRegistry:
    """Named instrument registry; ``snapshot()`` flattens everything into
    one ``{name: number}`` dict (histograms/timers expand to ``_n`` /
    ``_s`` / ``_mean`` ... suffixed keys)."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}
        self._timers: Dict[str, Timer] = {}

    def counter(self, name: str) -> Counter:
        return self._counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        return self._gauges.setdefault(name, Gauge())

    def histogram(self, name: str) -> Histogram:
        return self._hists.setdefault(name, Histogram())

    def timer(self, name: str) -> Timer:
        return self._timers.setdefault(name, Timer())

    def snapshot(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for k, c in self._counters.items():
            out[k] = c.n
        for k, g in self._gauges.items():
            out[k] = g.value
        for k, h in self._hists.items():
            out[f"{k}_n"] = h.n
            out[f"{k}_sum"] = h.total
            out[f"{k}_mean"] = h.mean()
            if h.n:
                out[f"{k}_min"] = h.min
                out[f"{k}_max"] = h.max
        for k, t in self._timers.items():
            out[f"{k}_s"] = t.s
            out[f"{k}_n"] = t.n
        return out


def instrument_drain(sim, registry: Optional[MetricsRegistry] = None,
                     *, name: str = "drain") -> MetricsRegistry:
    """Wrap the calendar lane's drain path — the fused/generic loop plus
    its ``on_begin``/``on_end`` brackets (the ε-fair recompute/rebuild
    lives in the brackets, so they are part of the drain's cost) — with a
    registry timer. Promoted from PR 7's ``attach_drain_timer`` one-off;
    read the cost back as ``registry.snapshot()["<name>_s"]``. Call after
    the simulation is fully constructed: engine wiring installs the
    brackets at ``Simulation.__init__`` time. Rescan/event substrates
    have no calendar lane; no timer is registered then."""
    reg = registry if registry is not None else MetricsRegistry()
    q = getattr(sim.shuffle, "batches", None)
    if q is None:
        return reg
    t = reg.timer(name)
    q._drain_impl = t.wrap(q._drain_impl)
    q.on_begin = t.wrap(q.on_begin)
    q.on_end = t.wrap(q.on_end)
    return reg
