"""Mamba-2 (SSD) block: projections + causal depthwise conv + chunked SSD
scan + gated RMSNorm + output projection, plus the single-token decode
recurrence. The scan itself lives in ``repro.kernels.ssd``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.ssd.ops import ssd, ssd_decode_step, ssd_with_state
from repro.models.layers import ParamFactory, split_tree
from repro.parallel.sharding import constrain

Params = Dict[str, Any]


def init_mamba(cfg: ModelConfig, f: ParamFactory):
    assert cfg.ssm is not None
    s = cfg.ssm
    d = cfg.d_model
    din = s.d_inner(d)
    nh = s.n_heads(d)
    gs = s.n_groups * s.d_state
    conv_dim = din + 2 * gs
    pairs = {
        "wz": f.normal((d, din), ("embed", "mamba_inner")),
        "wx": f.normal((d, din), ("embed", "mamba_inner")),
        "wB": f.normal((d, gs), ("embed", "mamba_group_state")),
        "wC": f.normal((d, gs), ("embed", "mamba_group_state")),
        "wdt": f.normal((d, nh), ("embed", "mamba_heads")),
        "dt_bias": f.zeros((nh,), ("mamba_heads",)),
        # A ∈ [-A_max, 0): init A_log ~ U(log 1, log 16) per mamba-2 defaults
        "A_log": f.const(
            jnp.log(jnp.linspace(1.0, 16.0, nh)), ("mamba_heads",)),
        "D": f.ones((nh,), ("mamba_heads",)),
        "conv_w": f.normal((s.conv_kernel, conv_dim), (None, None),
                           scale=s.conv_kernel ** -0.5),
        "conv_b": f.zeros((conv_dim,), (None,)),
        "gate_norm": f.ones((din,), ("mamba_inner",)),
        "wo": f.normal((din, d), ("mamba_inner", "embed")),
    }
    return split_tree(pairs)


def _split_xbc(cfg: ModelConfig, xbc: jax.Array):
    s = cfg.ssm
    din = s.d_inner(cfg.d_model)
    gs = s.n_groups * s.d_state
    x = xbc[..., :din]
    B = xbc[..., din:din + gs]
    C = xbc[..., din + gs:]
    return x, B, C


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 prev: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv1d. xbc: (b, s, c); w: (k, c); prev: (b, k-1, c)
    carry-in state (decode/chunk handoff)."""
    k = w.shape[0]
    if prev is None:
        prev = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    xpad = jnp.concatenate([prev, xbc], axis=1)
    out = jnp.zeros_like(xbc, shape=xbc.shape).astype(jnp.float32)
    for i in range(k):  # k is 4: unrolled taps beat a conv op at this size
        out = out + xpad[:, i:i + xbc.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    out = out + b.astype(jnp.float32)
    return jax.nn.silu(out).astype(xbc.dtype)


def _gated_norm(y: jax.Array, z: jax.Array, scale: jax.Array,
                eps: float) -> jax.Array:
    """RMSNorm(y * silu(z)) — the mamba-2 gated normalization."""
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def mamba_block(cfg: ModelConfig, p: Params, h: jax.Array, *,
                impl: str = "ref", return_state: bool = False):
    """Full-sequence mamba mixer. h: (b, s, d). Returns (out, cache|None)
    where cache = {'conv': (b, k-1, c), 'state': (b, nh, hd, N)}."""
    s = cfg.ssm
    din = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)

    z = jnp.einsum("bsd,di->bsi", h, p["wz"])
    xr = jnp.einsum("bsd,di->bsi", h, p["wx"])
    Br = jnp.einsum("bsd,dg->bsg", h, p["wB"])
    Cr = jnp.einsum("bsd,dg->bsg", h, p["wC"])
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", h, p["wdt"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))

    xbc = jnp.concatenate([xr, Br, Cr], axis=-1)
    conv_tail = xbc[:, -(s.conv_kernel - 1):, :]
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    x, B, C = _split_xbc(cfg, xbc)

    bsz, slen = h.shape[0], h.shape[1]
    x = constrain(x.reshape(bsz, slen, nh, s.head_dim),
                  "batch", "seq", "mamba_heads", "head_dim")
    B = B.reshape(bsz, slen, s.n_groups, s.d_state)
    C = C.reshape(bsz, slen, s.n_groups, s.d_state)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if return_state:
        y, state = ssd_with_state(x, dt, A, B, C, p["D"],
                                  chunk=s.chunk_size, impl=impl)
    else:
        y = ssd(x, dt, A, B, C, p["D"], chunk=s.chunk_size, impl=impl)
        state = None
    y = y.reshape(bsz, slen, din)
    y = _gated_norm(y, z, p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["wo"])
    out = constrain(out, "batch", "seq", "embed")
    cache = None
    if return_state:
        cache = {"conv": conv_tail, "state": state}
    return out, cache


def mamba_decode(cfg: ModelConfig, p: Params, h: jax.Array,
                 cache: Dict[str, jax.Array]):
    """Single-token step. h: (b, 1, d); cache from ``mamba_block``/init."""
    s = cfg.ssm
    din = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    b = h.shape[0]

    z = jnp.einsum("bsd,di->bsi", h, p["wz"])[:, 0]
    xr = jnp.einsum("bsd,di->bsi", h, p["wx"])[:, 0]
    Br = jnp.einsum("bsd,dg->bsg", h, p["wB"])[:, 0]
    Cr = jnp.einsum("bsd,dg->bsg", h, p["wC"])[:, 0]
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", h, p["wdt"]).astype(jnp.float32)[:, 0]
        + p["dt_bias"].astype(jnp.float32))

    xbc_t = jnp.concatenate([xr, Br, Cr], axis=-1)       # (b, c)
    window = jnp.concatenate([cache["conv"], xbc_t[:, None]], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))
    x, B, C = _split_xbc(cfg, conv_out.astype(h.dtype))

    x = x.reshape(b, nh, s.head_dim)
    B = B.reshape(b, s.n_groups, s.d_state)
    C = C.reshape(b, s.n_groups, s.d_state)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    new_state, y = ssd_decode_step(cache["state"], x, dt, A, B, C, p["D"])
    y = y.reshape(b, din)
    y = _gated_norm(y, z, p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bi,id->bd", y, p["wo"])[:, None]
    new_cache = {"conv": window[:, 1:], "state": new_state}
    return out, new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    s = cfg.ssm
    din = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    conv_dim = din + 2 * s.n_groups * s.d_state
    return {
        "conv": jnp.zeros((batch, s.conv_kernel - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
    }
