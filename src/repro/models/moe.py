"""Top-k routed mixture-of-experts, TPU-native "dropping" formulation.

Dispatch is scatter-based (token → (expert, capacity-slot)) rather than the
GShard dense-dispatch einsum: the (tokens × experts × capacity) one-hot of
the einsum form is quadratic in tokens and cannot be materialized at
1M-token global batches, whereas the scatter buffer is
(experts × capacity × d_model) — linear — and shards as
(expert → model, capacity → (pod, data)), turning the dispatch into an
XLA-SPMD all-to-all across the data axis plus expert parallelism on the
model axis. Overflow beyond capacity is dropped (standard "token dropping";
capacity_factor controls the head-room).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamFactory, split_tree
from repro.parallel.sharding import constrain

Params = Dict[str, Any]


def init_moe(cfg: ModelConfig, f: ParamFactory):
    assert cfg.moe is not None
    m = cfg.moe
    d, ff, e = cfg.d_model, m.d_ff_expert, m.n_experts
    pairs = {
        "router": f.normal((d, e), ("embed", "expert"), scale=d ** -0.5),
    }
    if cfg.mlp_act == "swiglu":
        pairs.update({
            "w_gate": f.normal((e, d, ff), ("expert", "embed", "expert_mlp")),
            "w_up": f.normal((e, d, ff), ("expert", "embed", "expert_mlp")),
            "w_down": f.normal((e, ff, d), ("expert", "expert_mlp", "embed"),
                               scale=ff ** -0.5),
        })
    else:
        pairs.update({
            "w_in": f.normal((e, d, ff), ("expert", "embed", "expert_mlp")),
            "w_out": f.normal((e, ff, d), ("expert", "expert_mlp", "embed"),
                              scale=ff ** -0.5),
        })
    return split_tree(pairs)


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    cap = int(math.ceil(m.top_k * n_tokens * m.capacity_factor / m.n_experts))
    # keep the buffer shardable over the batch axes and lane-aligned
    return max(128, -(-cap // 128) * 128)


def moe_block(cfg: ModelConfig, p: Params, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (b, s, d) → (out (b, s, d), aux_loss scalar)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    cap = _capacity(t, cfg)

    logits = jnp.einsum("td,de->te", xf, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                       # (t, e)
    gate, eid = jax.lax.top_k(probs, m.top_k)                      # (t, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)            # renorm

    # Switch-style load-balance auxiliary loss.
    me = jnp.mean(probs, axis=0)                                   # (e,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(eid, m.n_experts, dtype=jnp.float32), axis=1),
        axis=0)
    aux = m.n_experts * jnp.sum(me * ce) * m.router_aux_weight

    # position of each (token, slot) within its expert queue
    eflat = eid.reshape(-1)                                        # (t·k,)
    onehot = jax.nn.one_hot(eflat, m.n_experts, dtype=jnp.int32)   # (t·k, e)
    pos = jnp.cumsum(onehot, axis=0) - 1                           # (t·k, e)
    pos = jnp.sum(pos * onehot, axis=-1)                           # (t·k,)
    keep = pos < cap
    slot = jnp.where(keep, pos, 0)

    xk = jnp.repeat(xf[:, None, :], m.top_k, axis=1).reshape(-1, d)
    buf = jnp.zeros((m.n_experts, cap, d), x.dtype)
    buf = buf.at[eflat, slot].add(
        jnp.where(keep[:, None], xk, 0).astype(x.dtype), mode="drop")
    buf = constrain(buf, "expert", "expert_cap", None)

    # expert FFN (batched over the expert dim)
    if cfg.mlp_act == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
        u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
        y = constrain(jax.nn.silu(g) * u, "expert", "expert_cap", None)
        out_buf = jnp.einsum("ecf,efd->ecd", y, p["w_down"])
    else:
        y = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, p["w_in"]))
        y = constrain(y, "expert", "expert_cap", None)
        out_buf = jnp.einsum("ecf,efd->ecd", y, p["w_out"])
    out_buf = constrain(out_buf, "expert", "expert_cap", None)

    # combine: gather each slot back and weight by the (renormalized) gate
    gathered = out_buf[eflat, slot]                                # (t·k, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    gathered = gathered.reshape(t, m.top_k, d)
    out = jnp.sum(gathered * gate[..., None].astype(x.dtype), axis=1)
    return out.reshape(b, s, d), aux
