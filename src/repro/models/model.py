"""Model builder: one entry point for all ten assigned architectures.

Families:
- dense / moe / audio / vlm — homogeneous decoder/encoder stack, one
  ``lax.scan`` over stacked layer params (compact HLO, fast compiles).
- ssm — homogeneous Mamba-2 stack.
- hybrid (Jamba) — ``lax.scan`` over *blocks* (block = ``block_len`` layers
  with a fixed attn/mamba + dense/MoE pattern; pattern is static per block
  because ``block_len`` is even and the MoE period divides it).

``init_params`` materializes weights; ``param_shapes``/``param_axes``
produce ShapeDtypeStruct / logical-axis trees of the SAME structure without
allocating — the dry-run path for 400B-scale configs.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import moe as MOE
from repro.parallel.sharding import constrain

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Abstract factory: same init code path, zero allocation.
# ---------------------------------------------------------------------------
class AbstractFactory(L.ParamFactory):
    def __init__(self, dtype):
        super().__init__(jax.random.PRNGKey(0), dtype)

    def normal(self, shape, axes, scale=None):
        return jax.ShapeDtypeStruct(shape, self.dtype), axes

    def zeros(self, shape, axes):
        return jax.ShapeDtypeStruct(shape, self.dtype), axes

    def ones(self, shape, axes):
        return jax.ShapeDtypeStruct(shape, self.dtype), axes

    def const(self, value, axes):
        return jax.ShapeDtypeStruct(value.shape, self.dtype), axes


def _stack(leaves):
    first = leaves[0]
    n = len(leaves)
    if isinstance(first, jax.ShapeDtypeStruct):
        return jax.ShapeDtypeStruct((n, *first.shape), first.dtype)
    return jnp.stack(leaves)


def _stack_trees(trees):
    return jax.tree.map(lambda *xs: _stack(list(xs)), *trees)


def _prepend_axis(axes_tree, name="layers"):
    return jax.tree.map(
        lambda a: (name, *a),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            e is None or isinstance(e, str) for e in x),
    )


# ---------------------------------------------------------------------------
# Per-layer init
# ---------------------------------------------------------------------------
def _init_uniform_layer(cfg: ModelConfig, f: L.ParamFactory):
    pairs = {"ln1": L.init_norm(cfg, f)}
    if cfg.family == "ssm":
        pairs["mixer"] = M.init_mamba(cfg, f)
        return L.split_tree(pairs)
    pairs["mixer"] = L.init_attention(cfg, f)
    pairs["ln2"] = L.init_norm(cfg, f)
    if cfg.moe is not None and cfg.moe.period == 1:
        pairs["ffn"] = MOE.init_moe(cfg, f)
    else:
        pairs["ffn"] = L.init_mlp(cfg, f)
    return L.split_tree(pairs)


def _init_hybrid_block(cfg: ModelConfig, f: L.ParamFactory):
    hb = cfg.hybrid
    mambas, mamba_axes = [], None
    ffns_mlp, ffns_moe = [], []
    lns, ln_axes = [], None
    pairs: Dict[str, Any] = {}
    for j in range(hb.block_len):
        ln1 = L.init_norm(cfg, f)
        ln2 = L.init_norm(cfg, f)
        lns.append(_stack_trees([ln1[0], ln2[0]]))
        ln_axes = ln1[1]
        if hb.layer_kind(j) == ATTN:
            pairs["attn"] = L.init_attention(cfg, f)
        else:
            mp, ma = M.init_mamba(cfg, f)
            mambas.append(mp)
            mamba_axes = ma
        if cfg.moe is not None and cfg.moe.is_moe_layer(j):
            mo, moa = MOE.init_moe(cfg, f)
            ffns_moe.append(mo)
            moe_axes = moa
        else:
            ml, mla = L.init_mlp(cfg, f)
            ffns_mlp.append(ml)
            mlp_axes = mla
    params = {
        "attn": pairs["attn"][0],
        "mamba": _stack_trees(mambas),
        "moe": _stack_trees(ffns_moe),
        "mlp": _stack_trees(ffns_mlp),
        "lns": _stack_trees(lns),
    }
    axes = {
        "attn": pairs["attn"][1],
        "mamba": _prepend_axis(mamba_axes),
        "moe": _prepend_axis(moe_axes),
        "mlp": _prepend_axis(mlp_axes),
        "lns": _prepend_axis(_prepend_axis(ln_axes, "norm_pair"), "layers"),
    }
    return params, axes


def _build(cfg: ModelConfig, f: L.ParamFactory) -> Tuple[Params, Params]:
    d, v = cfg.d_model, cfg.vocab_size
    pairs: Dict[str, Any] = {}
    pairs["embed"] = f.normal((v, d), ("vocab", "embed"), scale=1.0)
    if cfg.frontend is not None:
        pairs["frontend"] = L.split_tree({
            "w": f.normal((cfg.frontend.feature_dim, d),
                          ("frontend_feature", "embed")),
        })
    if cfg.hybrid is not None:
        n_blocks = cfg.n_layers // cfg.hybrid.block_len
        blocks = [_init_hybrid_block(cfg, f) for _ in range(n_blocks)]
        pairs["blocks"] = (_stack_trees([b[0] for b in blocks]),
                           _prepend_axis(blocks[0][1]))
    else:
        layers_ = [_init_uniform_layer(cfg, f) for _ in range(cfg.n_layers)]
        pairs["layers"] = (_stack_trees([p for p, _ in layers_]),
                           _prepend_axis(layers_[0][1]))
    pairs["final_norm"] = L.init_norm(cfg, f)
    if not cfg.tie_embeddings:
        pairs["lm_head"] = f.normal((v, d), ("vocab", "embed"))
    return L.split_tree(pairs)


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    return _build(cfg, L.ParamFactory(key, dtype))[0]


def param_shapes(cfg: ModelConfig) -> Params:
    return _build(cfg, AbstractFactory(jnp.dtype(cfg.param_dtype)))[0]


def param_axes(cfg: ModelConfig) -> Params:
    return _build(cfg, AbstractFactory(jnp.dtype(cfg.param_dtype)))[1]


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------
def _embed_inputs(cfg: ModelConfig, params: Params, batch: Dict[str, Any]):
    adt = jnp.dtype(cfg.activation_dtype)
    if cfg.family == "audio":
        h = jnp.einsum("bsf,fd->bsd", batch["feats"].astype(adt),
                       params["frontend"]["w"])
    elif cfg.family == "vlm":
        text = params["embed"][batch["tokens"]].astype(adt)
        patches = jnp.einsum("bpf,fd->bpd", batch["feats"].astype(adt),
                             params["frontend"]["w"])
        h = jnp.concatenate([patches, text], axis=1)
    else:
        h = params["embed"][batch["tokens"]].astype(adt)
    return constrain(h, "batch", "seq", "embed")


def _lm_head(cfg: ModelConfig, params: Params, h: jax.Array) -> jax.Array:
    w = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", h, w)
    return constrain(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------
def _uniform_layer_fwd(cfg, impl, collect_cache, h, lp, positions):
    aux = jnp.zeros((), jnp.float32)
    x = L.apply_norm(cfg, lp["ln1"], h)
    if cfg.family == "ssm":
        out, cache = M.mamba_block(cfg, lp["mixer"], x, impl=impl,
                                   return_state=collect_cache)
        h = h + out
        return h, aux, cache
    out, kv = L.attention_block(cfg, lp["mixer"], x, positions=positions,
                                impl=impl)
    h = h + out
    x2 = L.apply_norm(cfg, lp["ln2"], h)
    if cfg.moe is not None and cfg.moe.period == 1:
        ffn_out, aux = MOE.moe_block(cfg, lp["ffn"], x2)
    else:
        ffn_out = L.mlp_block(cfg, lp["ffn"], x2)
    h = h + ffn_out
    return h, aux, (kv if collect_cache else None)


def _hybrid_block_fwd(cfg, impl, collect_cache, h, bp, positions):
    hb = cfg.hybrid
    aux = jnp.zeros((), jnp.float32)
    caches: Dict[str, Any] = {"mamba": [], "attn": None}
    mi = 0
    n_moe = 0
    n_mlp = 0
    for j in range(hb.block_len):
        lns = jax.tree.map(lambda x: x[j], bp["lns"])
        x = L.apply_norm(cfg, jax.tree.map(lambda t: t[0], lns), h)
        if hb.layer_kind(j) == ATTN:
            out, kv = L.attention_block(cfg, bp["attn"], x,
                                        positions=positions, impl=impl)
            if collect_cache:
                caches["attn"] = kv
        else:
            mp = jax.tree.map(lambda t: t[mi], bp["mamba"])
            out, mc = M.mamba_block(cfg, mp, x, impl=impl,
                                    return_state=collect_cache)
            if collect_cache:
                caches["mamba"].append(mc)
            mi += 1
        h = h + out
        x2 = L.apply_norm(cfg, jax.tree.map(lambda t: t[1], lns), h)
        if cfg.moe is not None and cfg.moe.is_moe_layer(j):
            mo = jax.tree.map(lambda t: t[n_moe], bp["moe"])
            ffn_out, a = MOE.moe_block(cfg, mo, x2)
            aux = aux + a
            n_moe += 1
        else:
            ml = jax.tree.map(lambda t: t[n_mlp], bp["mlp"])
            ffn_out = L.mlp_block(cfg, ml, x2)
            n_mlp += 1
        h = h + ffn_out
    if collect_cache and caches["mamba"]:
        caches["mamba"] = _stack_trees(caches["mamba"])
    return h, aux, (caches if collect_cache else None)


_REMAT_POLICIES = {
    "none": None,
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
}


def forward(
    cfg: ModelConfig,
    params: Params,
    batch: Dict[str, Any],
    *,
    impl: str = "ref",
    remat: str = "none",
    collect_cache: bool = False,
    unroll: bool = False,
) -> Tuple[jax.Array, jax.Array, Optional[Any]]:
    """Returns (logits (b,s,v), moe_aux_loss, caches|None).

    ``unroll=True`` replaces the layer scan with a Python loop — used by the
    dry-run's cost probes (XLA cost analysis counts a while-loop body once,
    so probes must be loop-free) and available as a perf knob.
    """
    h = _embed_inputs(cfg, params, batch)
    positions = jnp.arange(h.shape[1])

    if cfg.hybrid is not None:
        body_fn = functools.partial(_hybrid_block_fwd, cfg, impl,
                                    collect_cache)
        stacked = params["blocks"]
        n_steps = cfg.n_layers // cfg.hybrid.block_len
    else:
        body_fn = functools.partial(_uniform_layer_fwd, cfg, impl,
                                    collect_cache)
        stacked = params["layers"]
        n_steps = cfg.n_layers

    def scan_body(carry, lp):
        h, aux = carry
        h, a, cache = body_fn(h, lp, positions)
        return (h, aux + a), cache

    if remat != "none":
        policy = _REMAT_POLICIES[remat]
        scan_body = jax.checkpoint(
            scan_body, policy=policy, prevent_cse=False)

    carry = (h, jnp.zeros((), jnp.float32))
    if unroll:
        caches_list = []
        for i in range(n_steps):
            lp = jax.tree.map(lambda x: x[i], stacked)
            carry, cache = scan_body(carry, lp)
            caches_list.append(cache)
        caches = (_stack_trees(caches_list)
                  if collect_cache and caches_list[0] is not None else None)
        h, aux = carry
    else:
        (h, aux), caches = jax.lax.scan(scan_body, carry, stacked)
    h = L.apply_norm(cfg, params["final_norm"], h)
    logits = _lm_head(cfg, params, h)
    return logits, aux, caches


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------
def _pad_kv(kv: Dict[str, jax.Array], max_len: int):
    def pad(x):
        pad_len = max_len - x.shape[2]
        return jnp.pad(x, ((0, 0), (0, 0), (0, pad_len), (0, 0), (0, 0)))
    # kv leaves: (layers, b, s, kv_heads, hd)
    return jax.tree.map(pad, kv)


def prefill(cfg: ModelConfig, params: Params, batch: Dict[str, Any], *,
            max_len: Optional[int] = None, impl: str = "ref",
            unroll: bool = False):
    """Run the prompt through the model; returns (last-token logits, cache).

    The attention KV cache is padded out to ``max_len`` so decode can append.
    """
    logits, _, caches = forward(cfg, params, batch, impl=impl,
                                collect_cache=True, unroll=unroll)
    seq_len = logits.shape[1]
    if max_len is None:
        max_len = seq_len
    if cfg.hybrid is not None:
        kv = caches["attn"]
        kv = _pad_kv(kv, max_len) if max_len > seq_len else kv
        cache = {"attn": kv, "mamba": caches["mamba"]}
    elif cfg.family == "ssm":
        cache = {"mamba": caches}
    else:
        kv = _pad_kv(caches, max_len) if max_len > seq_len else caches
        cache = {"attn": kv}
    return logits[:, -1], cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """Zero-filled decode cache (the decode dry-run's input spec)."""
    adt = jnp.dtype(cfg.activation_dtype)
    hd = cfg.resolved_head_dim()

    def kv(n):
        return {
            "k": jnp.zeros((n, batch, max_len, cfg.n_kv_heads, hd), adt),
            "v": jnp.zeros((n, batch, max_len, cfg.n_kv_heads, hd), adt),
        }

    if cfg.hybrid is not None:
        n_blocks = cfg.n_layers // cfg.hybrid.block_len
        per_block_mamba = cfg.hybrid.block_len - 1
        mc = M.init_mamba_cache(cfg, batch, adt)
        mamba = jax.tree.map(
            lambda x: jnp.zeros((n_blocks, per_block_mamba, *x.shape),
                                x.dtype), mc)
        return {"attn": kv(n_blocks), "mamba": mamba}
    if cfg.family == "ssm":
        mc = M.init_mamba_cache(cfg, batch, adt)
        return {"mamba": jax.tree.map(
            lambda x: jnp.zeros((cfg.n_layers, *x.shape), x.dtype), mc)}
    return {"attn": kv(cfg.n_layers)}


def cache_axes(cfg: ModelConfig) -> Dict[str, Any]:
    """Logical-axis tree matching ``init_cache``'s structure."""
    kv = {"k": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
          "v": ("layers", "batch", "kv_seq", "kv_heads", "head_dim")}
    mamba = {"conv": ("batch", None, None),
             "state": ("batch", "mamba_heads", "head_dim", "state")}
    if cfg.hybrid is not None:
        mamba2 = jax.tree.map(
            lambda a: ("layers", "inner_layers", *a), mamba,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                e is None or isinstance(e, str) for e in x))
        return {"attn": kv, "mamba": mamba2}
    if cfg.family == "ssm":
        return {"mamba": jax.tree.map(
            lambda a: ("layers", *a), mamba,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                e is None or isinstance(e, str) for e in x))}
    return {"attn": kv}


def decode_step(
    cfg: ModelConfig,
    params: Params,
    cache: Dict[str, Any],
    tokens: jax.Array,   # (b,) int32
    pos: jax.Array,      # (b,) int32 current write position
    *,
    impl: str = "ref",
    unroll: bool = False,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One token for every sequence in the batch. Returns (logits (b, v),
    updated cache)."""
    adt = jnp.dtype(cfg.activation_dtype)
    h = params["embed"][tokens].astype(adt)[:, None]   # (b, 1, d)
    h = constrain(h, "batch", "seq", "embed")

    def _maybe_unrolled_scan(body, carry, xs):
        if not unroll:
            return jax.lax.scan(body, carry, xs)
        n = jax.tree.leaves(xs)[0].shape[0]
        ys = []
        for i in range(n):
            carry, y = body(carry, jax.tree.map(lambda t: t[i], xs))
            ys.append(y)
        return carry, _stack_trees(ys)

    if cfg.hybrid is not None:
        def body(h, xs):
            bp, bc = xs
            aux_cache = {"mamba": [], "attn": None}
            hb = cfg.hybrid
            mi = 0
            nm, nl = 0, 0
            hh = h
            for j in range(hb.block_len):
                lns = jax.tree.map(lambda x: x[j], bp["lns"])
                x = L.apply_norm(cfg, jax.tree.map(lambda t: t[0], lns), hh)
                if hb.layer_kind(j) == ATTN:
                    out, kv = L.attention_decode(cfg, bp["attn"], x,
                                                 bc["attn"], pos, impl=impl)
                    aux_cache["attn"] = kv
                else:
                    mp = jax.tree.map(lambda t: t[mi], bp["mamba"])
                    mcache = jax.tree.map(lambda t: t[mi], bc["mamba"])
                    out, nc = M.mamba_decode(cfg, mp, x, mcache)
                    aux_cache["mamba"].append(nc)
                    mi += 1
                hh = hh + out
                x2 = L.apply_norm(cfg, jax.tree.map(lambda t: t[1], lns), hh)
                if cfg.moe is not None and cfg.moe.is_moe_layer(j):
                    mo = jax.tree.map(lambda t: t[nm], bp["moe"])
                    ffn_out, _ = MOE.moe_block(cfg, mo, x2)
                    nm += 1
                else:
                    ml = jax.tree.map(lambda t: t[nl], bp["mlp"])
                    ffn_out = L.mlp_block(cfg, ml, x2)
                    nl += 1
                hh = hh + ffn_out
            aux_cache["mamba"] = _stack_trees(aux_cache["mamba"])
            return hh, aux_cache

        h, new_cache = _maybe_unrolled_scan(body, h, (params["blocks"], cache))
    elif cfg.family == "ssm":
        def body(h, xs):
            lp, lc = xs
            x = L.apply_norm(cfg, lp["ln1"], h)
            out, nc = M.mamba_decode(cfg, lp["mixer"], x, lc)
            return h + out, nc

        h, new_mamba = _maybe_unrolled_scan(body, h, (params["layers"],
                                                    cache["mamba"]))
        new_cache = {"mamba": new_mamba}
    else:
        def body(h, xs):
            lp, lc = xs
            x = L.apply_norm(cfg, lp["ln1"], h)
            out, kv = L.attention_decode(cfg, lp["mixer"], x, lc, pos,
                                         impl=impl)
            h = h + out
            x2 = L.apply_norm(cfg, lp["ln2"], h)
            if cfg.moe is not None and cfg.moe.period == 1:
                ffn_out, _ = MOE.moe_block(cfg, lp["ffn"], x2)
            else:
                ffn_out = L.mlp_block(cfg, lp["ffn"], x2)
            return h + ffn_out, kv

        h, new_kv = _maybe_unrolled_scan(body, h, (params["layers"], cache["attn"]))
        new_cache = {"attn": new_kv}

    h = L.apply_norm(cfg, params["final_norm"], h)
    logits = _lm_head(cfg, params, h)[:, 0]
    return logits, new_cache
