from repro.models.model import (
    decode_step,
    forward,
    init_params,
    param_axes,
    prefill,
)

__all__ = ["decode_step", "forward", "init_params", "param_axes", "prefill"]
