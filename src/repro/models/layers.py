"""Shared layer primitives: norms, RoPE, GQA attention, MLP.

Parameter trees are plain nested dicts of ``jnp`` arrays; each ``init_*``
returns ``(params, axes)`` where ``axes`` mirrors the tree with tuples of
logical axis names consumed by ``repro.parallel.sharding``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.flash_attention.ops import flash_attention
from repro.parallel.sharding import constrain

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Small init helper that builds params + logical axes trees in lockstep.
# ---------------------------------------------------------------------------
class ParamFactory:
    def __init__(self, key: jax.Array, dtype: jnp.dtype):
        self._key = key
        self.dtype = dtype

    def next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def normal(self, shape, axes, scale: Optional[float] = None):
        if scale is None:
            scale = shape[0] ** -0.5  # fan-in
        w = jax.random.normal(self.next_key(), shape, jnp.float32) * scale
        return w.astype(self.dtype), axes

    def zeros(self, shape, axes):
        return jnp.zeros(shape, self.dtype), axes

    def ones(self, shape, axes):
        return jnp.ones(shape, self.dtype), axes

    def const(self, value, axes):
        return value.astype(self.dtype), axes


def split_tree(pairs: Dict[str, Tuple[Any, Any]]) -> Tuple[Params, Params]:
    """{'name': (param, axes) | (subparams, subaxes)} → (params, axes)."""
    params, axes = {}, {}
    for name, (p, a) in pairs.items():
        params[name] = p
        axes[name] = a
    return params, axes


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_norm(cfg: ModelConfig, f: ParamFactory):
    if cfg.norm == "layernorm":
        return split_tree({
            "scale": f.ones((cfg.d_model,), (None,)),
            "bias": f.zeros((cfg.d_model,), (None,)),
        })
    return split_tree({"scale": f.ones((cfg.d_model,), (None,))})


def apply_norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_1d(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_sin_cos(positions: jax.Array, head_dim: int, theta: float):
    """positions: (...,) int → sin/cos of shape (..., head_dim/2) f32."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (b, s, h, d); sin/cos: (b, s, d/2) or (s, d/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if sin.ndim == 2:
        sin = sin[None]
        cos = cos[None]
    sin = sin[:, :, None, :]
    cos = cos[:, :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention block
# ---------------------------------------------------------------------------
def init_attention(cfg: ModelConfig, f: ParamFactory):
    d, hq, hkv, hd = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                      cfg.resolved_head_dim())
    pairs = {
        "wq": f.normal((d, hq, hd), ("embed", "heads", "head_dim")),
        "wk": f.normal((d, hkv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": f.normal((d, hkv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": f.normal((hq, hd, d), ("heads", "head_dim", "embed"),
                       scale=(hq * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        pairs["bq"] = f.zeros((hq, hd), ("heads", "head_dim"))
        pairs["bk"] = f.zeros((hkv, hd), ("kv_heads", "head_dim"))
        pairs["bv"] = f.zeros((hkv, hd), ("kv_heads", "head_dim"))
    if cfg.qk_norm:
        pairs["q_norm"] = f.ones((hd,), (None,))
        pairs["k_norm"] = f.ones((hd,), (None,))
    return split_tree(pairs)


def _project_qkv(cfg: ModelConfig, p: Params, x: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"][None, None]
        k = k + p["bk"][None, None]
        v = v + p["bv"][None, None]
    if cfg.qk_norm:
        q = rms_norm_1d(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm_1d(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def attention_block(
    cfg: ModelConfig,
    p: Params,
    h: jax.Array,
    *,
    positions: jax.Array,
    impl: str = "ref",
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence (train/prefill) attention. Returns (residual output,
    kv-cache contribution {'k','v'})."""
    q, k, v = _project_qkv(cfg, p, h)
    sin, cos = rope_sin_cos(positions, cfg.resolved_head_dim(), cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "seq", "kv_heads", "head_dim")
    attn = flash_attention(q, k, v, causal=cfg.causal, window=cfg.window,
                           impl=impl)
    out = jnp.einsum("bshk,hkd->bsd", attn, p["wo"])
    return constrain(out, "batch", "seq", "embed"), {"k": k, "v": v}


def attention_decode(
    cfg: ModelConfig,
    p: Params,
    h: jax.Array,            # (b, 1, d)
    cache: Dict[str, jax.Array],  # k/v: (b, S, kv, hd)
    pos: jax.Array,          # (b,) int32 write positions
    *,
    impl: str = "ref",
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    b = h.shape[0]
    q, k, v = _project_qkv(cfg, p, h)
    sin, cos = rope_sin_cos(pos[:, None], cfg.resolved_head_dim(),
                            cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    if impl == "dist":
        # Sequence-parallel decode: masked local cache writeback + partial
        # softmax per model-shard + tiny online-softmax combine — replaces
        # the per-layer full-cache all-gather/re-shard of the XLA default
        # (see kernels/decode_attention/distributed.py and §Perf).
        from repro.kernels.decode_attention.distributed import (
            dist_decode_update_attend)
        attn, ck, cv = dist_decode_update_attend(
            q[:, 0], k[:, 0], v[:, 0], cache["k"], cache["v"], pos)
    else:
        bidx = jnp.arange(b)
        ck = cache["k"].at[bidx, pos].set(k[:, 0])
        cv = cache["v"].at[bidx, pos].set(v[:, 0])
        ck = constrain(ck, "batch", "kv_seq", "kv_heads", "head_dim")
        cv = constrain(cv, "batch", "kv_seq", "kv_heads", "head_dim")
        attn = decode_attention(q[:, 0], ck, cv, pos + 1, impl=impl)
    out = jnp.einsum("bhk,hkd->bd", attn, p["wo"])[:, None]
    return out, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def init_mlp(cfg: ModelConfig, f: ParamFactory):
    d, ff = cfg.d_model, cfg.d_ff
    if cfg.mlp_act == "swiglu":
        return split_tree({
            "w_gate": f.normal((d, ff), ("embed", "mlp")),
            "w_up": f.normal((d, ff), ("embed", "mlp")),
            "w_down": f.normal((ff, d), ("mlp", "embed")),
        })
    return split_tree({
        "w_in": f.normal((d, ff), ("embed", "mlp")),
        "w_out": f.normal((ff, d), ("mlp", "embed")),
    })


def mlp_block(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.mlp_act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
        y = constrain(jax.nn.silu(g) * u, "batch", "seq", "mlp")
        out = jnp.einsum("bsf,fd->bsd", y, p["w_down"])
    else:
        y = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_in"]))
        y = constrain(y, "batch", "seq", "mlp")
        out = jnp.einsum("bsf,fd->bsd", y, p["w_out"])
    return constrain(out, "batch", "seq", "embed")
