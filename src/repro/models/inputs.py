"""``input_specs``: ShapeDtypeStruct stand-ins for every model input of an
(architecture × shape) cell — weak-type-correct, shardable, zero device
allocation. The dry-run lowers against these; tests materialize them with
``materialize`` on reduced configs.

Modality frontends are STUBS per the assignment: audio cells receive
precomputed frame features, VLM cells receive precomputed patch embeddings
(plus a shortened text stream so total seq == shape.seq_len).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import model as MODEL


def train_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {}
    if cfg.family == "audio":
        specs["feats"] = jax.ShapeDtypeStruct(
            (b, s, cfg.frontend.feature_dim), jnp.float32)
        specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return specs
    if cfg.family == "vlm":
        n_p = cfg.frontend.n_prefix
        specs["feats"] = jax.ShapeDtypeStruct(
            (b, n_p, cfg.frontend.feature_dim), jnp.float32)
        specs["tokens"] = jax.ShapeDtypeStruct((b, s - n_p), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return specs
    specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return specs


def prefill_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    specs = train_input_specs(cfg, shape)
    specs.pop("labels", None)
    return specs


def decode_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Decode lowers ``serve_step``: one new token against a cache of
    ``shape.seq_len`` positions."""
    b = shape.global_batch
    cache = jax.eval_shape(
        lambda: MODEL.init_cache(cfg, b, shape.seq_len))
    return {
        "cache": cache,
        "tokens": jax.ShapeDtypeStruct((b,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((b,), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    if shape.kind == "train":
        return train_input_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape)
    return decode_input_specs(cfg, shape)


def input_axes(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Logical-axis tree matching ``input_specs``'s structure."""
    if shape.kind == "train" or shape.kind == "prefill":
        axes: Dict[str, Any] = {}
        specs = input_specs(cfg, shape)
        for name, leaf in specs.items():
            axes[name] = ("batch",) + (None,) * (len(leaf.shape) - 1)
        return axes
    return {
        "cache": MODEL.cache_axes(cfg),
        "tokens": ("batch",),
        "pos": ("batch",),
    }


def materialize(specs, key: jax.Array, vocab_size: int):
    """Turn specs into concrete (seeded) arrays — smoke tests only."""
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    out = []
    for i, leaf in enumerate(leaves):
        sub = jax.random.fold_in(key, i)
        if jnp.issubdtype(leaf.dtype, jnp.integer):
            out.append(jax.random.randint(
                sub, leaf.shape, 0, max(2, vocab_size), dtype=leaf.dtype))
        else:
            out.append(jax.random.normal(sub, leaf.shape, leaf.dtype))
    return jax.tree.unflatten(treedef, out)
