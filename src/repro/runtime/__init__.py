"""Live training runtime: binocular speculation driving a JAX train loop
over thread-simulated multi-host workers (real control plane — heartbeats,
progress logs, speculative reassignment, rollback — with the model math
running on the container's CPU device). Chaos-hardened (DESIGN.md §16):
fault scripts shared with the simulator, at-least-once delivery with
retry/backoff, coverage-based hole repair, quorum rollback resume, and an
injectable clock for deterministic failure-timeline tests."""
from repro.runtime.chaos import PINNED_SCRIPTS, ChaosController, parse_script
from repro.runtime.clock import Clock, FakeClock, SystemClock
from repro.runtime.coordinator import (
    Coordinator,
    RuntimeConfig,
    StepReport,
    StepWedged,
)
from repro.runtime.hosts import (
    AckMessage,
    GradMessage,
    HostDaemon,
    ProgressMessage,
    WorkItem,
)
from repro.runtime.trainer import TrainerRuntime

__all__ = ["AckMessage", "ChaosController", "Clock", "Coordinator",
           "FakeClock", "GradMessage", "HostDaemon", "PINNED_SCRIPTS",
           "ProgressMessage", "RuntimeConfig", "StepReport", "StepWedged",
           "SystemClock", "TrainerRuntime", "WorkItem", "parse_script"]
