"""Live training runtime: binocular speculation driving a JAX train loop
over thread-simulated multi-host workers (real control plane — heartbeats,
progress logs, speculative reassignment, rollback — with the model math
running on the container's CPU device)."""
from repro.runtime.coordinator import Coordinator, RuntimeConfig, StepReport
from repro.runtime.hosts import GradMessage, HostDaemon, ProgressMessage, WorkItem
from repro.runtime.trainer import TrainerRuntime

__all__ = ["Coordinator", "GradMessage", "HostDaemon", "ProgressMessage",
           "RuntimeConfig", "StepReport", "TrainerRuntime", "WorkItem"]
