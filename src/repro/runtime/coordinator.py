"""Training coordinator: the global speculator's seat (paper §III → live
JAX training, DESIGN.md §2 mapping, chaos hardening §16).

One training step is a MapReduce round:
- map tasks   — per-shard microbatch gradient production on host daemons,
                streamed eagerly to the coordinator (the "MOF" is consumer-
                side the moment it exists, so a producer's death loses only
                its UNSTREAMED microbatches);
- reduce task — the deterministic ordered gradient sum + optimizer apply,
                dependent on every shard's stream (the barrier).

The policy engine (``repro.core``) sees this through the same
ClusterSnapshot/Action protocol as the MapReduce simulator — and, since
ISSUE 6, through the same *columnar* substrate: the coordinator maintains
an incrementally-written :class:`~repro.core.arrays.ArraySnapshot` whose
node columns are built from live heartbeats, so assessment runs through
the pluggable ``repro.accel`` backends exactly as in the simulator (one
assessment engine, two frontends). ``verify_columnar=True`` additionally
runs the per-object reference engine on every tick's snapshot and asserts
action-for-action agreement — the sim-vs-runtime differential gate.

Recovery strategies:

- ``bino``     — BinocularSpeculator: Eq. 4 adaptive failure detection,
                 neighborhood/temporal straggler glance, collective shadow
                 attempts, rollback resume from the (shard, mb, DataState)
                 progress log. Only missing microbatches are re-executed.
- ``restart``  — the gang-restart baseline: a silent host past the long
                 timeout (or a stalled gradient stream) aborts the step;
                 all partial gradients are discarded and the step re-runs
                 on survivors.

Hardened communication paths (DESIGN.md §16.5): work items are delivered
at-least-once — every assign is acked, unacked sends are redelivered
under a deadline with jittered exponential backoff (bounded; exhaustion
fails the attempt over to another host), and hosts dedup redeliveries.
Dropped results are repaired by coverage accounting: a task is complete
only when its shard's gradient coverage is, and a stalled incomplete
task is resumed from the first missing microbatch (never by trusting an
attempt's own "done" claim, which can vanish in transit). If a step
still wedges past its deadline, or the live-host set falls below quorum,
the step is rolled back to its in-memory commit point (model state only
mutates on step success) and retried; ``step_retry_limit`` exhaustion
raises :class:`StepWedged`, which the TrainerRuntime turns into a
durable rollback from the last checkpoint.

Exactly-once invariant: gradients are keyed by (shard, microbatch); the
first arrival wins, duplicates from racing speculative attempts (or a
chaos layer re-delivering messages) are dropped, and the final sum runs
in sorted key order — a faulted run's model update is bit-identical to a
fault-free run's.

All time flows through an injectable Clock (repro.runtime.clock), so the
chaos matrix runs on compressed virtual time without racing real sleeps.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import queue
import random
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

import jax
import numpy as np

from repro.core import (
    AttemptState,
    AttemptView,
    BinoConfig,
    BinocularSpeculator,
    ClusterSnapshot,
    KillAttempt,
    MarkNodeFailed,
    NodeView,
    ProgressLog,
    SpeculateTask,
    TaskKind,
    TaskState,
    TaskView,
)
from repro.core.arrays import ArraySnapshot
from repro.core.collective import CollectiveConfig
from repro.core.glance import GlanceConfig
from repro.data.pipeline import DataState
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import (
    END_COMPLETED,
    END_FAILED,
    END_KILLED,
    K_ATT_END,
    K_ATT_START,
    K_DETECT,
    K_ROLLBACK,
)
from repro.runtime.clock import Clock, SystemClock
from repro.runtime.hosts import (
    AckMessage,
    GradMessage,
    HostDaemon,
    ProgressMessage,
    WorkItem,
)


class StepWedged(RuntimeError):
    """A step exhausted its in-memory rollback retries (quorum loss or a
    persistent wedge); the caller should fall back to a durable rollback
    (checkpoint restore) or surface the failure."""

    def __init__(self, step: int, detail: str = ""):
        super().__init__(f"step {step} wedged{': ' + detail if detail else ''}")
        self.step = step


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    n_hosts: int = 4
    microbatches_per_shard: int = 8
    recovery: str = "bino"            # "bino" | "restart"
    heartbeat_period: float = 0.05
    spec_interval: float = 0.15
    # gang-restart baseline: host silent (or gradient stream stalled) past
    # this ⇒ abort + restart step
    restart_timeout: float = 6.0
    # per-microbatch artificial compute time (gives tiny test models a
    # realistic timeline; 0 for pure-throughput runs)
    compute_delay: float = 0.05
    checkpoint_every: int = 0         # 0 = off
    checkpoint_dir: Optional[str] = None
    # --- hardened comms (DESIGN.md §16.5) ------------------------------
    ack_timeout: float = 0.3          # unacked assign past this ⇒ resend
    send_retries: int = 4             # bounded; exhaustion fails over
    backoff_base: float = 0.1         # jittered exponential backoff
    backoff_cap: float = 2.0
    backoff_jitter: float = 0.25
    # incomplete task with no freshly-reporting attempt past this ⇒
    # rollback relaunch from the first missing microbatch (bino only)
    repair_timeout: float = 1.0
    quorum_frac: float = 0.5          # live < ceil(frac·n) ⇒ step rollback
    step_retry_limit: int = 3         # in-memory rollback resumes per step
    step_deadline: float = 0.0        # 0 = auto: max(60, 30·restart_timeout)
    seed: int = 0                     # backoff jitter RNG
    # --- columnar assessment path (DESIGN.md §16.6) --------------------
    assess_columnar: bool = True      # feed policies ArraySnapshot columns
    assess_backend: Optional[str] = None   # repro.accel backend name
    verify_columnar: bool = False     # differential: reference ≡ columnar
    # Alternative speculator under recovery="bino" plumbing: a callable
    # ``host_ids -> Speculator`` (e.g. a trained PredictorPolicy,
    # DESIGN.md §20). Learned policies (``learned = True``) skip the
    # verify_columnar reference shadow — their verdicts legitimately
    # differ from BinocularSpeculator's.
    speculator_factory: Optional[Callable[[Sequence[str]], Any]] = None

    def glance(self) -> GlanceConfig:
        return GlanceConfig(
            fail_threshold_init=1.0, fail_threshold_min=0.4,
            fail_threshold_max=8.0, temporal_period=0.3,
            size_neighbor=min(4, max(2, self.n_hosts)),
            spatial_consecutive=3,
            responsive_window=4 * self.heartbeat_period)


@dataclasses.dataclass
class _AttemptRec:
    attempt_id: str
    task_id: str
    host_id: str
    start: float
    mb_start: int
    mb_total: int
    mb_done: int = 0
    state: AttemptState = AttemptState.RUNNING
    speculative: bool = False
    rollback: bool = False
    end: float = 0.0
    last_seen: float = 0.0    # last grad/progress arrival (liveness)
    row: int = -1             # columnar mirror row (compaction re-targets)


@dataclasses.dataclass
class StepReport:
    step: int
    wall_s: float
    mb_executed: int          # total microbatch executions incl. waste
    mb_needed: int
    recoveries: List[str]
    restarts: int
    metrics: Dict[str, float]
    wedges: int = 0           # in-memory rollback resumes taken


class Coordinator:
    def __init__(self, cfg: RuntimeConfig, *, grad_fn, apply_fn, batch_fn,
                 init_state, datastates: Sequence[DataState],
                 clock: Optional[Clock] = None, chaos=None,
                 obs=None, metrics: Optional[MetricsRegistry] = None):
        self.cfg = cfg
        self.grad_fn = grad_fn
        self.apply_fn = apply_fn          # (state, summed_grads) -> state
        self.batch_fn = batch_fn
        self.state = init_state
        self.n_shards = len(datastates)
        self.datastates: List[DataState] = list(datastates)
        self.clock: Clock = clock if clock is not None else SystemClock()
        self.chaos = chaos
        self.queue: "queue.Queue" = queue.Queue()
        self.hosts: Dict[str, HostDaemon] = {}
        self.heartbeats: Dict[str, float] = {}
        self._hb_lock = threading.Lock()
        self.dead_hosts: Set[str] = set()
        self._aid = itertools.count()
        self._task_order = itertools.count()
        self._rng = random.Random(cfg.seed)
        # at-least-once assign delivery: attempt_id -> in-flight send
        self._pending: Dict[str, Dict[str, Any]] = {}
        self.resend_count = 0
        # Flight recorder + metrics plane (repro.obs, DESIGN.md §18).
        # Pass a ``TraceRecorder(thread_safe=True)``: the coordinator only
        # emits from its own thread, but a wired ChaosController emits
        # K_FAULT from the chaos scheduler thread.
        self.obs = obs
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        host_ids = [f"h{i:02d}" for i in range(cfg.n_hosts)]
        self._host_pos = {hid: i for i, hid in enumerate(host_ids)}
        if obs is not None:
            obs.time_fn = self.clock.time
            if self.chaos is not None and getattr(self.chaos, "obs", None) \
                    is None:
                self.chaos.obs = obs
        for hid in host_ids:
            self._spawn_host(hid)
        if self.chaos is not None:
            self.chaos.arm(self.hosts, self.clock)
        # Columnar substrate: the same incrementally-maintained columns the
        # simulator writes through, here fed from live heartbeats/progress
        # messages. Single-writer: only the coordinator thread touches the
        # arrays (heartbeats land in ``self.heartbeats`` under a lock and
        # are folded into ``node_hb`` at snapshot build).
        self.arr: Optional[ArraySnapshot] = None
        self.speculator: Optional[BinocularSpeculator] = None
        self._ref_spec: Optional[BinocularSpeculator] = None
        if cfg.recovery == "bino":
            bc = BinoConfig(glance=cfg.glance(),
                            collective=CollectiveConfig(check_period=0.2))
            if cfg.speculator_factory is not None:
                self.speculator = cfg.speculator_factory(host_ids)
            else:
                self.speculator = BinocularSpeculator(
                    host_ids, bc, assess_backend=cfg.assess_backend)
            if cfg.assess_columnar:
                self.arr = ArraySnapshot(host_ids, n_containers=2)
                # Runtime progress is message-driven: between reports an
                # attempt's observed work is frozen, so the accrual term
                # (now - last_sync)·node_speed must vanish. This keeps
                # progress_at() ≡ the reference AttemptView.progress.
                self.arr.node_speed[:] = 0.0
            if cfg.verify_columnar and cfg.assess_columnar \
                    and not getattr(self.speculator, "learned", False):
                # Learned policies are never shadowed by the reference
                # speculator: the differential gate checks columnar ≡
                # object-walk *of the same policy*, and a PredictorPolicy
                # has no object-walk twin (DESIGN.md §20).
                self._ref_spec = BinocularSpeculator(host_ids, bc)
            if obs is not None:
                # Policy-side decision records (K_LATE / K_GLANCE_* /
                # K_THRESH / K_RAMP). Never wired into ``_ref_spec`` —
                # the differential shadow would double-emit. Factory
                # policies may lack glance/collective sub-assessors.
                self.speculator.obs = obs
                glance = getattr(self.speculator, "glance", None)
                if glance is not None:
                    glance.obs = obs
                coll = getattr(self.speculator, "collective", None)
                if coll is not None:
                    coll.obs = obs
        self.reports: List[StepReport] = []

    # ------------------------------------------------------------------
    def _spawn_host(self, hid: str) -> None:
        out = self.queue
        hb: Callable[[str, float], None] = self._on_heartbeat
        if self.chaos is not None:
            out = self.chaos.wrap_out(hid, self.queue)
            hb = self.chaos.wrap_heartbeat(hid, self._on_heartbeat)
        h = HostDaemon(
            hid, grad_fn=self.grad_fn, batch_fn=self.batch_fn,
            out_queue=out, heartbeat=hb,
            heartbeat_period=self.cfg.heartbeat_period,
            compute_delay=self.cfg.compute_delay, clock=self.clock)
        self.hosts[hid] = h
        self.heartbeats[hid] = self.clock.time()
        h.start()

    def _on_heartbeat(self, host_id: str, now: float) -> None:
        with self._hb_lock:
            # Monotonic guard: a chaos-delayed heartbeat arrives late with
            # its ORIGINAL timestamp — never let it rewind liveness.
            if now > self.heartbeats.get(host_id, 0.0):
                self.heartbeats[host_id] = now

    def live_hosts(self) -> List[str]:
        return [h for h in self.hosts if h not in self.dead_hosts]

    def _quorum(self) -> int:
        return max(1, math.ceil(self.cfg.quorum_frac * len(self.hosts)))

    def shutdown(self) -> None:
        if self.chaos is not None:
            self.chaos.stop()
        for h in self.hosts.values():
            h.shutdown()
        # Release any FakeClock sleepers, then reap the daemons — exiting
        # the interpreter while a worker is inside an XLA call aborts the
        # process, so teardown must be deterministic.
        close = getattr(self.clock, "close", None)
        if close is not None:
            close()
        for h in self.hosts.values():
            h.join(timeout=2.0)

    # ------------------------------------------------------------------
    # One training step
    # ------------------------------------------------------------------
    def run_step(self, step: int) -> StepReport:
        t0 = self.clock.time()
        recoveries: List[str] = []
        restarts = 0
        wedges = 0
        mb_executed = 0
        while True:
            ok, mb_tried, metrics, status = self._try_step(step, recoveries)
            mb_executed += mb_tried  # discarded work still counts as waste
            if ok:
                break
            if status == "restart":
                restarts += 1
                self.metrics.counter("restarts").inc()
                continue
            # Wedged: graceful degradation instead of gang abort — the
            # step rolls back to its in-memory commit point (state only
            # mutates on success) and resumes on the surviving quorum.
            wedges += 1
            self.metrics.counter("wedges").inc()
            if wedges > self.cfg.step_retry_limit:
                raise StepWedged(step, status)
            self._declare_silent_dead(recoveries)
            if self.obs is not None:
                # step-level in-memory rollback (a = -1: not host-scoped)
                self.obs.emit(K_ROLLBACK, a=-1, b=wedges,
                              obj=f"step{step}")
            recoveries.append(
                f"step {step}: {status} -> rollback resume "
                f"#{wedges} on {len(self.live_hosts())} hosts")
        report = StepReport(
            step=step, wall_s=self.clock.time() - t0,
            mb_executed=mb_executed,
            mb_needed=self.n_shards * self.cfg.microbatches_per_shard,
            recoveries=recoveries, restarts=restarts, metrics=metrics,
            wedges=wedges)
        self.metrics.histogram("step_wall").observe(report.wall_s)
        self.metrics.counter("mb_executed").inc(mb_executed)
        self.reports.append(report)
        return report

    # -- step internals --------------------------------------------------
    def _assign(self, step, tasks, attempts, task_id: str, shard: int,
                host_id: str, mb_start: int, *, speculative: bool,
                rollback: bool, data_state: DataState) -> None:
        aid = f"{task_id}_a{next(self._aid)}"
        M = self.cfg.microbatches_per_shard
        now = self.clock.time()
        rec = _AttemptRec(aid, task_id, host_id, now, mb_start,
                          M - mb_start, speculative=speculative,
                          rollback=rollback, last_seen=now)
        attempts[aid] = rec
        t = tasks[task_id]
        seq = len(t["attempts"])
        t["attempts"].append(rec)
        if self.obs is not None:
            self.obs.emit(
                K_ATT_START, a=self._host_pos[host_id],
                b=(1 if speculative else 0) | (2 if rollback else 0),
                obj=aid)
        if self.arr is not None:
            rec.row = self.arr.add_attempt(
                rec, aid, task_id, t["order"], seq, t["job_idx"],
                self.arr.node_index[host_id], TaskKind.MAP,
                speculative, now, work_done=0.0, work_total=max(1, M - mb_start),
                n_deps=1,
                task_state=(TaskState.COMPLETED if t["done"]
                            else TaskState.RUNNING))
        # Parameter distribution is an out-of-band bulk transfer (a
        # parameter-store read), not part of the faulted message plane.
        self.hosts[host_id].set_params(self.state["params"])
        item = WorkItem(
            step=step, task_id=task_id, shard_id=shard,
            mb_start=mb_start, mb_end=M, data_state=data_state,
            attempt_id=aid, speculative=speculative)
        self._pending[aid] = {
            "item": item, "host": host_id, "tries": 0,
            "next_at": now + self.cfg.ack_timeout}
        self._deliver(host_id, item)

    def _deliver(self, host_id: str, item: WorkItem) -> None:
        host = self.hosts[host_id]
        if self.chaos is not None:
            self.chaos.deliver_assign(host, item)
        else:
            host.assign(item)

    def _pump_retries(self, step, now, tasks, attempts, grads, shard_states,
                      recoveries) -> None:
        """At-least-once assign delivery: redeliver unacked work items
        with jittered exponential backoff; on exhaustion fail the attempt
        over to another host (DESIGN.md §16.5)."""
        cfg = self.cfg
        for aid, p in list(self._pending.items()):
            if now < p["next_at"]:
                continue
            rec = attempts.get(aid)
            if rec is None or rec.state != AttemptState.RUNNING:
                self._pending.pop(aid, None)
                continue
            if p["tries"] >= cfg.send_retries:
                self._pending.pop(aid, None)
                self._set_astate(rec, AttemptState.FAILED)
                recoveries.append(
                    f"{rec.task_id}: assign to {rec.host_id} undeliverable "
                    f"after {p['tries']} retries -> failover")
                self._relaunch(step, tasks, attempts, grads, shard_states,
                               rec.task_id, reason="assign-undeliverable",
                               recoveries=recoveries,
                               exclude_extra={rec.host_id})
                continue
            p["tries"] += 1
            self.resend_count += 1
            self.metrics.counter("resends").inc()
            backoff = min(cfg.backoff_cap,
                          cfg.backoff_base * (2.0 ** p["tries"]))
            backoff *= 1.0 + cfg.backoff_jitter * self._rng.random()
            p["next_at"] = now + cfg.ack_timeout + backoff
            self._deliver(p["host"], p["item"])

    def _set_astate(self, rec: _AttemptRec, state: AttemptState) -> None:
        rec.state = state
        if state != AttemptState.RUNNING:
            rec.end = self.clock.time()
            if self.obs is not None:
                code = (END_COMPLETED if state == AttemptState.COMPLETED
                        else END_KILLED if state == AttemptState.KILLED
                        else END_FAILED)
                self.obs.emit(
                    K_ATT_END, a=self._host_pos[rec.host_id], b=code,
                    f0=rec.start, f1=float(rec.mb_done),
                    f2=1.0 if rec.speculative else 0.0,
                    obj=rec.attempt_id)
        if self.arr is not None and rec.row >= 0:
            self.arr.set_attempt_state(rec.row, state)

    def _mark_task_done(self, tasks, tid: str) -> None:
        t = tasks[tid]
        t["done"] = True
        if self.arr is not None:
            self.arr.set_task_state(
                [a.row for a in t["attempts"] if a.row >= 0],
                TaskState.COMPLETED)

    def _pick_host(self, tasks, exclude: Set[str],
                   prefer: Sequence[str] = ()) -> Optional[str]:
        """Least-loaded live host, placement hints first."""
        busy: Dict[str, int] = {h: 0 for h in self.live_hosts()}
        for t in tasks.values():
            for a in t["attempts"]:
                if a.state == AttemptState.RUNNING and a.host_id in busy:
                    busy[a.host_id] += 1
        for h in prefer:
            if h in busy and h not in exclude:
                return h
        cands = [h for h in busy if h not in exclude]
        if not cands:
            cands = list(busy)  # nothing else: double up anywhere alive
        if not cands:
            return None
        return min(cands, key=lambda h: (busy[h], h))

    def _try_step(self, step: int, recoveries: List[str]
                  ) -> Tuple[bool, int, Dict[str, float], str]:
        M = self.cfg.microbatches_per_shard
        grads: Dict[Tuple[int, int], Any] = {}
        metric_acc: Dict[str, float] = {}
        mb_executed = 0
        tasks: Dict[str, Dict[str, Any]] = {}
        attempts: Dict[str, _AttemptRec] = {}
        shard_states: Dict[int, DataState] = {}
        self._pending.clear()

        live = self.live_hosts()
        if not live:
            raise RuntimeError("no live hosts remain")
        if len(live) < self._quorum():
            return False, 0, {}, "quorum lost"
        job_id = f"step{step}"
        job_idx = -1
        if self.arr is not None:
            job_idx = self.arr.job_started(job_id)
        now0 = self.clock.time()
        for s in range(self.n_shards):
            tid = f"s{step}_grad{s:03d}"
            tasks[tid] = {"shard": s, "attempts": [], "done": False,
                          "order": next(self._task_order),
                          "job_idx": job_idx,
                          "t0": now0, "last_grad": now0, "repairs": 0,
                          "next_repair": now0}
            shard_states[s] = self.datastates[s]
            if self.arr is not None:
                self.arr.task_created(job_idx)

        # initial placement: shards round-robin over live hosts
        for s in range(self.n_shards):
            tid = f"s{step}_grad{s:03d}"
            host = live[s % len(live)]
            self._assign(step, tasks, attempts, tid, s, host, 0,
                         speculative=False, rollback=False,
                         data_state=shard_states[s])

        last_tick = 0.0
        last_grad = self.clock.time()
        auto = max(60.0, 30 * self.cfg.restart_timeout)
        deadline = self.clock.time() + (self.cfg.step_deadline or auto)
        while len(grads) < self.n_shards * M:
            now = self.clock.time()
            if now > deadline:
                self._abort_inflight(step, attempts)
                return False, mb_executed, {}, "deadline exceeded"
            if len(self.live_hosts()) < self._quorum():
                self._abort_inflight(step, attempts)
                return False, mb_executed, {}, "quorum lost"
            try:
                msg = self.queue.get(timeout=0.02)
            except queue.Empty:
                msg = None
            if isinstance(msg, GradMessage):
                if msg.step != step:
                    continue  # stale stream from a previous step's loser
                key = (msg.shard_id, msg.mb_index)
                mb_executed += 1
                rec = attempts.get(msg.attempt_id)
                if rec is not None:
                    rec.last_seen = self.clock.time()
                if key not in grads:  # exactly-once: first writer wins
                    grads[key] = msg.grads
                    for k, v in msg.metrics.items():
                        metric_acc[k] = metric_acc.get(k, 0.0) + v
                    tid = f"s{step}_grad{msg.shard_id:03d}"
                    t = tasks.get(tid)
                    if t is not None:
                        t["last_grad"] = self.clock.time()
                        last_grad = t["last_grad"]
                        # Coverage decides completion — never an attempt's
                        # own done-claim, which can vanish in transit.
                        if not t["done"]:
                            have = sum(1 for (s, _m) in grads
                                       if s == msg.shard_id)
                            if have >= M:
                                self._mark_task_done(tasks, tid)
            elif isinstance(msg, ProgressMessage):
                if msg.step != step:
                    continue
                rec = attempts.get(msg.attempt_id)
                if rec is not None and rec.state == AttemptState.RUNNING:
                    # max(): chaos can reorder adjacent reports
                    rec.mb_done = max(rec.mb_done, msg.mb_done)
                    rec.last_seen = self.clock.time()
                    if self.arr is not None and rec.row >= 0:
                        self.arr.sync_row(rec.row, float(rec.mb_done),
                                          rec.last_seen)
                    if msg.done:
                        self._set_astate(rec, AttemptState.COMPLETED)
                    # progress log: offset fraction + resumable data state
                    log = ProgressLog(
                        task_id=msg.task_id, node_id=msg.host_id,
                        offset=msg.mb_done / max(msg.mb_total, 1),
                        handle=msg.data_state)
                    if self.speculator is not None:
                        self.speculator.record_progress_log(log)
                    if self._ref_spec is not None:
                        self._ref_spec.record_progress_log(log)
            elif isinstance(msg, AckMessage):
                self._pending.pop(msg.attempt_id, None)

            now = self.clock.time()
            self._pump_retries(step, now, tasks, attempts, grads,
                               shard_states, recoveries)
            if now - last_tick >= self.cfg.spec_interval:
                last_tick = now
                if self.speculator is not None:
                    self._bino_tick(step, tasks, attempts, grads,
                                    shard_states, recoveries)
                else:
                    aborted = self._restart_tick(tasks, attempts,
                                                 recoveries, last_grad)
                    if aborted:
                        self._finish_job(step)
                        return False, mb_executed, {}, "restart"

        # ---- reduce: deterministic ordered sum + optimizer apply -------
        ordered = [grads[k] for k in sorted(grads)]
        total = jax.tree.map(
            lambda *xs: sum(x.astype(np.float32) if hasattr(x, "astype")
                            else x for x in xs), *ordered)
        denom = float(self.n_shards * M)
        total = jax.tree.map(lambda x: x / denom, total)
        self.state = self.apply_fn(self.state, total)
        for s in range(self.n_shards):
            self.datastates[s] = self.datastates[s].advance(M)
        for h in self.live_hosts():
            self.hosts[h].set_params(self.state["params"])
        metrics = {k: v / denom for k, v in metric_acc.items()}
        self._finish_job(step)
        return True, mb_executed, metrics, "ok"

    def _finish_job(self, step: int) -> None:
        job_id = f"step{step}"
        if self.arr is not None:
            self.arr.job_finished(job_id)
        if self.speculator is not None:
            self.speculator.job_done(job_id)
        if self._ref_spec is not None:
            self._ref_spec.job_done(job_id)

    def _abort_inflight(self, step: int, attempts) -> None:
        """Cancel running attempts, drop pending sends and drain the inbox
        — the cleanup edge of an in-memory step rollback."""
        for a in attempts.values():
            if a.state == AttemptState.RUNNING:
                self._set_astate(a, AttemptState.KILLED)
                if a.host_id not in self.dead_hosts:
                    self.hosts[a.host_id].cancel(a.attempt_id)
        self._pending.clear()
        self._drain()
        self._finish_job(step)

    def _declare_silent_dead(self, recoveries: List[str]) -> None:
        """Graceful degradation on a wedged step: hosts silent beyond the
        gang threshold are declared dead before the rollback resume, so
        the retry places work only on responsive survivors."""
        now = self.clock.time()
        with self._hb_lock:
            hb = dict(self.heartbeats)
        thresh = max(self.cfg.restart_timeout,
                     8 * self.cfg.heartbeat_period)
        for hid in self.live_hosts():
            if now - hb.get(hid, 0.0) > thresh:
                self.dead_hosts.add(hid)
                if self.obs is not None:
                    self.obs.emit(K_DETECT, a=self._host_pos[hid], b=0,
                                  obj="silent-at-rollback")
                self.metrics.counter("expiry_declares").inc()
                recoveries.append(
                    f"host {hid} silent {now - hb.get(hid, 0.0):.2f}s "
                    "at rollback -> declared dead")

    # -- bino recovery ----------------------------------------------------
    def _snapshot(self, step, tasks, attempts, grads) -> ClusterSnapshot:
        now = self.clock.time()
        with self._hb_lock:
            hb = dict(self.heartbeats)
        nodes = {}
        running_by_host: Dict[str, int] = {}
        for a in attempts.values():
            if a.state == AttemptState.RUNNING:
                running_by_host[a.host_id] = \
                    running_by_host.get(a.host_id, 0) + 1
        for hid in self.hosts:
            nodes[hid] = NodeView(
                node_id=hid, last_heartbeat=hb.get(hid, 0.0),
                total_containers=2,
                free_containers=max(0, 2 - running_by_host.get(hid, 0)),
                marked_failed=hid in self.dead_hosts)
        if self.arr is not None:
            # Fold the live heartbeat/occupancy state into the node
            # columns — this is the snapshot point: the columnar and
            # reference views of the cluster are frozen together.
            for hid, i in self.arr.node_index.items():
                nv = nodes[hid]
                self.arr.node_hb[i] = nv.last_heartbeat
                self.arr.node_free[i] = nv.free_containers
                self.arr.node_marked[i] = nv.marked_failed
        tviews: Dict[str, TaskView] = {}
        job_id = f"step{step}"
        M = self.cfg.microbatches_per_shard
        for tid, t in tasks.items():
            shard = t["shard"]
            avs = []
            for a in t["attempts"]:
                avs.append(AttemptView(
                    attempt_id=a.attempt_id, task_id=tid,
                    node_id=a.host_id, state=a.state, start_time=a.start,
                    progress=a.mb_done / max(a.mb_total, 1),
                    is_speculative=a.speculative,
                    is_rollback=a.rollback))
            have = sum(1 for (s, _m) in grads if s == shard)
            tviews[tid] = TaskView(
                task_id=tid, job_id=job_id, kind=TaskKind.MAP,
                state=(TaskState.COMPLETED if have >= M
                       else TaskState.RUNNING),
                attempts=avs, output_available=have >= M,
                output_nodes=("coord",))
        return ClusterSnapshot(now=now, nodes=nodes, tasks=tviews,
                               arrays=self.arr)

    def _assess(self, snap: ClusterSnapshot) -> List[Any]:
        """Policy tick; with ``verify_columnar`` the per-object reference
        engine runs on the same frozen snapshot and must agree action for
        action — the sim-vs-runtime differential gate (DESIGN.md §16.6)."""
        actions = self.speculator.assess(snap)
        if self._ref_spec is not None and snap.arrays is not None:
            ref = self._ref_spec.assess(
                dataclasses.replace(snap, arrays=None))
            if _action_sig(ref) != _action_sig(actions):
                raise AssertionError(
                    "columnar/reference divergence at now="
                    f"{snap.now:.3f}:\n  columnar={_action_sig(actions)}"
                    f"\n  reference={_action_sig(ref)}")
        return actions

    def _bino_tick(self, step, tasks, attempts, grads, shard_states,
                   recoveries) -> None:
        snap = self._snapshot(step, tasks, attempts, grads)
        actions = self._assess(snap)
        for act in actions:
            if isinstance(act, MarkNodeFailed):
                if act.node_id in self.dead_hosts:
                    continue
                self.dead_hosts.add(act.node_id)
                if self.obs is not None:
                    self.obs.emit(K_DETECT, a=self._host_pos[act.node_id],
                                  b=1, obj=act.reason)
                self.metrics.counter("detections").inc()
                recoveries.append(f"host {act.node_id} declared failed "
                                  f"({act.reason})")
                # fail its running attempts; reassignment happens via the
                # straggler path below or immediately here
                for a in list(attempts.values()):
                    if a.host_id == act.node_id \
                            and a.state == AttemptState.RUNNING:
                        self._set_astate(a, AttemptState.FAILED)
                        self._pending.pop(a.attempt_id, None)
                        self._relaunch(step, tasks, attempts, grads,
                                       shard_states, a.task_id,
                                       reason="failure",
                                       recoveries=recoveries)
            elif isinstance(act, SpeculateTask):
                tid = act.task_id
                if tid not in tasks or tasks[tid]["done"]:
                    continue
                running = [a for a in tasks[tid]["attempts"]
                           if a.state == AttemptState.RUNNING]
                if any(a.speculative for a in running) or len(running) >= 2:
                    continue
                self._relaunch(step, tasks, attempts, grads, shard_states,
                               tid, reason=act.reason, recoveries=recoveries,
                               speculative=bool(running),
                               prefer=act.placement_hint)
            elif isinstance(act, KillAttempt):
                a = attempts.get(act.attempt_id)
                if a is not None and a.state == AttemptState.RUNNING:
                    self._set_astate(a, AttemptState.KILLED)
                    self._pending.pop(a.attempt_id, None)
                    if a.host_id not in self.dead_hosts:
                        self.hosts[a.host_id].cancel(a.attempt_id)
        now = self.clock.time()
        # Exactly-once hole repair (DESIGN.md §16.5): results can vanish
        # in transit — an attempt may even "finish" inside a drop window,
        # leaving its task incomplete forever. Any incomplete task with no
        # freshly-reporting attempt is resumed from the first missing
        # microbatch, under per-task exponential backoff so a persistent
        # outage doesn't spray attempts.
        for tid, t in tasks.items():
            if t["done"]:
                continue
            running = [a for a in t["attempts"]
                       if a.state == AttemptState.RUNNING]
            fresh = [a for a in running
                     if now - a.last_seen < self.cfg.repair_timeout]
            # A running attempt that never streamed anything may just be
            # warming up (first-call jit compile): only a stream that
            # STOPPED (grads seen this try, then silence) or a task with
            # no attempts left marks a hole.
            started = t["last_grad"] > t["t0"]
            if fresh or (running and not started) \
                    or now < t["next_repair"] \
                    or now - t["last_grad"] < self.cfg.repair_timeout:
                continue
            t["repairs"] += 1
            pause = self.cfg.repair_timeout * (2.0 ** min(t["repairs"], 5))
            t["next_repair"] = now + pause * \
                (1.0 + self.cfg.backoff_jitter * self._rng.random())
            self._relaunch(step, tasks, attempts, grads, shard_states,
                           tid, reason="hole-repair", recoveries=recoveries)
        # Tail-straggler fallback (beyond-paper; DESIGN.md §10): once most
        # map tasks have drained, Eq. 1 loses its comparison population (the
        # paper's own small-job blind spot, §II.D.2) — so the coordinator
        # adds a LATE-style estimated-remaining-time check against the
        # completed population and shadow-executes the laggards.
        completed = [a for a in attempts.values()
                     if a.state == AttemptState.COMPLETED]
        running = [t for t in tasks.values() if not t["done"]]
        if completed and running and \
                len(running) <= max(1, len(tasks) // 4):
            durations = sorted((a.end - a.start) for a in completed)
            median = durations[len(durations) // 2]
            for t in tasks.values():
                if t["done"]:
                    continue
                live = [a for a in t["attempts"]
                        if a.state == AttemptState.RUNNING]
                if not live or any(a.speculative for a in live):
                    continue
                a = max(live, key=lambda a: a.mb_done)
                frac = a.mb_done / max(a.mb_total, 1)
                rate = frac / max(now - a.start, 1e-6)
                est_remaining = (1.0 - frac) / max(rate, 1e-6)
                if est_remaining > max(1.5 * median,
                                       4 * self.cfg.spec_interval):
                    tid = [k for k, v in tasks.items() if v is t][0]
                    self._relaunch(step, tasks, attempts, grads,
                                   shard_states, tid,
                                   reason="tail-straggler",
                                   recoveries=recoveries, speculative=True)

    def _relaunch(self, step, tasks, attempts, grads, shard_states, tid,
                  *, reason: str, recoveries: List[str],
                  speculative: bool = False,
                  prefer: Sequence[str] = (),
                  exclude_extra: Optional[Set[str]] = None) -> None:
        shard = tasks[tid]["shard"]
        M = self.cfg.microbatches_per_shard
        # Rollback: resume past every microbatch already streamed (the
        # consumer-side MOF survives the producer) — exactly-once keeps
        # racing duplicates harmless anyway.
        have = sorted(m for (s, m) in grads if s == shard)
        resume = 0
        for m in have:
            if m == resume:
                resume += 1
            else:
                break
        if resume >= M:
            return
        exclude = {a.host_id for a in tasks[tid]["attempts"]
                   if a.state == AttemptState.RUNNING} | self.dead_hosts
        if exclude_extra:
            exclude |= exclude_extra
        host = self._pick_host(tasks, exclude, prefer)
        if host is None:
            return
        st = self.datastates[shard]
        for _ in range(resume):
            st = st.advance()
        if self.obs is not None and resume > 0:
            # rollback resume: only the missing microbatches re-execute
            self.obs.emit(K_ROLLBACK, a=self._host_pos[host],
                          f0=resume / M, obj=tid)
        self.metrics.counter("recoveries").inc()
        self._assign(step, tasks, attempts, tid, shard, host, resume,
                     speculative=speculative,
                     rollback=resume > 0, data_state=st)
        recoveries.append(
            f"{tid}: {reason} -> {'spec' if speculative else 'relaunch'} "
            f"on {host} from mb {resume}")

    # -- gang-restart baseline ---------------------------------------------
    def _restart_tick(self, tasks, attempts, recoveries,
                      last_grad: float) -> bool:
        now = self.clock.time()
        with self._hb_lock:
            hb = dict(self.heartbeats)
        silent = [hid for hid in self.live_hosts()
                  if now - hb.get(hid, 0.0) > self.cfg.restart_timeout]
        # Progress watchdog: a dropped result stream looks like a wedged
        # step with perfectly healthy heartbeats — the gang baseline can
        # only ever re-run the whole step.
        stalled = (now - last_grad > self.cfg.restart_timeout
                   and any(not t["done"] for t in tasks.values()))
        if not silent and not stalled:
            return False
        for hid in silent:
            self.dead_hosts.add(hid)
            if self.obs is not None:
                self.obs.emit(K_DETECT, a=self._host_pos[hid], b=0,
                              obj="gang-timeout")
            self.metrics.counter("expiry_declares").inc()
            recoveries.append(
                f"host {hid} timed out ({self.cfg.restart_timeout}s) "
                "-> gang restart of step")
        if stalled and not silent:
            recoveries.append(
                f"gradient stream stalled {self.cfg.restart_timeout}s "
                "-> gang restart of step")
        # abort: cancel everything, discard partials
        for a in attempts.values():
            if a.state == AttemptState.RUNNING:
                self._set_astate(a, AttemptState.KILLED)
                if a.host_id not in self.dead_hosts:
                    self.hosts[a.host_id].cancel(a.attempt_id)
        self._pending.clear()
        self._drain()
        return True

    def _drain(self) -> None:
        try:
            while True:
                self.queue.get_nowait()
        except queue.Empty:
            pass


def _action_sig(actions) -> List[Tuple]:
    """Canonical, comparable form of a policy action list."""
    out = []
    for a in actions:
        d = dataclasses.asdict(a)
        out.append((type(a).__name__,
                    tuple(sorted((k, str(v)) for k, v in d.items()))))
    return out


def rec_step(task_id: str) -> int:
    return int(task_id.split("_")[0][1:])
