"""Training coordinator: the global speculator's seat (paper §III → live
JAX training, DESIGN.md §2 mapping).

One training step is a MapReduce round:
- map tasks   — per-shard microbatch gradient production on host daemons,
                streamed eagerly to the coordinator (the "MOF" is consumer-
                side the moment it exists, so a producer's death loses only
                its UNSTREAMED microbatches);
- reduce task — the deterministic ordered gradient sum + optimizer apply,
                dependent on every shard's stream (the barrier).

The policy engine (``repro.core``) sees this through the same
ClusterSnapshot/Action protocol as the MapReduce simulator. Recovery
strategies:

- ``bino``     — BinocularSpeculator: Eq. 4 adaptive failure detection,
                 neighborhood/temporal straggler glance, collective shadow
                 attempts, rollback resume from the (shard, mb, DataState)
                 progress log. Only missing microbatches are re-executed.
- ``restart``  — the gang-restart baseline: a silent host past the long
                 timeout aborts the step; all partial gradients are
                 discarded and the step re-runs on survivors.

Exactly-once invariant: gradients are keyed by (shard, microbatch); the
first arrival wins, duplicates from racing speculative attempts are
dropped, and the final sum runs in sorted key order — a faulted run's model
update is bit-identical to a fault-free run's.
"""
from __future__ import annotations

import dataclasses
import itertools
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

import jax
import numpy as np

from repro.core import (
    AttemptState,
    AttemptView,
    BinoConfig,
    BinocularSpeculator,
    ClusterSnapshot,
    KillAttempt,
    MarkNodeFailed,
    NodeView,
    ProgressLog,
    SpeculateTask,
    TaskKind,
    TaskState,
    TaskView,
)
from repro.core.collective import CollectiveConfig
from repro.core.glance import GlanceConfig
from repro.data.pipeline import DataState
from repro.runtime.hosts import GradMessage, HostDaemon, ProgressMessage, WorkItem


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    n_hosts: int = 4
    microbatches_per_shard: int = 8
    recovery: str = "bino"            # "bino" | "restart"
    heartbeat_period: float = 0.05
    spec_interval: float = 0.15
    # gang-restart baseline: host silent past this ⇒ abort + restart step
    restart_timeout: float = 6.0
    # per-microbatch artificial compute time (gives tiny test models a
    # realistic timeline; 0 for pure-throughput runs)
    compute_delay: float = 0.05
    checkpoint_every: int = 0         # 0 = off
    checkpoint_dir: Optional[str] = None

    def glance(self) -> GlanceConfig:
        return GlanceConfig(
            fail_threshold_init=1.0, fail_threshold_min=0.4,
            fail_threshold_max=8.0, temporal_period=0.3,
            size_neighbor=min(4, max(2, self.n_hosts)),
            spatial_consecutive=3,
            responsive_window=4 * self.heartbeat_period)


@dataclasses.dataclass
class _AttemptRec:
    attempt_id: str
    task_id: str
    host_id: str
    start: float
    mb_start: int
    mb_total: int
    mb_done: int = 0
    state: AttemptState = AttemptState.RUNNING
    speculative: bool = False
    rollback: bool = False
    end: float = 0.0


@dataclasses.dataclass
class StepReport:
    step: int
    wall_s: float
    mb_executed: int          # total microbatch executions incl. waste
    mb_needed: int
    recoveries: List[str]
    restarts: int
    metrics: Dict[str, float]


class Coordinator:
    def __init__(self, cfg: RuntimeConfig, *, grad_fn, apply_fn, batch_fn,
                 init_state, datastates: Sequence[DataState]):
        self.cfg = cfg
        self.grad_fn = grad_fn
        self.apply_fn = apply_fn          # (state, summed_grads) -> state
        self.batch_fn = batch_fn
        self.state = init_state
        self.n_shards = len(datastates)
        self.datastates: List[DataState] = list(datastates)
        self.queue: "queue.Queue" = queue.Queue()
        self.hosts: Dict[str, HostDaemon] = {}
        self.heartbeats: Dict[str, float] = {}
        self._hb_lock = threading.Lock()
        self.dead_hosts: Set[str] = set()
        self._aid = itertools.count()
        host_ids = [f"h{i:02d}" for i in range(cfg.n_hosts)]
        for hid in host_ids:
            self._spawn_host(hid)
        if cfg.recovery == "bino":
            self.speculator = BinocularSpeculator(
                host_ids,
                BinoConfig(glance=cfg.glance(),
                           collective=CollectiveConfig(check_period=0.2)))
        else:
            self.speculator = None
        self.reports: List[StepReport] = []

    # ------------------------------------------------------------------
    def _spawn_host(self, hid: str) -> None:
        h = HostDaemon(
            hid, grad_fn=self.grad_fn, batch_fn=self.batch_fn,
            out_queue=self.queue, heartbeat=self._on_heartbeat,
            heartbeat_period=self.cfg.heartbeat_period,
            compute_delay=self.cfg.compute_delay)
        self.hosts[hid] = h
        self.heartbeats[hid] = time.time()
        h.start()

    def _on_heartbeat(self, host_id: str, now: float) -> None:
        with self._hb_lock:
            self.heartbeats[host_id] = now

    def live_hosts(self) -> List[str]:
        return [h for h in self.hosts if h not in self.dead_hosts]

    def shutdown(self) -> None:
        for h in self.hosts.values():
            h.shutdown()

    # ------------------------------------------------------------------
    # One training step
    # ------------------------------------------------------------------
    def run_step(self, step: int) -> StepReport:
        t0 = time.time()
        recoveries: List[str] = []
        restarts = 0
        mb_executed = 0
        while True:
            ok, mb_tried, metrics = self._try_step(step, recoveries)
            mb_executed += mb_tried  # discarded work still counts as waste
            if ok:
                break
            restarts += 1
        report = StepReport(
            step=step, wall_s=time.time() - t0,
            mb_executed=mb_executed,
            mb_needed=self.n_shards * self.cfg.microbatches_per_shard,
            recoveries=recoveries, restarts=restarts, metrics=metrics)
        self.reports.append(report)
        return report

    # -- step internals --------------------------------------------------
    def _assign(self, tasks, attempts, task_id: str, shard: int,
                host_id: str, mb_start: int, *, speculative: bool,
                rollback: bool, data_state: DataState) -> None:
        aid = f"{task_id}_a{next(self._aid)}"
        M = self.cfg.microbatches_per_shard
        rec = _AttemptRec(aid, task_id, host_id, time.time(), mb_start,
                          M - mb_start, speculative=speculative,
                          rollback=rollback)
        attempts[aid] = rec
        tasks[task_id]["attempts"].append(rec)
        self.hosts[host_id].set_params(self.state["params"])
        self.hosts[host_id].assign(WorkItem(
            step=rec_step(task_id), task_id=task_id, shard_id=shard,
            mb_start=mb_start, mb_end=M, data_state=data_state,
            attempt_id=aid, speculative=speculative))

    def _pick_host(self, tasks, exclude: Set[str],
                   prefer: Sequence[str] = ()) -> Optional[str]:
        """Least-loaded live host, placement hints first."""
        busy: Dict[str, int] = {h: 0 for h in self.live_hosts()}
        for t in tasks.values():
            for a in t["attempts"]:
                if a.state == AttemptState.RUNNING and a.host_id in busy:
                    busy[a.host_id] += 1
        for h in prefer:
            if h in busy and h not in exclude:
                return h
        cands = [h for h in busy if h not in exclude]
        if not cands:
            cands = list(busy)  # nothing else: double up anywhere alive
        if not cands:
            return None
        return min(cands, key=lambda h: (busy[h], h))

    def _try_step(self, step: int, recoveries: List[str]
                  ) -> Tuple[bool, int, Dict[str, float]]:
        M = self.cfg.microbatches_per_shard
        grads: Dict[Tuple[int, int], Any] = {}
        metric_acc: Dict[str, float] = {}
        mb_executed = 0
        tasks: Dict[str, Dict[str, Any]] = {}
        attempts: Dict[str, _AttemptRec] = {}
        shard_states: Dict[int, DataState] = {}

        live = self.live_hosts()
        if not live:
            raise RuntimeError("no live hosts remain")
        for s in range(self.n_shards):
            tid = f"s{step}_grad{s:03d}"
            tasks[tid] = {"shard": s, "attempts": [], "done": False}
            shard_states[s] = self.datastates[s]
        reduce_tid = f"s{step}_apply"

        # initial placement: shards round-robin over live hosts
        for s in range(self.n_shards):
            tid = f"s{step}_grad{s:03d}"
            host = live[s % len(live)]
            self._assign(tasks, attempts, tid, s, host, 0,
                         speculative=False, rollback=False,
                         data_state=shard_states[s])

        last_tick = 0.0
        deadline = time.time() + max(60.0, 30 * self.cfg.restart_timeout)
        while len(grads) < self.n_shards * M:
            if time.time() > deadline:
                raise RuntimeError(f"step {step} wedged")
            try:
                msg = self.queue.get(timeout=0.02)
            except queue.Empty:
                msg = None
            if isinstance(msg, GradMessage):
                if msg.step != step:
                    continue  # stale stream from a previous step's loser
                key = (msg.shard_id, msg.mb_index)
                mb_executed += 1
                if key not in grads:  # exactly-once: first writer wins
                    grads[key] = msg.grads
                    for k, v in msg.metrics.items():
                        metric_acc[k] = metric_acc.get(k, 0.0) + v
            elif isinstance(msg, ProgressMessage):
                if msg.step != step:
                    continue
                rec = attempts.get(msg.attempt_id)
                if rec is not None and rec.state == AttemptState.RUNNING:
                    rec.mb_done = msg.mb_done
                    if msg.done:
                        rec.state = AttemptState.COMPLETED
                        rec.end = time.time()
                        tasks[msg.task_id]["done"] = True
                    # progress log: offset fraction + resumable data state
                    if self.speculator is not None:
                        self.speculator.record_progress_log(ProgressLog(
                            task_id=msg.task_id, node_id=msg.host_id,
                            offset=msg.mb_done / max(msg.mb_total, 1),
                            handle=msg.data_state))

            now = time.time()
            if now - last_tick >= self.cfg.spec_interval:
                last_tick = now
                if self.speculator is not None:
                    done = self._bino_tick(step, tasks, attempts, grads,
                                           shard_states, recoveries)
                else:
                    aborted = self._restart_tick(tasks, attempts, recoveries)
                    if aborted:
                        return False, mb_executed, {}

        # ---- reduce: deterministic ordered sum + optimizer apply -------
        ordered = [grads[k] for k in sorted(grads)]
        total = jax.tree.map(
            lambda *xs: sum(x.astype(np.float32) if hasattr(x, "astype")
                            else x for x in xs), *ordered)
        denom = float(self.n_shards * M)
        total = jax.tree.map(lambda x: x / denom, total)
        self.state = self.apply_fn(self.state, total)
        for s in range(self.n_shards):
            self.datastates[s] = self.datastates[s].advance(M)
        for h in self.live_hosts():
            self.hosts[h].set_params(self.state["params"])
        metrics = {k: v / denom for k, v in metric_acc.items()}
        if self.speculator is not None:
            self.speculator.job_done(f"step{step}")
        return True, mb_executed, metrics

    # -- bino recovery ----------------------------------------------------
    def _snapshot(self, step, tasks, attempts, grads) -> ClusterSnapshot:
        with self._hb_lock:
            hb = dict(self.heartbeats)
        nodes = {}
        running_by_host: Dict[str, int] = {}
        for a in attempts.values():
            if a.state == AttemptState.RUNNING:
                running_by_host[a.host_id] = \
                    running_by_host.get(a.host_id, 0) + 1
        for hid in self.hosts:
            nodes[hid] = NodeView(
                node_id=hid, last_heartbeat=hb.get(hid, 0.0),
                total_containers=2,
                free_containers=max(0, 2 - running_by_host.get(hid, 0)),
                marked_failed=hid in self.dead_hosts)
        tviews: Dict[str, TaskView] = {}
        job_id = f"step{step}"
        M = self.cfg.microbatches_per_shard
        for tid, t in tasks.items():
            shard = t["shard"]
            avs = []
            for a in t["attempts"]:
                avs.append(AttemptView(
                    attempt_id=a.attempt_id, task_id=tid,
                    node_id=a.host_id, state=a.state, start_time=a.start,
                    progress=a.mb_done / max(a.mb_total, 1),
                    is_speculative=a.speculative,
                    is_rollback=a.rollback))
            have = sum(1 for (s, _m) in grads if s == shard)
            tviews[tid] = TaskView(
                task_id=tid, job_id=job_id, kind=TaskKind.MAP,
                state=(TaskState.COMPLETED if have >= M
                       else TaskState.RUNNING),
                attempts=avs, output_available=have >= M,
                output_nodes=("coord",))
        return ClusterSnapshot(now=time.time(), nodes=nodes, tasks=tviews)

    def _bino_tick(self, step, tasks, attempts, grads, shard_states,
                   recoveries) -> None:
        snap = self._snapshot(step, tasks, attempts, grads)
        actions = self.speculator.assess(snap)
        M = self.cfg.microbatches_per_shard
        for act in actions:
            if isinstance(act, MarkNodeFailed):
                if act.node_id in self.dead_hosts:
                    continue
                self.dead_hosts.add(act.node_id)
                recoveries.append(f"host {act.node_id} declared failed "
                                  f"({act.reason})")
                # fail its running attempts; reassignment happens via the
                # straggler path below or immediately here
                for a in list(attempts.values()):
                    if a.host_id == act.node_id \
                            and a.state == AttemptState.RUNNING:
                        a.state = AttemptState.FAILED
                        self._relaunch(step, tasks, attempts, grads,
                                       shard_states, a.task_id,
                                       reason="failure", recoveries=recoveries)
            elif isinstance(act, SpeculateTask):
                tid = act.task_id
                if tid not in tasks or tasks[tid]["done"]:
                    continue
                running = [a for a in tasks[tid]["attempts"]
                           if a.state == AttemptState.RUNNING]
                if any(a.speculative for a in running) or len(running) >= 2:
                    continue
                self._relaunch(step, tasks, attempts, grads, shard_states,
                               tid, reason=act.reason, recoveries=recoveries,
                               speculative=bool(running),
                               prefer=act.placement_hint)
            elif isinstance(act, KillAttempt):
                a = attempts.get(act.attempt_id)
                if a is not None and a.state == AttemptState.RUNNING:
                    a.state = AttemptState.KILLED
                    self.hosts[a.host_id].cancel(a.attempt_id)
        # Tail-straggler fallback (beyond-paper; DESIGN.md §10): once most
        # map tasks have drained, Eq. 1 loses its comparison population (the
        # paper's own small-job blind spot, §II.D.2) — so the coordinator
        # adds a LATE-style estimated-remaining-time check against the
        # completed population and shadow-executes the laggards.
        completed = [a for a in attempts.values()
                     if a.state == AttemptState.COMPLETED]
        running = [t for t in tasks.values() if not t["done"]]
        now = time.time()
        if completed and running and \
                len(running) <= max(1, len(tasks) // 4):
            durations = sorted((a.end - a.start) for a in completed)
            median = durations[len(durations) // 2]
            for t in tasks.values():
                if t["done"]:
                    continue
                live = [a for a in t["attempts"]
                        if a.state == AttemptState.RUNNING]
                if not live or any(a.speculative for a in live):
                    continue
                a = max(live, key=lambda a: a.mb_done)
                frac = a.mb_done / max(a.mb_total, 1)
                rate = frac / max(now - a.start, 1e-6)
                est_remaining = (1.0 - frac) / max(rate, 1e-6)
                if est_remaining > max(1.5 * median, 4 * self.cfg.spec_interval):
                    tid = [k for k, v in tasks.items() if v is t][0]
                    self._relaunch(step, tasks, attempts, grads,
                                   shard_states, tid,
                                   reason="tail-straggler",
                                   recoveries=recoveries, speculative=True)

    def _relaunch(self, step, tasks, attempts, grads, shard_states, tid,
                  *, reason: str, recoveries: List[str],
                  speculative: bool = False,
                  prefer: Sequence[str] = ()) -> None:
        shard = tasks[tid]["shard"]
        M = self.cfg.microbatches_per_shard
        # Rollback: resume past every microbatch already streamed (the
        # consumer-side MOF survives the producer) — exactly-once keeps
        # racing duplicates harmless anyway.
        have = sorted(m for (s, m) in grads if s == shard)
        resume = 0
        for m in have:
            if m == resume:
                resume += 1
            else:
                break
        if resume >= M:
            return
        exclude = {a.host_id for a in tasks[tid]["attempts"]
                   if a.state == AttemptState.RUNNING} | self.dead_hosts
        host = self._pick_host(tasks, exclude, prefer)
        if host is None:
            return
        st = self.datastates[shard]
        for _ in range(resume):
            st = st.advance()
        self._assign(tasks, attempts, tid, shard, host, resume,
                     speculative=speculative,
                     rollback=resume > 0, data_state=st)
        recoveries.append(
            f"{tid}: {reason} -> {'spec' if speculative else 'relaunch'} "
            f"on {host} from mb {resume}")

    # -- gang-restart baseline ---------------------------------------------
    def _restart_tick(self, tasks, attempts, recoveries) -> bool:
        now = time.time()
        with self._hb_lock:
            hb = dict(self.heartbeats)
        for hid in self.live_hosts():
            if now - hb.get(hid, 0.0) > self.cfg.restart_timeout:
                self.dead_hosts.add(hid)
                recoveries.append(
                    f"host {hid} timed out ({self.cfg.restart_timeout}s) "
                    "-> gang restart of step")
                # abort: cancel everything, discard partials
                for a in attempts.values():
                    if a.state == AttemptState.RUNNING:
                        a.state = AttemptState.KILLED
                        if a.host_id not in self.dead_hosts:
                            self.hosts[a.host_id].cancel(a.attempt_id)
                self._drain()
                return True
        return False

    def _drain(self) -> None:
        try:
            while True:
                self.queue.get_nowait()
        except queue.Empty:
            pass


def rec_step(task_id: str) -> int:
    return int(task_id.split("_")[0][1:])
