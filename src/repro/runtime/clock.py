"""Injectable time source for the live runtime (DESIGN.md §16.2).

Every timestamp, timeout and sleep in ``repro.runtime`` flows through a
:class:`Clock`, so the chaos tests can compress hours of failure-detection
timelines into milliseconds of wall clock and — more importantly — so no
test assertion ever races the scheduler against a real ``time.sleep``.

- :class:`SystemClock` — ``time.time``/``time.sleep``; the default, used
  by the load harness (honest p50/p99 latencies) and the examples.
- :class:`FakeClock` — virtual time. ``sleep`` blocks the calling thread
  until virtual now reaches its deadline; time moves only via
  :meth:`FakeClock.advance` or the auto-advancer, which jumps to the
  earliest pending deadline once the sleeper set has settled (no
  registrations/wake-ups for ``settle`` real seconds). Threads doing real
  work (a jitted grad computation) are simply not sleepers: virtual time
  waits for nobody but also never deadlocks on them, because at least the
  host heartbeat loops are always parked on a deadline.

The coordinator's policy thresholds (heartbeat silence, restart timeout,
backoff schedules, step deadlines) are all compared in *clock* time, so a
``FakeClock`` run exercises exactly the same detection logic as a real
deployment — just faster and reproducibly.
"""
from __future__ import annotations

import itertools
import threading
import time


class Clock:
    """Time-source protocol for the runtime."""

    def time(self) -> float:  # pragma: no cover - protocol
        raise NotImplementedError

    def sleep(self, dt: float) -> None:  # pragma: no cover - protocol
        raise NotImplementedError


class SystemClock(Clock):
    def time(self) -> float:
        return time.time()

    def sleep(self, dt: float) -> None:
        if dt > 0:
            time.sleep(dt)


class FakeClock(Clock):
    """Deterministically advanceable virtual clock for chaos tests.

    ``auto_advance=True`` starts a daemon that, whenever at least one
    thread is parked in :meth:`sleep` and nothing has changed for
    ``settle`` real seconds, jumps virtual time to the earliest pending
    deadline. A whole simulated failure-detection window (say a 6 s
    restart timeout) then elapses in a few milliseconds of wall time.
    """

    def __init__(self, start: float = 1000.0, *, auto_advance: bool = False,
                 settle: float = 0.002, max_real_wait: float = 0.05):
        self._now = float(start)
        self._cond = threading.Condition()
        self._waiters: dict = {}          # waiter id -> virtual deadline
        self._ids = itertools.count()
        self._activity = 0                # bumped on any state change
        self._settle = settle
        self._max_real_wait = max_real_wait
        self._stop = threading.Event()
        self._auto = None
        if auto_advance:
            self._auto = threading.Thread(target=self._auto_loop,
                                          daemon=True, name="fakeclock")
            self._auto.start()

    # -- Clock protocol --------------------------------------------------
    def time(self) -> float:
        with self._cond:
            return self._now

    def sleep(self, dt: float) -> None:
        if dt <= 0:
            time.sleep(0)  # yield
            return
        with self._cond:
            deadline = self._now + dt
            wid = next(self._ids)
            self._waiters[wid] = deadline
            self._activity += 1
            self._cond.notify_all()
            try:
                while self._now < deadline and not self._stop.is_set():
                    # Real-time cap: a FakeClock without an advancer (or a
                    # shutdown mid-sleep) must never hard-hang a daemon.
                    self._cond.wait(timeout=self._max_real_wait)
            finally:
                del self._waiters[wid]
                self._activity += 1
                self._cond.notify_all()

    # -- test control ----------------------------------------------------
    def advance(self, dt: float) -> None:
        with self._cond:
            self._now += float(dt)
            self._activity += 1
            self._cond.notify_all()

    def advance_to(self, t: float) -> None:
        with self._cond:
            if t > self._now:
                self._now = float(t)
                self._activity += 1
                self._cond.notify_all()

    def close(self) -> None:
        self._stop.set()
        with self._cond:
            self._cond.notify_all()

    # -- auto-advancer ---------------------------------------------------
    def _auto_loop(self) -> None:
        while not self._stop.is_set():
            with self._cond:
                snap = self._activity
                waiters = bool(self._waiters)
            time.sleep(self._settle)
            with self._cond:
                if (waiters and self._activity == snap
                        and self._waiters):
                    target = min(self._waiters.values())
                    if target > self._now:
                        self._now = target
                        self._activity += 1
                        self._cond.notify_all()
