"""TrainerRuntime: model + data + optimizer wired into the coordinator.

The end-to-end driver behind ``examples/train_lm.py``, ``examples/
serve.py`` and the runtime integration tests: trains any registry
architecture (reduced or full config) under injected host failures /
stragglers / chaos scripts, with either recovery strategy, checkpoint/
restore, and a per-step report stream.

Two rollback tiers (DESIGN.md §16.7):
- in-memory — the coordinator retries a wedged step from its pre-step
  commit point (model state only mutates on step success);
- durable  — when a step exhausts its retries (:class:`StepWedged`),
  ``run`` restores the last crash-safe checkpoint and re-runs from the
  restored step, dropping reports from rolled-back steps.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataState, ShardedTokenPipeline, TokenDataset
from repro.models import model as MODEL
from repro.optim.adamw import adamw_init, adamw_update
from repro.runtime.clock import Clock
from repro.runtime.coordinator import (
    Coordinator,
    RuntimeConfig,
    StepReport,
    StepWedged,
)
from repro.train.loop import TrainConfig, cross_entropy_loss


class TrainerRuntime:
    def __init__(self, cfg: ModelConfig, tc: TrainConfig,
                 rt: RuntimeConfig, *, seq_len: int = 128,
                 per_shard_batch: int = 2, seed: int = 0,
                 clock: Optional[Clock] = None, chaos=None,
                 obs=None, metrics=None):
        self.cfg = cfg
        self.tc = tc
        self.rt = rt
        self.dataset = TokenDataset(cfg.vocab_size, seq_len, seed=seed)
        self.per_shard_batch = per_shard_batch

        def loss_fn(params, batch):
            logits, aux, _ = MODEL.forward(cfg, params, batch,
                                           impl=tc.impl, remat=tc.remat)
            loss = cross_entropy_loss(logits, batch["labels"])
            return loss + aux, {"loss": loss}

        grad_val = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))

        def grad_fn(params, batch):
            (_, metrics), grads = grad_val(params, batch)
            return grads, metrics

        @jax.jit
        def apply_fn(state, grads):
            new_params, new_opt, opt_metrics = adamw_update(
                grads, state["opt"], state["params"],
                lr=tc.lr(), b1=tc.b1, b2=tc.b2,
                weight_decay=tc.weight_decay,
                grad_clip_norm=tc.grad_clip_norm)
            return {"params": new_params, "opt": new_opt,
                    "step": state["step"] + 1}

        def batch_fn(state: DataState) -> Dict[str, Any]:
            toks = self.dataset.batch(state.shard_id, state.offset,
                                      per_shard_batch)
            return {"tokens": jnp.asarray(toks[:, :-1]),
                    "labels": jnp.asarray(toks[:, 1:])}

        params = MODEL.init_params(cfg, jax.random.PRNGKey(seed))
        init_state = {"params": params, "opt": adamw_init(params),
                      "step": jnp.zeros((), jnp.int32)}
        shards = [DataState(seed, s, rt.n_hosts, 0)
                  for s in range(rt.n_hosts)]
        self.coord = Coordinator(
            rt, grad_fn=grad_fn, apply_fn=apply_fn, batch_fn=batch_fn,
            init_state=init_state, datastates=shards,
            clock=clock, chaos=chaos, obs=obs, metrics=metrics)
        self.ckpt = (CheckpointManager(rt.checkpoint_dir)
                     if rt.checkpoint_dir else None)
        self._start_step = 0
        if self.ckpt is not None and self.ckpt.latest_step() is not None:
            self.restore()

    # ------------------------------------------------------------------
    @property
    def state(self):
        return self.coord.state

    def restore(self) -> int:
        tree, step, meta = self.ckpt.restore(self.coord.state)
        self.coord.state = jax.tree.map(jnp.asarray, tree)
        self.coord.datastates = [
            DataState(**d) for d in meta["datastates"]]
        self._start_step = step
        return step

    def run(self, n_steps: int,
            on_step: Optional[Callable[[int, "TrainerRuntime"], None]] = None,
            max_durable_rollbacks: int = 2) -> List[StepReport]:
        reports: List[StepReport] = []
        target = self._start_step + n_steps
        i = self._start_step
        rollbacks = 0
        while i < target:
            if on_step is not None:
                on_step(i, self)
            try:
                rep = self.coord.run_step(i)
            except StepWedged:
                # durable rollback: restore the last crash-safe checkpoint
                # and re-run from there (DESIGN.md §16.7)
                if (self.ckpt is None or self.ckpt.latest_step() is None
                        or rollbacks >= max_durable_rollbacks):
                    raise
                rollbacks += 1
                self.ckpt.wait()
                step = self.restore()
                reports = [r for r in reports if r.step < step]
                i = step
                continue
            reports.append(rep)
            if self.ckpt is not None and self.rt.checkpoint_every and \
                    (i + 1) % self.rt.checkpoint_every == 0:
                self.ckpt.save_async(
                    self.coord.state, i + 1,
                    metadata={"datastates": [
                        dataclasses.asdict(d)
                        for d in self.coord.datastates]})
            i += 1
        if self.ckpt is not None:
            self.ckpt.wait()
        return reports

    # fault-injection passthroughs ---------------------------------------
    def freeze_host(self, host_id: str) -> None:
        self.coord.hosts[host_id].freeze()

    def slow_host(self, host_id: str, factor: float) -> None:
        self.coord.hosts[host_id].slow(factor)

    def mute_host(self, host_id: str, duration: float) -> None:
        self.coord.hosts[host_id].mute(duration)

    def shutdown(self) -> None:
        self.coord.shutdown()
