"""Chaos fault plane for the live runtime (DESIGN.md §16.3).

One declarative fault script, two worlds: the same plain tuples
``(kind, idx, x, y)`` that ``repro.sim.faults.apply_script`` arms against
the discrete-event simulator are interpreted here against *real threads*
— the :class:`ChaosController` wraps each ``HostDaemon``'s channels to
the coordinator and injects the paper's fault vocabulary with live
timing:

========  ==========================================================
kind      runtime effect (victim = ``hosts[idx % n]``)
========  ==========================================================
crash     ``host.freeze()`` — heartbeats and compute stop, for good
crash_restore  freeze, then ``unfreeze()`` after the scaled duration
hang      ``host.hang()`` — compute stops, heartbeats keep flowing
slow      ``host.slow(f)`` — microbatches take 1/(0.02+0.06y)× longer
hb        ``host.mute(dur)`` — heartbeats vanish, compute continues
delay_hb  heartbeats delivered late (original timestamps) for a window
drop      outbound Grad/Progress/Ack messages silently discarded
dup       outbound messages delivered twice
reorder   adjacent outbound messages pairwise swapped
cut       transient link cut: outbound messages + heartbeats dropped
          AND inbound work-item delivery dropped (exercises the
          coordinator's ack/retry path), for a window
degrade   → slow (no rack switches in the thread runtime; §16.4)
part      → cut       (single-host partition)
mof       → drop      (a lost consumer-side MOF is a lost message)
disk      → hang for a short window (attempt stalls, host healthy)
========  ==========================================================

``x`` maps to an absolute fire time ``t0 + x*horizon``; ``y`` scales
durations/magnitudes. All randomness (none today — scripts are fully
deterministic) would come from the seeded RNG, so a script replays
identically given the same clock behaviour.

The controller never touches payloads: a "duplicated" GradMessage is the
same object delivered twice, which the coordinator's first-writer-wins
dedup must (and does) swallow — that is the exactly-once invariant the
chaos matrix in ``tests/test_runtime.py`` pins down.
"""
from __future__ import annotations

import heapq
import itertools
import random
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.obs.trace import FAULT_CODES, K_FAULT
from repro.runtime.clock import Clock
from repro.runtime.hosts import AckMessage, GradMessage, ProgressMessage

Script = Sequence[Tuple[str, int, float, float]]

# Named pinned scripts: the chaos corpus used by tests/test_runtime.py,
# examples/serve.py --chaos <name>, and benchmarks/perf_runtime.py. Keep
# in sync with SCRIPT_KINDS in repro/sim/faults.py.
PINNED_SCRIPTS: Dict[str, List[Tuple[str, int, float, float]]] = {
    "crash": [("crash", 1, 0.2, 0.0)],
    "crash_restore": [("crash_restore", 1, 0.15, 0.3)],
    "hang": [("hang", 2, 0.2, 0.4)],
    "slow": [("slow", 2, 0.1, 0.5)],
    "hb_outage": [("hb", 1, 0.15, 0.3)],
    "delay_hb": [("delay_hb", 1, 0.1, 0.5)],
    "drop": [("drop", 1, 0.1, 0.5)],
    "dup": [("dup", 0, 0.05, 0.9)],
    "reorder": [("reorder", 3, 0.05, 0.8)],
    "cut": [("cut", 1, 0.15, 0.35)],
    "crash_plus_drop": [("crash", 1, 0.25, 0.0), ("drop", 2, 0.1, 0.4)],
}


def parse_script(text: str) -> List[Tuple[str, int, float, float]]:
    """``--chaos`` argument: a pinned-script name, or inline steps
    ``kind:idx:x:y[,kind:idx:x:y...]``."""
    if text in PINNED_SCRIPTS:
        return list(PINNED_SCRIPTS[text])
    steps = []
    for part in text.split(","):
        kind, idx, x, y = part.split(":")
        steps.append((kind, int(idx), float(x), float(y)))
    return steps


class _HostState:
    """Active fault windows for one host (virtual-time deadlines)."""

    __slots__ = ("drop_until", "dup_until", "reorder_until", "cut_until",
                 "hb_delay_until", "hb_delay", "held", "lock")

    def __init__(self) -> None:
        self.drop_until = 0.0
        self.dup_until = 0.0
        self.reorder_until = 0.0
        self.cut_until = 0.0
        self.hb_delay_until = 0.0
        self.hb_delay = 0.0
        self.held = None  # reorder buffer: at most one message in flight
        self.lock = threading.Lock()


class _OutTap:
    """Queue facade interposed between a host and the coordinator inbox."""

    def __init__(self, ctrl: "ChaosController", host_id: str, down) -> None:
        self._ctrl = ctrl
        self._hid = host_id
        self._down = down

    def put(self, msg) -> None:
        self._ctrl._on_out(self._hid, msg, self._down)


class ChaosController:
    """Interprets a declarative fault script against live host threads."""

    def __init__(self, script: Script, *, horizon: float = 4.0,
                 seed: int = 0, defer_arm: bool = False) -> None:
        self.script = [tuple(s) for s in script]
        self.horizon = float(horizon)
        self.defer_arm = bool(defer_arm)
        # Optional flight recorder (repro.obs): one K_FAULT ground-truth
        # record per script step, emitted at fire time from THIS
        # controller's scheduler thread — pass a recorder built with
        # ``thread_safe=True``. The Coordinator auto-wires its own ``obs``
        # here when none was set.
        self.obs = None
        self._armed = False
        self.rng = random.Random(seed)
        self.stats: Dict[str, int] = {}
        self._states: Dict[str, _HostState] = {}
        self._hosts: Dict[str, object] = {}
        self._clock: Optional[Clock] = None
        self._t0 = 0.0
        self._events: list = []  # heap of (virtual time, seq, fn)
        self._seq = itertools.count()
        self._ev_lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- wiring (called by the Coordinator while spawning hosts) ---------
    def wrap_out(self, host_id: str, down_queue):
        self._states.setdefault(host_id, _HostState())
        return _OutTap(self, host_id, down_queue)

    def wrap_heartbeat(self, host_id: str,
                       cb: Callable[[str, float], None]):
        self._states.setdefault(host_id, _HostState())

        def wrapped(hid: str, ts: float) -> None:
            st = self._states[hid]
            now = self._now()
            if st.cut_until > now:
                self._bump("hb_dropped")
                return
            if st.hb_delay_until > now and st.hb_delay > 0:
                # delivered late, with the ORIGINAL timestamp — the
                # coordinator's monotonic max() guard must absorb the
                # resulting reordering
                self._bump("hb_delayed")
                self._schedule(now + st.hb_delay, lambda: cb(hid, ts))
                return
            cb(hid, ts)

        return wrapped

    def deliver_assign(self, host, item) -> bool:
        """Coordinator→host work-item delivery; a cut link eats it (the
        unacked send is retried with backoff — §16.5). Returns whether
        the item was actually delivered."""
        st = self._states.get(host.host_id)
        if st is not None and st.cut_until > self._now():
            self._bump("assign_dropped")
            return False
        host.assign(item)
        return True

    def arm(self, hosts: Dict[str, object], clock: Clock) -> None:
        """Wire up hosts/clock; unless ``defer_arm``, compile the script
        into timed events and start the scheduler immediately."""
        self._hosts = dict(hosts)
        self._clock = clock
        if not self.defer_arm:
            self.release()

    def release(self) -> None:
        """Compile the script against *now* (``t0 = clock.time()``) and
        start the scheduler. Called automatically from :meth:`arm` unless
        ``defer_arm=True`` — the load harness defers so JIT warm-up steps
        run fault-free and the fault lands at a known measured instant."""
        if self._armed:
            return
        assert self._clock is not None, "release() before arm()"
        self._armed = True
        self._t0 = self._clock.time()
        ids = sorted(self._hosts)
        for kind, idx, x, y in self.script:
            hid = ids[idx % len(ids)]
            self._compile(kind, hid, float(x), float(y),
                          pos=idx % len(ids))
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="chaos-sched")
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        # flush any reorder-held message so nothing is silently lost
        for hid, st in self._states.items():
            with st.lock:
                held, st.held = st.held, None
            if held is not None:
                self._bump("reorder_flushed")

    # -- script compilation ----------------------------------------------
    def _compile(self, kind: str, hid: str, x: float, y: float,
                 pos: int = -1) -> None:
        at = self._t0 + x * self.horizon
        dur = (0.15 + 0.5 * y) * self.horizon
        host = self._hosts[hid]
        st = self._states.setdefault(hid, _HostState())

        def emit_fault() -> None:
            # ground truth for the speculation scorecard (§18.4): reads
            # self.obs at fire time so late wiring still records
            rec = self.obs
            if rec is not None:
                rec.emit(K_FAULT, a=pos, b=FAULT_CODES.get(kind, 0),
                         f0=x, f1=y, obj=hid)

        self._schedule(at, emit_fault)

        def window(attr: str) -> None:
            # windows only ever extend (overlap unions, like the sim)
            setattr(st, attr, max(getattr(st, attr), at + dur))

        if kind == "crash":
            self._schedule(at, host.freeze)
        elif kind == "crash_restore":
            self._schedule(at, host.freeze)
            self._schedule(at + dur, host.unfreeze)
        elif kind in ("hang", "disk"):
            d = dur if kind == "hang" else 0.35 * self.horizon
            self._schedule(at, host.hang)
            self._schedule(at + d, host.unhang)
        elif kind in ("slow", "degrade"):
            factor = 1.0 / (0.02 + 0.06 * y)  # sim speed -> delay multiple
            if kind == "degrade":
                factor = min(factor, 8.0)
            self._schedule(at, lambda: host.slow(factor))
            self._schedule(at + dur, lambda: host.slow(1.0))
        elif kind == "hb":
            self._schedule(at, lambda: host.mute(dur))
        elif kind == "delay_hb":
            delay = (0.05 + 0.25 * y) * self.horizon

            def start_delay() -> None:
                st.hb_delay = max(st.hb_delay, delay)
                window("hb_delay_until")

            self._schedule(at, start_delay)
        elif kind in ("drop", "mof"):
            self._schedule(at, lambda: window("drop_until"))
        elif kind == "dup":
            self._schedule(at, lambda: window("dup_until"))
        elif kind == "reorder":
            self._schedule(at, lambda: window("reorder_until"))
            # flush a straggler held past the window's end
            self._schedule(at + dur + 1e-6, lambda: self._flush_held(hid))
        elif kind in ("cut", "part"):
            self._schedule(at, lambda: window("cut_until"))
        else:  # pragma: no cover - corpus bug guard
            raise ValueError(f"unknown chaos kind: {kind}")

    # -- message-plane interposition --------------------------------------
    def _on_out(self, hid: str, msg, down) -> None:
        if not isinstance(msg, (GradMessage, ProgressMessage, AckMessage)):
            down.put(msg)
            return
        st = self._states[hid]
        now = self._now()
        if st.cut_until > now or st.drop_until > now:
            self._bump("msg_dropped")
            return
        if st.reorder_until > now:
            with st.lock:
                if st.held is None:
                    st.held = msg
                    return
                held, st.held = st.held, None
            self._bump("msg_reordered")
            down.put(msg)    # later message first...
            down.put(held)   # ...then the earlier one
            return
        if st.dup_until > now:
            self._bump("msg_duplicated")
            down.put(msg)
            down.put(msg)
            return
        with st.lock:
            held, st.held = st.held, None
        if held is not None:  # reorder window just closed
            down.put(held)
        down.put(msg)

    def _flush_held(self, hid: str) -> None:
        st = self._states[hid]
        with st.lock:
            held, st.held = st.held, None
        if held is not None:
            self._bump("reorder_flushed")
            # downstream queue is the coordinator inbox; every tap of a
            # host shares it, so any tap's down works — use none: deliver
            # via the host's out queue is gone here, so stash on coord
            self._late_deliver(held)

    def _late_deliver(self, msg) -> None:
        # the coordinator inbox is shared across hosts; grab it from any
        # armed host's out tap
        for host in self._hosts.values():
            out = getattr(host, "out", None)
            if isinstance(out, _OutTap):
                out._down.put(msg)
                return

    # -- scheduler ---------------------------------------------------------
    def _schedule(self, at: float, fn: Callable[[], None]) -> None:
        with self._ev_lock:
            heapq.heappush(self._events, (at, next(self._seq), fn))

    def _loop(self) -> None:
        while not self._stop.is_set():
            with self._ev_lock:
                head = self._events[0] if self._events else None
            if head is None:
                if not self._stop.is_set():
                    time.sleep(0.005)
                    with self._ev_lock:
                        empty = not self._events
                    if empty:
                        continue
                continue
            now = self._now()
            at, _, fn = head
            if now + 1e-9 >= at:
                with self._ev_lock:
                    heapq.heappop(self._events)
                try:
                    fn()
                    self._bump("events_fired")
                except Exception:  # pragma: no cover - fault hooks are
                    pass           # best-effort; never kill the scheduler
            else:
                # clock-aware wait: under FakeClock this parks a deadline
                # the auto-advancer can jump to
                assert self._clock is not None
                self._clock.sleep(min(at - now, 0.05 * self.horizon))

    # -- helpers -----------------------------------------------------------
    def _now(self) -> float:
        return self._clock.time() if self._clock is not None else 0.0

    def _bump(self, key: str) -> None:
        self.stats[key] = self.stats.get(key, 0) + 1
