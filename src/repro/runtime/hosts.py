"""Host daemons: thread-simulated TPU hosts with a real control plane.

Each ``HostDaemon`` executes assigned *map work* — microbatch gradient
production for a data shard — and streams results + progress reports to
the coordinator. Fault injection mirrors the simulator's vocabulary:
``freeze()`` (crash: heartbeats and compute stop), ``hang()`` (the liar
node: compute stops but heartbeats keep flowing), ``slow(factor)``
(straggler), ``mute(duration)`` (transient network outage: compute
continues, heartbeats vanish). Message-plane faults (drop / duplicate /
delay / reorder on the way to the coordinator) are injected one layer
up, by ``repro.runtime.chaos`` wrapping the out-queue and the heartbeat
callback (DESIGN.md §16.3).

Delivery is at-least-once: the coordinator redelivers unacknowledged
``WorkItem``s with backoff, so the daemon acks every item and keeps a
seen-set to make redelivery idempotent (§16.5). All time flows through
an injected :class:`repro.runtime.clock.Clock`.

The JAX computation itself runs in-process (one CPU device stands in for
every host's chip); what is REAL here is the control plane the paper is
about: heartbeats, progress logs, speculative reassignment, rollback.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, Optional

from repro.data.pipeline import DataState
from repro.runtime.clock import Clock, SystemClock


@dataclasses.dataclass
class WorkItem:
    """One map task: produce grads for microbatches [mb_start, mb_end) of
    ``shard`` at ``step``. ``data_state`` pins the exact batches."""

    step: int
    task_id: str
    shard_id: int
    mb_start: int
    mb_end: int
    data_state: DataState
    attempt_id: str = ""
    speculative: bool = False


@dataclasses.dataclass
class GradMessage:
    """One microbatch's contribution, streamed eagerly (the 'MOF' lives on
    the consumer side the moment it exists — eager shuffle)."""

    step: int
    task_id: str
    attempt_id: str
    shard_id: int
    mb_index: int
    grads: Any
    metrics: Dict[str, float]
    host_id: str


@dataclasses.dataclass
class ProgressMessage:
    step: int
    task_id: str
    attempt_id: str
    host_id: str
    mb_done: int
    mb_total: int
    data_state: DataState
    done: bool = False


@dataclasses.dataclass
class AckMessage:
    """Work-item receipt: the coordinator stops redelivering on this.
    Acks themselves ride the (chaos-faultable) out-queue, so a dropped
    ack triggers a redelivery the seen-set then swallows — idempotent in
    both directions."""

    step: int
    attempt_id: str
    host_id: str


class HostDaemon(threading.Thread):
    def __init__(self, host_id: str, *, grad_fn: Callable,
                 batch_fn: Callable[[DataState], Dict[str, Any]],
                 out_queue, heartbeat: Callable[[str, float], None],
                 heartbeat_period: float = 0.05,
                 compute_delay: float = 0.0,
                 clock: Optional[Clock] = None):
        super().__init__(daemon=True, name=f"host-{host_id}")
        self.host_id = host_id
        self.grad_fn = grad_fn
        self.batch_fn = batch_fn
        self.out = out_queue
        self.heartbeat_cb = heartbeat
        self.heartbeat_period = heartbeat_period
        self.clock = clock if clock is not None else SystemClock()
        # artificial per-microbatch delay: makes tiny test models behave
        # like real work so stragglers/failures have visible timelines
        self.compute_delay = compute_delay
        self._work: "queue.Queue[Optional[WorkItem]]" = queue.Queue()
        self._params = None
        self._params_lock = threading.Lock()
        # fault state
        self._frozen = threading.Event()
        self._hung = threading.Event()
        self._speed = 1.0
        self._mute_until = 0.0
        self._halt = threading.Event()
        self._cancelled: set = set()
        # at-least-once delivery: attempt ids already accepted (redelivered
        # work items are re-acked but not re-executed)
        self._seen: set = set()

    # -- control ---------------------------------------------------------
    def set_params(self, params) -> None:
        with self._params_lock:
            self._params = params

    def assign(self, item: WorkItem) -> None:
        self._work.put(item)

    def cancel(self, attempt_id: str) -> None:
        self._cancelled.add(attempt_id)

    def shutdown(self) -> None:
        self._halt.set()
        self._work.put(None)

    # -- fault injection ---------------------------------------------------
    def freeze(self) -> None:
        """Crash: no heartbeats, no compute, in-flight work lost."""
        self._frozen.set()

    def unfreeze(self) -> None:
        self._frozen.clear()

    def hang(self) -> None:
        """Livelock: compute stops but heartbeats keep flowing — the node
        that looks healthy to Eq. 4 and can only be caught by the
        progress-based assessments (Eq. 1–3 / tail-straggler)."""
        self._hung.set()

    def unhang(self) -> None:
        self._hung.clear()

    def slow(self, factor: float) -> None:
        """Straggler: microbatches take ``factor×`` longer."""
        self._speed = max(factor, 1e-3)

    def mute(self, duration: float) -> None:
        """Transient outage: heartbeats vanish, compute continues."""
        self._mute_until = self.clock.time() + duration

    @property
    def frozen(self) -> bool:
        return self._frozen.is_set()

    # -- main loop --------------------------------------------------------
    def _hb_loop(self) -> None:
        """NodeManager heartbeat thread: independent of task work (a busy
        or compiling host still heartbeats — only crash/outage silences)."""
        while not self._halt.is_set():
            now = self.clock.time()
            if not self._frozen.is_set() and now >= self._mute_until:
                self.heartbeat_cb(self.host_id, now)
            self.clock.sleep(self.heartbeat_period)

    def run(self) -> None:
        threading.Thread(target=self._hb_loop, daemon=True,
                         name=f"hb-{self.host_id}").start()
        while not self._halt.is_set():
            try:
                item = self._work.get(timeout=self.heartbeat_period)
            except queue.Empty:
                continue
            if item is None:
                return
            # Ack on receipt; a redelivered item is acked again but not
            # re-executed (exactly-once execution under at-least-once
            # delivery).
            first = item.attempt_id not in self._seen
            self._seen.add(item.attempt_id)
            self.out.put(AckMessage(step=item.step,
                                    attempt_id=item.attempt_id,
                                    host_id=self.host_id))
            if first:
                self._execute(item)

    def _blocked(self) -> bool:
        return self._frozen.is_set() or self._hung.is_set()

    def _execute(self, item: WorkItem) -> None:
        state = item.data_state
        for mb in range(item.mb_start, item.mb_end):
            # crash/hang = stop making progress, silently
            while self._blocked():
                if self._halt.is_set():
                    return
                time.sleep(0.002)
            if item.attempt_id in self._cancelled or self._halt.is_set():
                return
            batch = self.batch_fn(state)
            with self._params_lock:
                params = self._params
            grads, metrics = self.grad_fn(params, batch)
            delay = self.compute_delay * self._speed
            if delay > 0:
                self.clock.sleep(delay)
            if self._frozen.is_set():
                return  # crashed during compute: result lost with the host
            if self._hung.is_set():
                continue_at = mb  # hung mid-compute: result withheld
                while self._hung.is_set() and not self._frozen.is_set():
                    if self._halt.is_set() \
                            or item.attempt_id in self._cancelled:
                        return
                    time.sleep(0.002)
                if self._frozen.is_set():
                    return
                del continue_at
            state = state.advance()
            self.out.put(GradMessage(
                step=item.step, task_id=item.task_id,
                attempt_id=item.attempt_id, shard_id=item.shard_id,
                mb_index=mb, grads=grads,
                metrics={k: float(v) for k, v in metrics.items()},
                host_id=self.host_id))
            self.out.put(ProgressMessage(
                step=item.step, task_id=item.task_id,
                attempt_id=item.attempt_id, host_id=self.host_id,
                mb_done=mb + 1 - item.mb_start,
                mb_total=item.mb_end - item.mb_start,
                data_state=state,
                done=(mb == item.mb_end - 1)))
