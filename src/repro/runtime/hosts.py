"""Host daemons: thread-simulated TPU hosts with a real control plane.

Each ``HostDaemon`` executes assigned *map work* — microbatch gradient
production for a data shard — and streams results + progress reports to
the coordinator. Fault injection mirrors the simulator's vocabulary:
``freeze()`` (crash: heartbeats and compute stop), ``slow(factor)``
(straggler), ``mute(duration)`` (transient network outage: compute
continues, heartbeats vanish).

The JAX computation itself runs in-process (one CPU device stands in for
every host's chip); what is REAL here is the control plane the paper is
about: heartbeats, progress logs, speculative reassignment, rollback.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, Optional

from repro.data.pipeline import DataState


@dataclasses.dataclass
class WorkItem:
    """One map task: produce grads for microbatches [mb_start, mb_end) of
    ``shard`` at ``step``. ``data_state`` pins the exact batches."""

    step: int
    task_id: str
    shard_id: int
    mb_start: int
    mb_end: int
    data_state: DataState
    attempt_id: str = ""
    speculative: bool = False


@dataclasses.dataclass
class GradMessage:
    """One microbatch's contribution, streamed eagerly (the 'MOF' lives on
    the consumer side the moment it exists — eager shuffle)."""

    step: int
    task_id: str
    attempt_id: str
    shard_id: int
    mb_index: int
    grads: Any
    metrics: Dict[str, float]
    host_id: str


@dataclasses.dataclass
class ProgressMessage:
    step: int
    task_id: str
    attempt_id: str
    host_id: str
    mb_done: int
    mb_total: int
    data_state: DataState
    done: bool = False


class HostDaemon(threading.Thread):
    def __init__(self, host_id: str, *, grad_fn: Callable,
                 batch_fn: Callable[[DataState], Dict[str, Any]],
                 out_queue: "queue.Queue", heartbeat: Callable[[str, float], None],
                 heartbeat_period: float = 0.05,
                 compute_delay: float = 0.0):
        super().__init__(daemon=True, name=f"host-{host_id}")
        self.host_id = host_id
        self.grad_fn = grad_fn
        self.batch_fn = batch_fn
        self.out = out_queue
        self.heartbeat_cb = heartbeat
        self.heartbeat_period = heartbeat_period
        # artificial per-microbatch delay: makes tiny test models behave
        # like real work so stragglers/failures have visible timelines
        self.compute_delay = compute_delay
        self._work: "queue.Queue[Optional[WorkItem]]" = queue.Queue()
        self._params = None
        self._params_lock = threading.Lock()
        # fault state
        self._frozen = threading.Event()
        self._speed = 1.0
        self._mute_until = 0.0
        self._stop = threading.Event()
        self._cancelled: set = set()

    # -- control ---------------------------------------------------------
    def set_params(self, params) -> None:
        with self._params_lock:
            self._params = params

    def assign(self, item: WorkItem) -> None:
        self._work.put(item)

    def cancel(self, attempt_id: str) -> None:
        self._cancelled.add(attempt_id)

    def shutdown(self) -> None:
        self._stop.set()
        self._work.put(None)

    # -- fault injection ---------------------------------------------------
    def freeze(self) -> None:
        """Crash: no heartbeats, no compute, in-flight work lost."""
        self._frozen.set()

    def unfreeze(self) -> None:
        self._frozen.clear()

    def slow(self, factor: float) -> None:
        """Straggler: microbatches take ``factor×`` longer."""
        self._speed = max(factor, 1e-3)

    def mute(self, duration: float) -> None:
        """Transient outage: heartbeats vanish, compute continues."""
        self._mute_until = time.time() + duration

    @property
    def frozen(self) -> bool:
        return self._frozen.is_set()

    # -- main loop --------------------------------------------------------
    def _hb_loop(self) -> None:
        """NodeManager heartbeat thread: independent of task work (a busy
        or compiling host still heartbeats — only crash/outage silences)."""
        while not self._stop.is_set():
            now = time.time()
            if not self._frozen.is_set() and now >= self._mute_until:
                self.heartbeat_cb(self.host_id, now)
            time.sleep(self.heartbeat_period)

    def run(self) -> None:
        threading.Thread(target=self._hb_loop, daemon=True,
                         name=f"hb-{self.host_id}").start()
        while not self._stop.is_set():
            try:
                item = self._work.get(timeout=self.heartbeat_period)
            except queue.Empty:
                continue
            if item is None:
                return
            self._execute(item)

    def _execute(self, item: WorkItem) -> None:
        state = item.data_state
        for mb in range(item.mb_start, item.mb_end):
            # crash = stop mid-task, silently
            while self._frozen.is_set():
                if self._stop.is_set():
                    return
                time.sleep(0.01)
            if item.attempt_id in self._cancelled or self._stop.is_set():
                return
            batch = self.batch_fn(state)
            with self._params_lock:
                params = self._params
            grads, metrics = self.grad_fn(params, batch)
            delay = self.compute_delay * self._speed
            if delay > 0:
                time.sleep(delay)
            if self._frozen.is_set():
                return  # crashed during compute: result lost with the host
            state = state.advance()
            self.out.put(GradMessage(
                step=item.step, task_id=item.task_id,
                attempt_id=item.attempt_id, shard_id=item.shard_id,
                mb_index=mb, grads=grads,
                metrics={k: float(v) for k, v in metrics.items()},
                host_id=self.host_id))
            self.out.put(ProgressMessage(
                step=item.step, task_id=item.task_id,
                attempt_id=item.attempt_id, host_id=self.host_id,
                mb_done=mb + 1 - item.mb_start,
                mb_total=item.mb_end - item.mb_start,
                data_state=state,
                done=(mb == item.mb_end - 1)))
