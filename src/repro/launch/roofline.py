"""Roofline analysis over the dry-run artifacts (§Roofline of
EXPERIMENTS.md).

Per (arch × shape) cell on the single-pod mesh:

    compute_s    = HLO_FLOPs_per_device   / PEAK_FLOPS_BF16
    memory_s     = HBM-traffic lower bound / HBM_BW
    collective_s = collective_bytes_per_device / ICI_BW

Memory accounting: ``cost_analysis()['bytes accessed']`` on the CPU
backend counts every f32-promotion copy of bf16 operands (the CPU has no
native bf16 matmul) and re-counts buffers at every consumer — a TPU fuses
these into the MXU. We therefore use buffer-level traffic
``arguments + outputs + 2×temporaries`` as the HBM lower bound for the
bound attribution, and keep the pessimistic accessed-bytes figure as
``mem_hi`` for reference. True HBM time lies between the two.

The dominant term is the bottleneck; the roofline fraction is
``useful_compute_s / max(term)`` where useful compute is the analytic
MODEL_FLOPS (6·N_active·D for training, 2·N_active·D for inference) at
peak — i.e. how much of the roofline-limited step time is spent on
irreducible model math.

Records tagged ``unroll`` are exact (XLA cost analysis counts a lax.scan
body once, so scanned records undercount per-layer FLOPs/collectives);
scanned records are used as fallback and flagged approximate.

Usage:
    PYTHONPATH=src python -m repro.launch.roofline [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import dataclasses
import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import ARCH_IDS, ALL_SHAPES, get_config, get_shape, skip_reason
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    tag: str
    compute_s: float
    memory_s: float      # buffer-traffic lower bound
    memory_hi_s: float   # accessed-bytes upper bound (CPU-promotion incl.)
    collective_s: float
    model_flops_global: float
    hlo_flops_global: float
    n_devices: int
    exact: bool

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_s(self) -> float:
        return self.model_flops_global / self.n_devices / PEAK_FLOPS_BF16

    @property
    def roofline_fraction(self) -> float:
        return self.useful_s / max(self.step_s, 1e-30)

    @property
    def flops_utilization(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        return self.model_flops_global / max(self.hlo_flops_global, 1e-30)


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    _, n_active = cfg.param_counts()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one new token per sequence
    return 2.0 * n_active * shape.global_batch


def advice(c: Cell) -> str:
    if c.bound == "collective":
        return ("shrink collective bytes: cast all-reduced activations/"
                "grads to bf16, reduce-scatter instead of all-reduce, or "
                "re-shard so the hot einsum keeps its contraction local")
    if c.bound == "memory":
        return ("raise arithmetic intensity: fuse the attention/scan path "
                "(Pallas), keep working sets in VMEM, batch decode requests "
                "deeper so weights are re-used per byte")
    if c.flops_utilization < 0.7:
        return ("compute-bound but wasteful: relax the remat policy "
                "(checkpoint dots only) to cut recompute FLOPs")
    return ("compute-bound at high utilization: gains now come from MXU "
            "shape alignment (128-multiples) and overlap of the remaining "
            "collectives with compute")


def load_cells(dirpath: str, mesh: str = "pod16x16") -> Dict[tuple, Cell]:
    by_key: Dict[tuple, Cell] = {}
    for path in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("mesh") != mesh or "skipped" in rec:
            continue
        if rec.get("tag") not in ("", "unroll"):
            continue  # perf-experiment records are handled separately
        key = (rec["arch"], rec["shape"])
        exact = rec.get("tag") == "unroll"
        if key in by_key and by_key[key].exact and not exact:
            continue
        n_dev = rec["n_devices"]
        mem = rec["memory"]
        traffic_lb = (mem["argument_bytes"] + mem["output_bytes"]
                      + 2 * mem["temp_bytes"])
        cell = Cell(
            arch=rec["arch"], shape=rec["shape"], tag=rec.get("tag", ""),
            compute_s=rec["flops_per_device"] / PEAK_FLOPS_BF16,
            memory_s=traffic_lb / HBM_BW,
            memory_hi_s=rec["bytes_accessed_per_device"] / HBM_BW,
            collective_s=rec["collectives"]["total_bytes"] / ICI_BW,
            model_flops_global=model_flops(rec["arch"], rec["shape"]),
            hlo_flops_global=rec["flops_per_device"] * n_dev,
            n_devices=n_dev,
            exact=exact)
        if key not in by_key or (exact and not by_key[key].exact):
            by_key[key] = cell
    return by_key


def table(cells: Dict[tuple, Cell]) -> str:
    lines = [
        "| arch | shape | compute | mem_lb | mem_hi | collective | bound | "
        "MODEL/HLO | roofline frac | exact |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_IDS:
        for shape in ALL_SHAPES:
            reason = skip_reason(get_config(arch), shape)
            if reason is not None:
                lines.append(f"| {arch} | {shape.name} | — | — | — | — | "
                             f"N/A | — | — | skip: {reason} |")
                continue
            c = cells.get((arch, shape.name))
            if c is None:
                lines.append(f"| {arch} | {shape.name} | … | … | … | … | "
                             "pending | … | … | |")
                continue
            if c.exact:
                util = f"{c.flops_utilization:.2f}"
                frac = f"{c.roofline_fraction:.2%}"
            else:
                # scan records undercount per-layer FLOPs/collectives:
                # the ratio columns would mislead — structural terms only
                util = frac = "n/a(scan)"
            lines.append(
                f"| {arch} | {shape.name} | {c.compute_s*1e3:.2f}ms | "
                f"{c.memory_s*1e3:.2f}ms | {c.memory_hi_s*1e3:.2f}ms | "
                f"{c.collective_s*1e3:.2f}ms | "
                f"{c.bound} | {util} | {frac} | "
                f"{'yes' if c.exact else 'scan(approx)'} |")
    return "\n".join(lines)


def pick_hillclimb(cells: Dict[tuple, Cell]) -> List[tuple]:
    """worst roofline fraction, most collective-bound, most representative
    (largest-model training cell — the production case the fault-tolerant
    runtime exists for)."""
    live = [c for c in cells.values() if c.exact]
    if not live:
        live = list(cells.values())
    worst = min(live, key=lambda c: c.roofline_fraction)
    coll = max(live, key=lambda c: c.collective_s / max(c.step_s, 1e-30))
    train_cells = [c for c in live if c.shape == "train_4k"]
    rep = max(train_cells,
              key=lambda c: get_config(c.arch).param_counts()[0]) \
        if train_cells else worst
    seen, out = set(), []
    for c in (worst, coll, rep):
        if (c.arch, c.shape) not in seen:
            seen.add((c.arch, c.shape))
            out.append((c.arch, c.shape))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--csv", default="results/roofline.csv")
    args = ap.parse_args()
    cells = load_cells(args.dir)
    print(table(cells))
    print()
    for (arch, shape), c in sorted(cells.items()):
        print(f"{arch} × {shape}: bound={c.bound}; {advice(c)}")
    picks = pick_hillclimb(cells)
    print("\nhillclimb candidates:", picks)
    os.makedirs(os.path.dirname(args.csv), exist_ok=True)
    with open(args.csv, "w") as f:
        f.write("arch,shape,compute_s,memory_s,memory_hi_s,collective_s,"
                "bound,model_over_hlo,roofline_fraction,exact\n")
        for (arch, shape), c in sorted(cells.items()):
            f.write(f"{arch},{shape},{c.compute_s:.6g},{c.memory_s:.6g},"
                    f"{c.memory_hi_s:.6g},{c.collective_s:.6g},{c.bound},"
                    f"{c.flops_utilization:.4f},"
                    f"{c.roofline_fraction:.4f},{int(c.exact)}\n")


if __name__ == "__main__":
    main()
