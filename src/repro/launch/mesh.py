"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state. TPU v5e numbers assumed throughout:
256 chips/pod on a 16×16 ICI torus; multi-pod runs span pods over DCN.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh

try:  # jax ≥ 0.5; older releases default every axis to Auto anyway
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _axis_type_kwargs(n_axes: int) -> dict:
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "the dry-run entry point must set "
            "xla_force_host_platform_device_count before any jax import")
    return jax.make_mesh(shape, axes, devices=devices,
                         **_axis_type_kwargs(len(axes)))


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              devices: Optional[Sequence] = None) -> Mesh:
    """Arbitrary mesh for tests/examples (e.g. (1,1) on one CPU device)."""
    n = math.prod(shape)
    devices = list(jax.devices() if devices is None else devices)[:n]
    return jax.make_mesh(tuple(shape), tuple(axes), devices=devices,
                         **_axis_type_kwargs(len(axes)))


# v5e hardware constants (roofline denominators).
PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (~per-chip effective)
VMEM_BYTES = 128 * 2 ** 20    # ~128 MiB VMEM per chip
HBM_BYTES = 16 * 2 ** 30      # 16 GiB HBM per chip
