import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax ---------------------------------------
import argparse        # noqa: E402
import json            # noqa: E402
import re              # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ALL_SHAPES, ARCH_IDS, get_config, get_shape, skip_reason  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model as MODEL  # noqa: E402
from repro.models.inputs import input_axes, input_specs  # noqa: E402
from repro.parallel import sharding as SH  # noqa: E402
from repro.train.loop import (  # noqa: E402
    TrainConfig,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    train_state_axes,
    train_state_shapes,
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(tok_dtype: str, dims: str) -> int:
    size = 1
    if dims:
        for d in dims.split(","):
            size *= int(d)
    return size * _DTYPE_BYTES.get(tok_dtype, 4)


def collect_collectives(hlo_text: str) -> Dict[str, Any]:
    """Sum the output bytes of every collective in the partitioned HLO.

    Accounting: all-reduce counted 2× (ring = reduce-scatter + all-gather);
    others 1× their output. These are per-device module bytes (the HLO is
    the post-SPMD per-device program).
    """
    per_op: Dict[str, int] = {}
    counts: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith("ROOT "):
            s = s[5:]
        if not s.startswith("%") and not s[:1].isalpha():
            continue
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+)$", s)
        if not m:
            continue
        rhs = m.group(1)
        opm = re.match(r"(?:\([^)]*\)|[\w\[\],{}: ]+?)\s*([a-z\-]+)\(", rhs)
        if not opm:
            continue
        op = opm.group(1)
        if op not in _COLLECTIVES:
            continue
        shapes = _SHAPE_RE.findall(rhs.split("(")[0])
        nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        factor = 2 if op == "all-reduce" else 1
        per_op[op] = per_op.get(op, 0) + nbytes * factor
        counts[op] = counts.get(op, 0) + 1
    return {"bytes_by_op": per_op, "counts": counts,
            "total_bytes": sum(per_op.values())}


def _named(tree_axes, tree_shapes, mesh, rules):
    def one(axes, leaf):
        return NamedSharding(
            mesh, SH.physical_spec(leaf.shape, axes, rules, mesh))
    return jax.tree.map(
        one, tree_axes, tree_shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))


def build_cell(cfg, shape, mesh, tc: TrainConfig):
    """Returns (fn, arg_shapes tuple, in_shardings tuple). Sharding rules
    come from the ACTIVE context (run_cell's use_mesh may override them —
    the perf harness drives exactly that)."""
    param_rules_ctx, act_rules_ctx = SH._current_rules()
    # Donation: production semantics — the train state and the decode KV
    # cache are updated in place (XLA buffer aliasing); without it the
    # compiled module carries a full copy of the largest live buffer.
    if shape.kind == "train":
        fn = make_train_step(cfg, tc)
        state = train_state_shapes(cfg, tc)
        state_ax = train_state_axes(cfg, tc)
        batch = input_specs(cfg, shape)
        batch_ax = input_axes(cfg, shape)
        args = (state, batch)
        shardings = (_named(state_ax, state, mesh, param_rules_ctx),
                     _named(batch_ax, batch, mesh, act_rules_ctx))
        return fn, args, shardings, (0,)
    params = MODEL.param_shapes(cfg)
    params_ax = MODEL.param_axes(cfg)
    if param_rules_ctx is SH.PARAM_RULES:
        # serving default: no FSDP re-gathers per token (SERVE_PARAM_RULES)
        param_rules_ctx = SH.SERVE_PARAM_RULES
    p_shard = _named(params_ax, params, mesh, param_rules_ctx)
    if shape.kind == "prefill":
        fn = make_prefill_step(cfg, tc)
        batch = input_specs(cfg, shape)
        batch_ax = input_axes(cfg, shape)
        args = (params, batch)
        shardings = (p_shard, _named(batch_ax, batch, mesh, act_rules_ctx))
        return fn, args, shardings, ()
    # decode
    fn = make_serve_step(cfg, tc)
    specs = input_specs(cfg, shape)
    axes = input_axes(cfg, shape)
    args = (params, specs["cache"], specs["tokens"], specs["pos"])
    shardings = (p_shard,
                 _named(axes["cache"], specs["cache"], mesh, act_rules_ctx),
                 _named(axes["tokens"], specs["tokens"], mesh, act_rules_ctx),
                 _named(axes["pos"], specs["pos"], mesh, act_rules_ctx))
    return fn, args, shardings, (1,)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             tc: Optional[TrainConfig] = None,
             out_dir: str = "results/dryrun",
             save: bool = True,
             act_rules=None, param_rules=None,
             tag: str = "") -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    reason = skip_reason(cfg, shape)
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "tag": tag,
    }
    if reason is not None:
        record["skipped"] = reason
        _maybe_save(record, cell_id, out_dir, save)
        return record

    if tc is None:
        # production defaults: full remat for big models' train steps
        tc = TrainConfig(remat="full" if shape.kind == "train" else "none")

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with SH.use_mesh(mesh, param_rules=param_rules, act_rules=act_rules):
        fn, args, shardings, donate = build_cell(cfg, shape, mesh, tc)
        lowered = jax.jit(fn, in_shardings=shardings,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collect_collectives(hlo)

    n_total, n_active = cfg.param_counts()
    record.update({
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": cost.get("flops", -1.0),
        "bytes_accessed_per_device": cost.get("bytes accessed", -1.0),
        "collectives": coll,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        "params_total": n_total,
        "params_active": n_active,
        "n_devices": mesh.size,
    })
    _maybe_save(record, cell_id, out_dir, save)
    return record


def _maybe_save(record, cell_id, out_dir, save):
    if not save:
        return
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, cell_id + ".json"), "w") as f:
        json.dump(record, f, indent=1)


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None, help="architecture id (or all)")
    ap.add_argument("--shape", default=None, help="shape name (or all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer loops: exact cost probes (XLA cost "
                         "analysis counts a scan body ONCE, so scanned "
                         "records undercount FLOPs/collectives ~n_layers×; "
                         "unrolled records carry tag='unroll')")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else [s.name for s in ALL_SHAPES]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                label = f"{arch} × {shape_name} × {'2x16x16' if multi else '16x16'}"
                tc = None
                tag = ""
                if args.remat or args.unroll:
                    shape = get_shape(shape_name)
                    remat = args.remat or (
                        "full" if shape.kind == "train" else "none")
                    tc = TrainConfig(remat=remat, unroll=args.unroll)
                    tag = "unroll" if args.unroll else ""
                try:
                    rec = run_cell(arch, shape_name, multi, tc=tc,
                                   out_dir=args.out, tag=tag)
                except Exception as e:  # a failure here is a bug in the system
                    failures.append((label, e))
                    print(f"[FAIL] {label}: {type(e).__name__}: {e}")
                    if args.verbose:
                        traceback.print_exc()
                    continue
                if "skipped" in rec:
                    print(f"[SKIP] {label}: {rec['skipped']}")
                else:
                    gb = rec["memory"]["argument_bytes"] / 2 ** 30
                    print(f"[ OK ] {label}: flops/dev={rec['flops_per_device']:.3e} "
                          f"args={gb:.2f}GiB coll={rec['collectives']['total_bytes']/2**20:.1f}MiB "
                          f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)")
    if failures:
        print(f"\n{len(failures)} FAILURES")
        raise SystemExit(1)
    print("\nall requested dry-run cells passed")


if __name__ == "__main__":
    main()
