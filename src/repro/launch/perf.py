"""Perf-iteration harness (§Perf of EXPERIMENTS.md).

Each named VARIANT re-lowers one (arch × shape) cell with a config/sharding
change, records the exact (unrolled) roofline terms, and prints the
before/after delta against the baseline — one hypothesis→change→measure
cycle per invocation.

    PYTHONPATH=src python -m repro.launch.perf --arch granite-20b \
        --shape decode_32k --variant kv_seq_unsharded
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
from typing import Callable, Dict, Optional, Tuple  # noqa: E402

from repro.launch.dryrun import run_cell             # noqa: E402
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16  # noqa: E402
from repro.launch.roofline import model_flops        # noqa: E402
from repro.parallel import sharding as SH            # noqa: E402
from repro.train.loop import TrainConfig             # noqa: E402


def _rules(base: Dict, **overrides) -> Dict:
    out = dict(base)
    out.update(overrides)
    return out


# Each variant: name -> (tc_overrides, act_rules, param_rules), built lazily
# so new ideas are one line. ``kind`` filters applicability.
def variants(kind: str) -> Dict[str, Tuple[TrainConfig, Optional[Dict], Optional[Dict]]]:
    train = kind == "train"
    base_tc = TrainConfig(remat="full" if train else "none", unroll=True)
    v: Dict[str, Tuple[TrainConfig, Optional[Dict], Optional[Dict]]] = {
        "baseline": (base_tc, None, None),
    }
    if train:
        v["remat_dots"] = (dataclasses.replace(base_tc, remat="dots"),
                           None, None)
        v["remat_dots_no_batch"] = (
            dataclasses.replace(base_tc, remat="dots_no_batch"), None, None)
        v["remat_none"] = (dataclasses.replace(base_tc, remat="none"),
                           None, None)
        v["ef_int8_grads"] = (
            dataclasses.replace(base_tc, grad_compression=True), None, None)
        v["microbatch4"] = (
            dataclasses.replace(base_tc, microbatches=4), None, None)
        # FSDP off: keep params replicated over data (pure TP)
        v["no_fsdp"] = (base_tc, None, _rules(SH.PARAM_RULES, embed=None))
        # TP off: pure DP+FSDP. For sub-1B models TP=16 is over-sharding —
        # the per-layer activation all-reduces (95% of collective bytes on
        # qwen0.5b train) vanish; only the grad reduction remains.
        no_tp_act = _rules(SH.ACT_RULES, heads=None, kv_heads=None,
                           mlp=None, vocab=None, expert=None,
                           batch=("pod", "data", "model"))
        no_tp_param = _rules(SH.PARAM_RULES, heads=None, kv_heads=None,
                             mlp=None, vocab=None, expert=None,
                             mamba_inner=None, mamba_heads=None)
        v["no_tp"] = (base_tc, no_tp_act, no_tp_param)
        # stack the winners: DP-only + grad accumulation shrinks live
        # activation temps; dots-remat trades a little recompute for the
        # rest (no TP ⇒ no activation all-reduces to duplicate)
        v["no_tp_mb4_dots"] = (
            dataclasses.replace(base_tc, remat="dots", microbatches=4),
            no_tp_act, no_tp_param)
        v["no_tp_mb8_full"] = (
            dataclasses.replace(base_tc, microbatches=8),
            no_tp_act, no_tp_param)
        # shard the sequence dim of activations over model (context para.)
        v["seq_shard"] = (base_tc,
                          _rules(SH.ACT_RULES, seq="model", heads=None,
                                 mlp=None, vocab=None),
                          None)
    else:
        v["kv_seq_unsharded"] = (
            base_tc, _rules(SH.ACT_RULES, kv_seq=None), None)
        v["kv_batch_model"] = (
            base_tc, _rules(SH.ACT_RULES, kv_seq=None,
                            batch=("pod", "data", "model")), None)
        # sequence-parallel decode: shard_map partial softmax over the
        # seq-sharded cache (kernels/decode_attention/distributed.py)
        v["dist_decode"] = (
            dataclasses.replace(base_tc, impl="dist"), None, None)
    # vocab over data instead of model (affects lm-head collective shape)
    v["vocab_over_data"] = (
        base_tc,
        _rules(SH.ACT_RULES, vocab="data"),
        _rules(SH.PARAM_RULES, vocab="data", embed="model"))
    return v


def terms(rec: Dict) -> Dict[str, float]:
    mf = model_flops(rec["arch"], rec["shape"])
    compute = rec["flops_per_device"] / PEAK_FLOPS_BF16
    mem = rec["memory"]
    memory = (mem["argument_bytes"] + mem["output_bytes"]
              + 2 * mem["temp_bytes"]) / HBM_BW  # buffer-traffic LB
    coll = rec["collectives"]["total_bytes"] / ICI_BW
    step = max(compute, memory, coll)
    return {
        "compute_s": compute, "memory_s": memory, "collective_s": coll,
        "bound": max((("compute", compute), ("memory", memory),
                      ("collective", coll)), key=lambda kv: kv[1])[0],
        "step_s": step,
        "roofline_fraction": (mf / rec["n_devices"] / PEAK_FLOPS_BF16) / step,
        "model_over_hlo": mf / (rec["flops_per_device"] * rec["n_devices"]),
    }


def run_variant(arch: str, shape: str, variant: str,
                out_dir: str = "results/perf") -> Dict:
    from repro.configs import get_shape
    kind = get_shape(shape).kind
    vs = variants("train" if kind == "train" else "serve")
    if variant not in vs:
        raise SystemExit(f"unknown variant {variant!r}; "
                         f"have: {', '.join(vs)}")
    tc, act_rules, param_rules = vs[variant]
    rec = run_cell(arch, shape, False, tc=tc, out_dir=out_dir,
                   act_rules=act_rules, param_rules=param_rules,
                   tag=f"perf-{variant}")
    rec["terms"] = terms(rec)
    with open(os.path.join(
            out_dir, f"{arch}__{shape}__{variant}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True)
    args = ap.parse_args()
    rec = run_variant(args.arch, args.shape, args.variant)
    t = rec["terms"]
    print(f"{args.arch} × {args.shape} × {args.variant}: "
          f"compute {t['compute_s']*1e3:.2f}ms "
          f"memory {t['memory_s']*1e3:.2f}ms "
          f"collective {t['collective_s']*1e3:.2f}ms "
          f"bound={t['bound']} "
          f"roofline={t['roofline_fraction']:.2%} "
          f"useful/hlo={t['model_over_hlo']:.2f}")


if __name__ == "__main__":
    main()
