"""Train/serve step builders.

``make_train_step`` produces the jit-able ``train_step(state, batch)``
covering: microbatched gradient accumulation (``lax.scan``), activation
remat policies, optional error-feedback int8 gradient compression, AdamW,
and MoE auxiliary losses. ``make_serve_step`` produces the decode step.
These are exactly what the dry-run lowers for every (arch × shape × mesh).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as MODEL
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.compress import ef_state_init, error_feedback_step
from repro.parallel.sharding import constrain


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    microbatches: int = 1
    remat: str = "none"            # none | full | dots | dots_no_batch
    impl: str = "ref"              # attention/ssd kernel impl
    grad_compression: bool = False  # error-feedback int8
    unroll: bool = False           # unroll layer loops (dry-run cost probes)
    lr_schedule: Optional[Callable[[jax.Array], jax.Array]] = None

    def lr(self):
        return self.lr_schedule if self.lr_schedule is not None \
            else self.learning_rate


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------
def cross_entropy_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Next-token xent; logits (b, s, v) any float dtype, labels (b, s)."""
    lf = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def make_loss_fn(cfg: ModelConfig, tc: TrainConfig):
    def loss_fn(params, batch):
        logits, aux, _ = MODEL.forward(
            cfg, params, batch, impl=tc.impl, remat=tc.remat,
            unroll=tc.unroll)
        loss = cross_entropy_loss(logits, batch["labels"])
        return loss + aux, {"loss": loss, "moe_aux": aux}
    return loss_fn


# ---------------------------------------------------------------------------
# State
# ---------------------------------------------------------------------------
def train_state_init(cfg: ModelConfig, key: jax.Array,
                     tc: TrainConfig) -> Dict[str, Any]:
    params = MODEL.init_params(cfg, key)
    state = {"params": params, "opt": adamw_init(params),
             "step": jnp.zeros((), jnp.int32)}
    if tc.grad_compression:
        state["ef"] = ef_state_init(params)
    return state


def train_state_shapes(cfg: ModelConfig, tc: TrainConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct tree of the full train state — no allocation."""
    params = MODEL.param_shapes(cfg)
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    state = {
        "params": params,
        "opt": {
            "m": jax.tree.map(f32, params),
            "v": jax.tree.map(f32, params),
            "count": jax.ShapeDtypeStruct((), jnp.int32),
        },
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if tc.grad_compression:
        state["ef"] = jax.tree.map(f32, params)
    return state


def train_state_axes(cfg: ModelConfig, tc: TrainConfig) -> Dict[str, Any]:
    """Logical-axis tree matching ``train_state_shapes``."""
    axes = MODEL.param_axes(cfg)
    state = {
        "params": axes,
        "opt": {"m": axes, "v": axes, "count": ()},
        "step": (),
    }
    if tc.grad_compression:
        state["ef"] = axes
    return state


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------
def _split_microbatches(batch: Dict[str, jax.Array], n: int):
    def split(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(split, batch)


def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    loss_fn = make_loss_fn(cfg, tc)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if tc.microbatches > 1:
            micro = _split_microbatches(batch, tc.microbatches)

            def acc_body(carry, mb):
                g_acc, metric_acc = carry
                (_, metrics), grads = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
                metric_acc = jax.tree.map(lambda a, m: a + m, metric_acc,
                                          metrics)
                return (g_acc, metric_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            m0 = {"loss": jnp.zeros((), jnp.float32),
                  "moe_aux": jnp.zeros((), jnp.float32)}
            (grads, metrics), _ = jax.lax.scan(acc_body, (g0, m0), micro)
            grads = jax.tree.map(lambda g: g / tc.microbatches, grads)
            metrics = jax.tree.map(lambda m: m / tc.microbatches, metrics)
        else:
            (_, metrics), grads = grad_fn(params, batch)

        new_state = dict(state)
        if tc.grad_compression:
            grads, new_ef = error_feedback_step(grads, state["ef"])
            new_state["ef"] = new_ef

        new_params, new_opt, opt_metrics = adamw_update(
            grads, state["opt"], params,
            lr=tc.lr(), b1=tc.b1, b2=tc.b2,
            weight_decay=tc.weight_decay,
            grad_clip_norm=tc.grad_clip_norm)
        new_state["params"] = new_params
        new_state["opt"] = new_opt
        new_state["step"] = state["step"] + 1
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return new_state, metrics

    return train_step


def make_serve_step(cfg: ModelConfig, tc: TrainConfig):
    def serve_step(params, cache, tokens, pos):
        return MODEL.decode_step(cfg, params, cache, tokens, pos,
                                 impl=tc.impl, unroll=tc.unroll)
    return serve_step


def make_prefill_step(cfg: ModelConfig, tc: TrainConfig,
                      max_len: Optional[int] = None):
    def prefill_step(params, batch):
        return MODEL.prefill(cfg, params, batch, max_len=max_len,
                             impl=tc.impl, unroll=tc.unroll)
    return prefill_step
