from repro.train.loop import (
    TrainConfig,
    cross_entropy_loss,
    make_loss_fn,
    make_serve_step,
    make_train_step,
    train_state_init,
    train_state_shapes,
)

__all__ = [
    "TrainConfig",
    "cross_entropy_loss",
    "make_loss_fn",
    "make_serve_step",
    "make_train_step",
    "train_state_init",
    "train_state_shapes",
]
