"""Straggler-prediction MLP: jax init/training path, numpy inference path
(DESIGN.md §20).

The parameter tree is one flat dict so the two worlds stay trivially
interchangeable:

- ``w0/b0/w1/b1`` — the trained net (features → hidden → 1 logit);
- ``mu/sd`` — corpus normalization statistics, computed on the *train*
  split and carried as frozen leaves (never touched by the optimizer —
  weight decay on ``sd`` would drive the normalizer to zero).

``forward_np`` is the default inference path so ``PredictorPolicy``
works in the bare tier-1 lane with no jax import; ``forward_jax`` is
the same arithmetic for the training loop. Checkpoints go through
``repro.checkpoint.manager`` (jax side); :func:`load_params_np` reads
the same ``manifest.json`` + ``leaf_*.npy`` layout back with numpy
alone, so a trained model loads in the bare lane too.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

import numpy as np

from repro.predict.features import N_FEATURES

N_HIDDEN = 16

Params = Dict[str, np.ndarray]

# Optimizer-visible leaves, in the flat dict. mu/sd are normalization
# constants: restored, broadcast, never updated.
TRAINED_LEAVES = ("w0", "b0", "w1", "b1")
FROZEN_LEAVES = ("mu", "sd")


def default_params(n_features: int = N_FEATURES,
                   hidden: int = N_HIDDEN) -> Params:
    """Checkpoint-less fallback: a zero net with a negative output bias.
    Every score is sigmoid(-2) ≈ 0.12 — below any sane threshold — so an
    untrained predictor degenerates to "reap + failure detection, never
    speculate". Deterministic, and safe for smoke lanes without jax."""
    return {
        "w0": np.zeros((n_features, hidden)),
        "b0": np.zeros(hidden),
        "w1": np.zeros((hidden, 1)),
        "b1": np.full(1, -2.0),
        "mu": np.zeros(n_features),
        "sd": np.ones(n_features),
    }


def init_params(seed: int, n_features: int = N_FEATURES,
                hidden: int = N_HIDDEN) -> Params:
    """Seeded jax init through the shared ParamFactory (fan-in normals),
    mirroring repro.models.layers idiom. Requires jax."""
    import jax
    import jax.numpy as jnp

    from repro.models.layers import ParamFactory, split_tree
    f = ParamFactory(jax.random.PRNGKey(seed), jnp.float32)
    params, _axes = split_tree({
        "w0": f.normal((n_features, hidden), ("features", "hidden")),
        "b0": f.zeros((hidden,), (None,)),
        "w1": f.normal((hidden, 1), ("hidden", None)),
        "b1": f.zeros((1,), (None,)),
        "mu": f.zeros((n_features,), (None,)),
        "sd": f.ones((n_features,), (None,)),
    })
    return params


def forward_np(params: Params, X: np.ndarray) -> np.ndarray:
    """Logits for a feature matrix — pure numpy, float64, the live
    assessment-tick path (deterministic across platforms)."""
    z = (np.asarray(X, dtype=np.float64) - np.asarray(params["mu"],
                                                      dtype=np.float64)) \
        / np.asarray(params["sd"], dtype=np.float64)
    h = np.maximum(z @ np.asarray(params["w0"], dtype=np.float64)
                   + np.asarray(params["b0"], dtype=np.float64), 0.0)
    out = h @ np.asarray(params["w1"], dtype=np.float64) \
        + np.asarray(params["b1"], dtype=np.float64)
    return out[:, 0]


def forward_jax(params, X):
    """Same arithmetic as :func:`forward_np` on jnp arrays (training)."""
    import jax.numpy as jnp
    z = (X - params["mu"]) / params["sd"]
    h = jnp.maximum(z @ params["w0"] + params["b0"], 0.0)
    return (h @ params["w1"] + params["b1"])[:, 0]


def sigmoid_np(logits: np.ndarray) -> np.ndarray:
    out = np.empty_like(logits, dtype=np.float64)
    pos = logits >= 0
    out[pos] = 1.0 / (1.0 + np.exp(-logits[pos]))
    e = np.exp(logits[~pos])
    out[~pos] = e / (1.0 + e)
    return out


def scores_np(params: Params, X: np.ndarray) -> np.ndarray:
    return sigmoid_np(forward_np(params, X))


# ---------------------------------------------------------------------------
# Bare-lane checkpoint loading (no jax import)
# ---------------------------------------------------------------------------
def load_params_np(ckpt_dir: str, step: Optional[int] = None) -> Params:
    """Read a ``repro.checkpoint.manager`` checkpoint with numpy alone.

    ``ckpt_dir`` is either one ``step_*`` directory (contains
    ``manifest.json``) or a manager root (the newest ``step_*`` child is
    taken, or the one matching ``step``). The manifest's ``leaves`` map
    gives ``leaf_XXXXX.npy → flat key``; our param tree is one flat dict,
    so the key path is the leaf name itself.
    """
    d = ckpt_dir
    if not os.path.exists(os.path.join(d, "manifest.json")):
        steps = sorted(
            (int(name.split("_", 1)[1]), name)
            for name in os.listdir(d) if name.startswith("step_"))
        if not steps:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
        if step is not None:
            match = [name for s, name in steps if s == step]
            if not match:
                raise FileNotFoundError(
                    f"no step_{step} checkpoint under {ckpt_dir}")
            d = os.path.join(d, match[0])
        else:
            d = os.path.join(d, steps[-1][1])
    with open(os.path.join(d, "manifest.json")) as fh:
        manifest = json.load(fh)
    params: Params = {}
    for fname, key in manifest["leaves"].items():
        params[str(key)] = np.load(os.path.join(d, fname))
    missing = [k for k in TRAINED_LEAVES + FROZEN_LEAVES if k not in params]
    if missing:
        raise ValueError(f"checkpoint {d} missing leaves: {missing}")
    return params


def checkpoint_metadata(ckpt_dir: str, step: Optional[int] = None) -> Dict:
    """The training-time metadata blob (threshold, metrics, split)."""
    d = ckpt_dir
    if not os.path.exists(os.path.join(d, "manifest.json")):
        steps = sorted(
            (int(name.split("_", 1)[1]), name)
            for name in os.listdir(d) if name.startswith("step_"))
        if not steps:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
        d = os.path.join(d, steps[-1][1])
    with open(os.path.join(d, "manifest.json")) as fh:
        return json.load(fh).get("metadata") or {}
