"""PredictorPolicy: learned straggler speculation beside LATE/bino
(DESIGN.md §20).

Same Speculator protocol as yarn/bino/budgeted/clone, different verdict
source: each assessment tick runs one batched numpy forward pass of the
§20 MLP over the live candidate rows and speculates the tasks whose
score clears the calibrated threshold, under the cluster-wide
``SpeculationBudget`` admission of §19.3. Backups land through the
existing collective winning/reaping path — the model only *nominates*.

Three deliberate properties:

- **Columnar-only.** Features like shuffle status counts and per-node
  flow counters exist only in the ArraySnapshot mirror; there is no
  honest object-walk fallback, so a plain snapshot is a hard error
  (and the runtime's reference-speculator shadow is skipped for
  learned policies rather than diverged — ``learned = True`` below).
- **Bare-lane inference.** The forward pass is numpy float64
  (``model.forward_np``); jax is never imported here. An untrained
  policy (``model.default_params``) degenerates to reap + failure
  detection with zero speculations.
- **Obs contract (§18.2).** Every emit site is ``if self.obs is not
  None``-guarded, records draw the recorder's own seq, and inference
  schedules no engine events — obs-on ≡ obs-off byte-identity holds
  under ``policy="predictor"`` (tests/test_predict.py pins it).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.accel.base import AssessmentBackend, get_backend
from repro.core.speculator import SpeculationBudget, Speculator
from repro.core.types import (
    Action,
    ClusterSnapshot,
    KillAttempt,
    MarkNodeFailed,
    SpeculateTask,
)
from repro.obs.trace import K_BUDGET, K_PREDICT
from repro.predict.features import candidate_rows, extract_features
from repro.predict.model import Params, default_params, scores_np


@dataclasses.dataclass(frozen=True)
class PredictorConfig:
    # Score cut for nominating a backup; overridden by the calibrated
    # value from the checkpoint metadata when a trained model is loaded.
    threshold: float = 0.7
    # Silent-heartbeat failure declaration (the Eq. 4 role, one fixed
    # window instead of the adaptive threshold — the learned model owns
    # slowness, the detector owns silence).
    fail_silent: float = 12.0
    # YARN's young-task guard, same default as LateConfig.min_runtime.
    min_runtime: float = 10.0
    # Cluster-wide speculative-slot budget as a fraction of slots.
    budget_fraction: float = 0.05
    min_budget: int = 2


class PredictorPolicy(Speculator):
    """Score-threshold speculation from a trained (or default) MLP."""

    # Runtime coordinators must not shadow a learned policy with the
    # BinocularSpeculator reference — the decisions legitimately differ
    # (DESIGN.md §20 honesty waiver).
    learned = True

    def __init__(self, node_ids: Sequence[str],
                 params: Optional[Params] = None, *,
                 cfg: PredictorConfig = PredictorConfig(),
                 total_slots: int = 160,
                 threshold: Optional[float] = None,
                 assess_backend: "Optional[str | AssessmentBackend]" = None):
        self.node_ids = list(node_ids)
        self.params = params if params is not None else default_params()
        self.cfg = cfg if threshold is None \
            else dataclasses.replace(cfg, threshold=float(threshold))
        self.backend = get_backend(assess_backend)
        self.budget = SpeculationBudget(
            max(cfg.min_budget,
                int(cfg.budget_fraction * total_slots)))
        self._declared = np.zeros(len(self.node_ids), dtype=bool)
        # Once-per-task nomination: a reaped backup must not be re-
        # launched next tick on the same model verdict — without this,
        # a post-crash congestion window churns backups (launch, lose
        # race, relaunch) and the wasted-work gate blows up.
        self._nominated: set = set()

    # Protocol compatibility: the runtime coordinator forwards progress
    # logs to its speculator; scores read the columnar mirror instead.
    def record_progress_log(self, log) -> None:
        pass

    def load_checkpoint(self, ckpt_dir: str,
                        step: Optional[int] = None) -> None:
        """Adopt a trained model and its calibrated threshold (numpy-only
        manifest read — works in the bare lane)."""
        from repro.predict.model import checkpoint_metadata, load_params_np
        self.params = load_params_np(ckpt_dir, step=step)
        meta = checkpoint_metadata(ckpt_dir)
        thr = (meta or {}).get("threshold")
        if thr is not None:
            self.cfg = dataclasses.replace(self.cfg, threshold=float(thr))

    def assess(self, snap: ClusterSnapshot) -> List[Action]:
        arr = getattr(snap, "arrays", None)
        if arr is None:
            raise ValueError(
                "PredictorPolicy requires columnar snapshots "
                "(shuffle/flow features exist only in the ArraySnapshot "
                "mirror); run with columnar assessment enabled")
        now = snap.now
        actions: List[Action] = [
            KillAttempt(arr.attempt_ids[r], "sibling completed")
            for r in self.backend.reap_rows(arr, now)]

        # Failure detection: a fixed silent-window declaration. Reset on
        # heartbeat resume so a recovered outage can be re-declared.
        # Silence is the only input — node_alive is ground truth the
        # detector must not read (it is exactly what it estimates).
        silent = now - arr.node_hb
        self._declared &= ~(silent < self.cfg.fail_silent)
        cand = (silent > self.cfg.fail_silent) & ~arr.node_marked \
            & ~self._declared
        for i in np.flatnonzero(cand):
            self._declared[i] = True
            actions.append(MarkNodeFailed(self.node_ids[i],
                                          reason="predict:silent"))

        # Straggler nomination: batched inference over the shared
        # candidate filter (one primary per backup-less task, §20),
        # minus nodes this policy has declared and already-nominated
        # tasks.
        crows = candidate_rows(arr, now, min_runtime=self.cfg.min_runtime)
        if not len(crows):
            return actions
        fresh = ~self._declared[arr.node[crows]]
        fresh &= np.array([arr.task_ids[r] not in self._nominated
                           for r in crows], dtype=bool)
        crows = crows[fresh]
        if not len(crows):
            return actions
        scores = scores_np(self.params,
                           extract_features(arr, now, crows))
        hits = scores > self.cfg.threshold
        # highest score first; stable sort keeps canonical order on ties
        rank = np.argsort(-scores[hits], kind="stable")
        self.budget.begin_tick(arr.n_running_spec())
        admitted = np.zeros(int(hits.sum()), dtype=bool)
        for pos in rank:
            admitted[pos] = self.budget.admit()
            if admitted[pos]:
                tid = arr.task_ids[crows[hits][pos]]
                self._nominated.add(tid)
                actions.append(SpeculateTask(task_id=tid,
                                             reason="predict"))
        if self.obs is not None:
            hrows = crows[hits]
            for pos in range(len(hrows)):
                self.obs.emit(
                    K_PREDICT, a=int(arr.node[hrows[pos]]),
                    b=int(admitted[pos]),
                    f0=float(scores[hits][pos]),
                    f1=self.cfg.threshold,
                    obj=arr.task_ids[hrows[pos]])
            if len(hrows):
                self.obs.emit(K_BUDGET, a=self.budget.in_use,
                              b=self.budget.capacity,
                              f0=float(len(hrows)),
                              f1=float(int(admitted.sum())),
                              f2=float(int((~admitted).sum())))
        return actions

    def job_done(self, job_id: str) -> None:
        prefix = job_id + "_"
        self._nominated = {t for t in self._nominated
                           if not t.startswith(prefix)}
