"""Tick-time feature extraction over ArraySnapshot columns (DESIGN.md §20).

One function, two call sites: dataset generation (repro.predict.dataset)
samples these rows mid-sim to build the training corpus, and the live
``PredictorPolicy`` (repro.predict.policy) extracts the *same* rows each
assessment tick for inference. Sharing the code path is the leakage
guarantee — a feature that is not computable from the columns visible at
tick time cannot exist here, so it cannot leak into training either.

Deliberately excluded (§20 leakage rules): ``node_speed`` and
``rack_factor`` are injected oracle values — the fault scripts *set*
them, so a model reading them would be reading the ground-truth label.
The observable shadows (per-node progress rate ρ, silent seconds, flow
counts) are what a real AM could measure, and are what we feed.
"""
from __future__ import annotations

import numpy as np

# Fixed feature order; the corpus, the checkpoint metadata and the live
# policy all index by position into this tuple.
FEATURE_NAMES = (
    "progress",          # ζ (shuffle/compute split for reduces)
    "progress_rate",     # ζ / elapsed
    "elapsed",           # now - start
    "is_reduce",         # task kind
    "is_speculative",    # backup attempt flag
    "node_silent",       # now - last heartbeat of the hosting node
    "node_alive",
    "node_marked",       # already declared failed by a policy
    "node_supp_active",  # heartbeat-suppression window open (outage)
    "node_free_frac",    # free containers / total
    "node_rho",          # mean progress rate of running attempts on node
    "node_rho_rel",      # node_rho / cluster mean ρ (1.0 when undefined)
    "fetched_frac",      # shuffle deps fetched / deps
    "ready_frac",        # shuffle deps ready / deps
    "inflight_frac",     # shuffle deps in flight / deps
    "fail_cycles",       # fetch-failure cycles burned
    "node_flows",        # open fair-net flows touching the node
    "node_link_up",
    "rack_flows",        # open flows through the node's rack uplink
)
N_FEATURES = len(FEATURE_NAMES)


def candidate_rows(arr, now: float, *,
                   min_runtime: float = 10.0) -> np.ndarray:
    """Rows the model may score: running non-speculative attempts past
    the young-task guard, with no live backup sibling, on nodes not yet
    declared failed. The dataset probe and the live policy share this
    filter, so the training distribution IS the inference distribution
    (DESIGN.md §20)."""
    rows = arr.running_rows(now)
    if not len(rows):
        return rows
    torder = arr.skey[rows] >> 20
    starts, inv = arr.task_segments(torder)
    has_spec = np.bincount(inv, weights=arr.spec[rows],
                           minlength=len(starts)) > 0
    healthy = arr.node_alive & ~arr.node_marked
    ok = (~arr.spec[rows]) & (~has_spec[inv]) \
        & (now - arr.start[rows] >= min_runtime) \
        & healthy[arr.node[rows]]
    # one candidate per task: the first eligible row in canonical order
    # (inv is nondecreasing over canonical rows)
    ok_idx = np.flatnonzero(ok)
    seg = inv[ok_idx]
    lead = np.ones(len(seg), dtype=bool)
    lead[1:] = seg[1:] != seg[:-1]
    return rows[ok_idx[lead]]


def node_progress_rate(arr, now: float) -> np.ndarray:
    """Observable per-node ρ: mean ζ/elapsed over the *running* attempts
    each node hosts right now (0.0 for idle nodes). This is the honest
    shadow of the injected ``node_speed`` oracle — what a glance could
    measure from progress reports alone."""
    n_nodes = len(arr.node_ids)
    rows = arr.running_rows(now)
    rho = np.zeros(n_nodes)
    if not len(rows):
        return rho
    elapsed = np.maximum(now - arr.start[rows], 1e-9)
    rate = arr.progress_at(now, rows) / elapsed
    nodes = arr.node[rows]
    total = np.bincount(nodes, weights=rate, minlength=n_nodes)
    count = np.bincount(nodes, minlength=n_nodes)
    np.divide(total, count, out=rho, where=count > 0)
    return rho


def extract_features(arr, now: float, rows: np.ndarray) -> np.ndarray:
    """Feature matrix ``(len(rows), N_FEATURES)`` for attempt ``rows``
    of a live :class:`~repro.core.arrays.ArraySnapshot` at time ``now``.

    Pure reads — no column is written, no memo beyond the snapshot's own
    ``running_rows`` tick memo is touched, so calling this from a
    sampling probe or an assessment tick cannot perturb the engine
    (the obs-on ≡ obs-off gate relies on that).
    """
    rows = np.asarray(rows, dtype=np.int64)
    k = len(rows)
    X = np.zeros((k, N_FEATURES))
    if not k:
        return X
    nodes = arr.node[rows]
    elapsed = np.maximum(now - arr.start[rows], 1e-9)
    prog = arr.progress_at(now, rows)
    rho = node_progress_rate(arr, now)
    hosted = np.bincount(
        arr.node[arr.running_rows(now)], minlength=len(arr.node_ids))
    mean_rho = float(rho[hosted > 0].mean()) if (hosted > 0).any() else 0.0
    rho_rel = (rho[nodes] / mean_rho) if mean_rho > 0 \
        else np.ones(k)
    deps = np.maximum(arr.deps[rows], 1)
    X[:, 0] = prog
    X[:, 1] = prog / elapsed
    X[:, 2] = elapsed
    X[:, 3] = arr.kind[rows] != 0
    X[:, 4] = arr.spec[rows]
    X[:, 5] = now - arr.node_hb[nodes]
    X[:, 6] = arr.node_alive[nodes]
    X[:, 7] = arr.node_marked[nodes]
    X[:, 8] = arr.node_supp[nodes] > now
    X[:, 9] = arr.node_free[nodes] / np.maximum(arr.node_total[nodes], 1)
    X[:, 10] = rho[nodes]
    X[:, 11] = rho_rel
    X[:, 12] = arr.fetched[rows] / deps
    X[:, 13] = arr.sh_ready[rows] / deps
    X[:, 14] = arr.sh_inflight[rows] / deps
    X[:, 15] = arr.sh_fail[rows]
    X[:, 16] = arr.node_flows[nodes]
    X[:, 17] = arr.node_link_up[nodes]
    X[:, 18] = arr.rack_flows[arr.node_rack[nodes]]
    return X
