"""repro.predict — learned straggler prediction (DESIGN.md §20).

Dataset generation from traced sims (``repro.predict.dataset``), a
small MLP with a jax training path and a numpy inference path
(``model``/``train``), and a ``PredictorPolicy`` speculator that runs
batched inference over the live ArraySnapshot columns each assessment
tick, beside the fixed-threshold LATE/bino/budgeted/clone policies.

Only the numpy-side surface is imported here; dataset/train are
accessed as modules so the bare tier-1 lane never touches jax or the
simulator transitively.
"""
from repro.predict.features import (
    FEATURE_NAMES,
    N_FEATURES,
    extract_features,
    node_progress_rate,
)
from repro.predict.model import (
    checkpoint_metadata,
    default_params,
    forward_np,
    load_params_np,
    scores_np,
)
from repro.predict.policy import PredictorConfig, PredictorPolicy

__all__ = [
    "FEATURE_NAMES", "N_FEATURES", "extract_features",
    "node_progress_rate",
    "default_params", "forward_np", "scores_np", "load_params_np",
    "checkpoint_metadata",
    "PredictorConfig", "PredictorPolicy",
]
