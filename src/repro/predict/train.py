"""Predictor training CLI (DESIGN.md §20): corpus → MLP checkpoint.

Reuses the in-tree stack end to end — ``repro.models.layers``
ParamFactory init, ``repro.optim.adamw`` updates under a
``repro.train.loop.TrainConfig``, ``repro.checkpoint.manager`` for the
committed checkpoint — on a full-batch sigmoid-BCE objective (the
corpus is thousands of rows, not billions; minibatching would only add
an rng axis to the determinism contract).

Deterministic from ``seed``: corpus replay, train/eval split,
ParamFactory init and the update loop all derive from it, so two runs
produce identical final eval metrics (pinned by tests/test_predict.py).

Threshold calibration: the decision threshold the live policy uses is
chosen *on the train split* as the lowest score cut achieving
``target_precision`` (fallback: the max-precision cut). High precision
is what the fig_predictor false-positive gate needs — a backup launched
for a task that was never going to straggle is pure wasted work.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional, Sequence

import numpy as np

from repro.predict.dataset import generate_corpus, load_corpus, \
    train_eval_split
from repro.predict.features import FEATURE_NAMES
from repro.predict.model import FROZEN_LEAVES, TRAINED_LEAVES, init_params

THRESHOLD_GRID = np.round(np.arange(0.05, 0.96, 0.05), 2)


def _precision_recall(scores: np.ndarray, y: np.ndarray,
                      thr: float) -> Dict[str, float]:
    pred = scores > thr
    tp = int((pred & (y == 1)).sum())
    fp = int((pred & (y == 0)).sum())
    fn = int((~pred & (y == 1)).sum())
    return {
        "precision": tp / (tp + fp) if tp + fp else 1.0,
        "recall": tp / (tp + fn) if tp + fn else 1.0,
        "tp": tp, "fp": fp, "fn": fn,
    }


def calibrate_threshold(scores: np.ndarray, y: np.ndarray,
                        target_precision: float = 0.8) -> float:
    """Lowest grid cut whose precision meets the target (most recall at
    acceptable purity); falls back to the most precise cut."""
    best_thr, best_prec = float(THRESHOLD_GRID[-1]), -1.0
    for thr in THRESHOLD_GRID:
        pr = _precision_recall(scores, y, float(thr))
        if pr["tp"] + pr["fp"] == 0:
            continue
        if pr["precision"] >= target_precision:
            return float(thr)
        if pr["precision"] > best_prec:
            best_thr, best_prec = float(thr), pr["precision"]
    return best_thr


def train(corpus_path: str, out_dir: str, *, seed: int = 0,
          hidden: int = 16, steps: int = 400, lr: float = 0.02,
          pos_weight: Optional[float] = None,
          target_precision: float = 0.8) -> Dict:
    """Train from a corpus .npz, checkpoint into ``out_dir``; returns the
    metrics/metadata dict (also stored in the checkpoint manifest)."""
    import jax
    import jax.numpy as jnp

    from repro.checkpoint.manager import CheckpointManager
    from repro.optim.adamw import adamw_init, adamw_update
    from repro.train.loop import TrainConfig

    corpus = load_corpus(corpus_path)
    X, y = corpus["X"], corpus["y"].astype(np.float64)
    tr, ev = train_eval_split(len(y), seed=seed)
    if pos_weight is None:
        n_pos = max(float(y[tr].sum()), 1.0)
        pos_weight = float((len(tr) - n_pos) / n_pos)

    # normalization constants from the TRAIN split only (§20: the eval
    # split stands in for unseen scenarios; its moments stay unseen too)
    mu = X[tr].mean(axis=0)
    sd = np.maximum(X[tr].std(axis=0), 1e-6)

    params = init_params(seed, n_features=X.shape[1], hidden=hidden)
    params = {k: jnp.asarray(v, jnp.float32) for k, v in params.items()}
    params["mu"] = jnp.asarray(mu, jnp.float32)
    params["sd"] = jnp.asarray(sd, jnp.float32)
    frozen = {k: params[k] for k in FROZEN_LEAVES}
    net = {k: params[k] for k in TRAINED_LEAVES}

    tc = TrainConfig(learning_rate=lr, weight_decay=0.01)
    Xtr = jnp.asarray(X[tr], jnp.float32)
    ytr = jnp.asarray(y[tr], jnp.float32)
    w = jnp.where(ytr == 1.0, pos_weight, 1.0)

    def loss_fn(net_params):
        from repro.predict.model import forward_jax
        z = forward_jax({**net_params, **frozen}, Xtr)
        # weighted BCE-with-logits, the stable max/log1p form
        per = jnp.maximum(z, 0.0) - z * ytr + jnp.log1p(jnp.exp(-jnp.abs(z)))
        return jnp.mean(w * per)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    opt = adamw_init(net)
    loss = float("nan")
    for _ in range(steps):
        loss, grads = grad_fn(net)
        net, opt, _m = adamw_update(
            grads, opt, net, lr=tc.lr(), b1=tc.b1, b2=tc.b2,
            weight_decay=tc.weight_decay,
            grad_clip_norm=tc.grad_clip_norm)

    final = {k: np.asarray(v, dtype=np.float64) for k, v in net.items()}
    final["mu"] = np.asarray(mu, dtype=np.float64)
    final["sd"] = np.asarray(sd, dtype=np.float64)

    from repro.predict.model import scores_np
    thr = calibrate_threshold(scores_np(final, X[tr]), y[tr],
                              target_precision=target_precision)
    ev_pr = _precision_recall(scores_np(final, X[ev]), y[ev], thr) \
        if len(ev) else {"precision": 1.0, "recall": 1.0,
                         "tp": 0, "fp": 0, "fn": 0}
    meta = {
        "seed": seed,
        "steps": steps,
        "hidden": hidden,
        "lr": lr,
        "pos_weight": round(float(pos_weight), 6),
        "threshold": thr,
        "final_train_loss": round(float(loss), 6),
        "eval": {k: round(v, 6) if isinstance(v, float) else v
                 for k, v in ev_pr.items()},
        "split": {"seed": seed, "n_train": int(len(tr)),
                  "n_eval": int(len(ev)),
                  "n_pos_train": int(y[tr].sum()),
                  "n_pos_eval": int(y[ev].sum())},
        "feature_names": list(FEATURE_NAMES[:X.shape[1]]),
        "corpus": corpus["meta"],
    }
    mgr = CheckpointManager(out_dir, keep=2)
    mgr.save(final, steps, metadata=meta)
    return meta


def main(argv: Optional[Sequence[str]] = None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--corpus", default="predict_corpus.npz",
                    help="corpus .npz (generated here if missing)")
    ap.add_argument("--out", default="predict_ckpt")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--hidden", type=int, default=16)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--no-fleet", action="store_true",
                    help="corpus without the fleet slice (faster)")
    args = ap.parse_args(argv)
    if not os.path.exists(args.corpus):
        meta = generate_corpus(args.corpus, seed=args.seed,
                               include_fleet=not args.no_fleet)
        print(f"corpus: {meta['n_rows']} rows "
              f"({meta['n_positive']} positive) -> {args.corpus}")
    meta = train(args.corpus, args.out, seed=args.seed, hidden=args.hidden,
                 steps=args.steps, lr=args.lr)
    print(json.dumps(meta, indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
