"""Training-corpus generation for the straggler predictor (DESIGN.md §20).

Replays the pinned fuzz fault scripts and a ``fleet_workload`` slice
through traced simulations, sampling per-attempt feature rows from
inside the live assessment ticks (``repro.predict.features`` — the same
code path, snapshot and tick timing the live policy sees) and labeling
them *post hoc* from the flight-recorder join
(``repro.obs.scorecard.attempt_outcomes``).
Features see only tick-time-visible columns; labels see only the
completed trace — the §20 leakage boundary runs exactly between the two
imports.

Determinism: every run seed, sample time and rng draw derives from the
corpus ``seed``; the ``.npz`` is written through a fixed-timestamp zip
writer (``np.savez`` stamps member mtimes, so two identical corpora
would differ byte-wise). Two calls with one seed produce byte-identical
files — tests/test_predict.py pins this.
"""
from __future__ import annotations

import io
import json
import zipfile
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.scorecard import attempt_outcomes
from repro.obs.trace import TraceRecorder
from repro.predict.features import FEATURE_NAMES, candidate_rows, \
    extract_features

# (name, seed, script, net) — replayed under the bino policy, whose
# backups race the primaries: a primary reaped (END_KILLED) on a faulted
# node, or one that dies outright (END_FAILED), becomes a positive
# label. Fault victims are nodes 0-2: a single terasort packs its ~28
# attempts onto the first few of the 20 workers, so a fault injected on
# an idle node teaches nothing. Seeds are all >= 20 — the fig_predictor
# evaluation runs at seed 1, so no evaluation trajectory was trained on.
CORPUS_RUNS: Tuple = (
    ("fault_free", 27, [], None),
    ("crash_mid_map", 21, [("crash", 1, 0.08, 0.0)], None),
    ("crash_during_shuffle", 23, [("crash", 2, 0.25, 0.0)], None),
    ("slow_straggler", 21, [("slow", 1, 0.1, 0.3)], None),
    ("hang_liar", 22, [("hang", 2, 0.2, 0.4)], None),
    ("hb_outage", 24, [("hb", 2, 0.25, 0.8)], None),
    ("double_fault", 25, [("crash", 2, 0.2, 0.0), ("slow", 1, 0.3, 0.4)],
     None),
    ("rack_degrade", 23, [("degrade", 0, 0.25, 0.1), ("slow", 2, 0.3, 0.4)],
     ("topo", 4)),
)
# Appended in full corpora: a bursty multi-job fleet slice (several jobs
# → more nodes loaded, so mid-cluster victims are informative here).
FLEET_RUN = ("fleet_mix", 26,
             [("crash", 2, 0.25, 0.0), ("slow", 0, 0.3, 0.5)], "fleet")

# Rows are sampled *inside* the speculator's own assessment ticks (every
# SAMPLE_EVERY-th tick), not at synthetic probe times. Assessment and
# heartbeats share the 1 s event grid, so tick-time ``node_silent`` sits
# near a full heartbeat period for healthy nodes — a probe scheduled
# off-grid just after a heartbeat sees ~0 instead, and a model trained
# on such probes saturates on every live candidate (train/serve skew;
# DESIGN.md §20). Piggybacking on the real tick kills the skew exactly.
SAMPLE_EVERY = 3


def _write_npz(path: str, arrays: Dict[str, np.ndarray]) -> None:
    """np.load-compatible .npz with pinned member timestamps (byte-
    deterministic, unlike np.savez which stamps wall-clock mtimes)."""
    with zipfile.ZipFile(path, "w", zipfile.ZIP_STORED) as zf:
        for name in sorted(arrays):
            buf = io.BytesIO()
            np.lib.format.write_array(
                buf, np.ascontiguousarray(arrays[name]), version=(1, 0))
            info = zipfile.ZipInfo(name + ".npy",
                                   date_time=(1980, 1, 1, 0, 0, 0))
            zf.writestr(info, buf.getvalue())


def _run_one(name: str, run_seed: int, script, net, *,
             sample_every: int) -> Tuple[np.ndarray, np.ndarray, int]:
    """One traced sim → (features, labels, n_dropped)."""
    from repro.sim import JobSpec, Simulation
    from repro.sim.faults import apply_script

    rec = TraceRecorder()
    kw: Dict = {}
    if isinstance(net, tuple):
        kw.update(net=net[0], racks=net[1])
    sim = Simulation(policy="bino", seed=run_seed, obs=rec, **kw)
    if net == "fleet":
        from repro.sim.workload import fleet_workload
        jobs = [sim.submit(s) for s in fleet_workload(
            6, mean_interarrival=5.0, seed=run_seed)]
        first = jobs[0]
    else:
        first = sim.submit(JobSpec("j0", "terasort", 2.0))
    if script:
        apply_script(sim, first, script)

    feats: List[np.ndarray] = []
    aids: List[str] = []
    times: List[float] = []
    ticks = [0]

    # Piggyback on the live assessment tick: sample candidates from the
    # exact snapshot the policy assesses, at the exact moment it does.
    # Pure reads inside an existing event — no new engine events, no
    # perturbation of the bino run being traced.
    speculator = sim.speculator
    inner_assess = speculator.assess

    def sampling_assess(snap):
        ticks[0] += 1
        if (ticks[0] - 1) % sample_every == 0:
            arr, now = snap.arrays, snap.now
            rows = candidate_rows(arr, now)
            if len(rows):
                feats.append(extract_features(arr, now, rows))
                aids.extend(arr.attempt_ids[int(r)] for r in rows)
                times.extend([now] * len(rows))
        return inner_assess(snap)

    speculator.assess = sampling_assess
    sim.run()

    X = np.concatenate(feats) if feats else np.zeros((0, len(FEATURE_NAMES)))
    # Post-hoc, time-aware label join: a sampled row is positive iff its
    # attempt went bad (failed or straggled per attempt_outcomes) AND
    # the node fault had already fired at sample time. Samples of a
    # doomed attempt taken *before* its fault are negatives — at that
    # instant nothing was observably wrong, and a backup launched then
    # would have been wasted. Labeling them positive teaches the model
    # to fire on healthy-looking rows (every young reduce mid-shuffle).
    bad: Dict[str, float] = {
        o["attempt_id"]: (o["fault_time"]
                          if o["fault_time"] is not None else -1.0)
        for o in attempt_outcomes(rec)
        if o["attempt_id"] is not None and (o["failed"] or o["straggled"])}
    seen = {o["attempt_id"] for o in attempt_outcomes(rec)
            if o["attempt_id"] is not None}
    keep = np.array([a in seen for a in aids], dtype=bool)
    y = np.array([a in bad and t >= bad[a]
                  for a, t, k in zip(aids, times, keep) if k],
                 dtype=np.int8)
    return X[keep], y, int((~keep).sum())


def generate_corpus(path: str, *, seed: int = 0,
                    runs: Optional[Sequence] = None,
                    include_fleet: bool = True,
                    replicas: int = 3,
                    sample_every: int = SAMPLE_EVERY) -> Dict:
    """Generate the corpus at ``path`` (.npz); returns a summary dict.

    Each script replays under ``replicas`` distinct sim seeds (fault
    windows land against different placements, so the positive set isn't
    one trajectory's). ``seed`` offsets every run seed, so distinct
    corpus seeds see distinct — but individually deterministic —
    trajectories.
    """
    if runs is None:
        base = list(CORPUS_RUNS) + ([FLEET_RUN] if include_fleet else [])
        runs = [(f"{name}.r{rep}", run_seed + 101 * rep, script, net)
                for rep in range(replicas)
                for (name, run_seed, script, net) in base]
    Xs: List[np.ndarray] = []
    ys: List[np.ndarray] = []
    run_idx: List[np.ndarray] = []
    dropped = 0
    run_names = []
    for i, (name, run_seed, script, net) in enumerate(runs):
        X, y, n_drop = _run_one(name, run_seed + 1009 * seed, script, net,
                                sample_every=sample_every)
        Xs.append(X)
        ys.append(y)
        run_idx.append(np.full(len(y), i, dtype=np.int32))
        dropped += n_drop
        run_names.append(name)
    X = np.concatenate(Xs)
    y = np.concatenate(ys)
    meta = {
        "seed": seed,
        "runs": run_names,
        "sample_every": sample_every,
        "n_rows": int(len(y)),
        "n_positive": int(y.sum()),
        "n_dropped": dropped,
        "feature_names": list(FEATURE_NAMES),
    }
    _write_npz(path, {
        "X": X.astype(np.float64),
        "y": y,
        "run_idx": np.concatenate(run_idx),
        "feature_names": np.array(FEATURE_NAMES),
        "meta_json": np.array([json.dumps(meta, sort_keys=True)]),
    })
    return meta


def load_corpus(path: str) -> Dict:
    with np.load(path, allow_pickle=False) as z:
        out = {k: z[k] for k in z.files}
    out["meta"] = json.loads(str(out.pop("meta_json")[0]))
    return out


def train_eval_split(n: int, *, seed: int,
                     eval_frac: float = 0.2
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Deterministic permutation split; returns (train_idx, eval_idx)."""
    perm = np.random.default_rng(seed).permutation(n)
    n_eval = max(1, int(round(n * eval_frac))) if n > 1 else 0
    return np.sort(perm[n_eval:]), np.sort(perm[:n_eval])


def main(argv: Optional[Sequence[str]] = None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="predict_corpus.npz")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-fleet", action="store_true",
                    help="skip the multi-job fleet slice (faster)")
    args = ap.parse_args(argv)
    meta = generate_corpus(args.out, seed=args.seed,
                           include_fleet=not args.no_fleet)
    print(json.dumps(meta, indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
