"""internvl2-2b — VLM: InternViT frontend (STUB) + InternLM2-1.8B backbone.

The vision tower is a STUB per the assignment: ``input_specs`` supplies
precomputed 1024-d patch embeddings (InternViT-300M output width, 256
patches after pixel-shuffle); the backbone owns the MLP projector into
d_model and prepends the patch tokens to the text sequence.
[arXiv:2404.16821; hf]
"""
from repro.configs.base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    rope_theta=1e6,
    frontend=FrontendConfig(kind="vision_patches", feature_dim=1024, n_prefix=256),
    source="arXiv:2404.16821",
)
