"""Configuration schema for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``. The schema is
deliberately a superset: dense GQA transformers, GShard-style MoE, Mamba-2
SSD stacks, Jamba-style hybrid interleaves, encoder-only stacks, and
modality-frontend (audio/VLM) stubs are all instances of the same dataclass,
so the model builder, sharding rules, dry-run, and runtime cost models can
treat them uniformly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer-block kinds (used by the hybrid interleave machinery).
# ---------------------------------------------------------------------------
ATTN = "attn"
MAMBA = "mamba"


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings (GShard/Switch-style top-k routing)."""

    n_experts: int
    top_k: int
    d_ff_expert: int
    # Apply MoE every `period` layers (1 = every layer, 2 = alternate).
    period: int = 1
    # Capacity factor for the dense-dispatch (masked einsum) formulation.
    capacity_factor: float = 1.25
    # Router jitter / aux-loss weight (load balancing, Switch-style).
    router_aux_weight: float = 0.01

    def is_moe_layer(self, layer_idx: int) -> bool:
        return layer_idx % self.period == (self.period - 1)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD settings."""

    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_kernel: int = 4
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Jamba-style interleave: within each block of ``block_len`` layers,
    layer ``attn_index`` is attention and the rest are Mamba."""

    block_len: int = 8
    attn_index: int = 4  # Jamba puts attention mid-block.

    def layer_kind(self, layer_idx: int) -> str:
        return ATTN if (layer_idx % self.block_len) == self.attn_index else MAMBA


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend STUB: ``input_specs`` supplies precomputed
    frame/patch embeddings of width ``feature_dim``; the model owns only the
    projection into ``d_model``."""

    kind: str  # "audio_frames" | "vision_patches"
    feature_dim: int
    # Number of prefix embedding positions contributed by the frontend
    # (vision). For audio the whole sequence comes from the frontend.
    n_prefix: int = 0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int          # 0 for attention-free stacks
    n_kv_heads: int       # GQA group count (== n_heads for MHA, 1 for MQA)
    d_ff: int             # dense-MLP hidden width (0 if every layer is MoE/SSM)
    vocab_size: int

    head_dim: int = 0     # 0 → d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    causal: bool = True   # False for encoder-only stacks
    tie_embeddings: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    # "swiglu" (llama lineage) or "gelu" (older encoders)
    mlp_act: str = "swiglu"
    # "rmsnorm" (llama lineage) or "layernorm" (BERT/BigCode lineage)
    norm: str = "rmsnorm"

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    frontend: Optional[FrontendConfig] = None

    # Sliding-window attention width (0 = full attention).
    window: int = 0

    # dtype policy
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"

    # citation / provenance string from the assignment table
    source: str = ""

    # ------------------------------------------------------------------
    # Derived properties
    # ------------------------------------------------------------------
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.n_heads == 0:
            return 0
        return self.d_model // self.n_heads

    def layer_kind(self, layer_idx: int) -> str:
        if self.family == "ssm":
            return MAMBA
        if self.hybrid is not None:
            return self.hybrid.layer_kind(layer_idx)
        return ATTN

    def n_attn_layers(self) -> int:
        return sum(1 for i in range(self.n_layers) if self.layer_kind(i) == ATTN)

    def n_mamba_layers(self) -> int:
        return self.n_layers - self.n_attn_layers()

    def is_encoder_only(self) -> bool:
        return not self.causal

    def is_subquadratic(self) -> bool:
        """True when long-context decode (500k) is feasible: attention-free
        or hybrid stacks (the few attention layers hold the only KV)."""
        return self.family in ("ssm", "hybrid") or self.window > 0

    def moe_layer_count(self) -> int:
        if self.moe is None:
            return 0
        return sum(
            1
            for i in range(self.n_layers)
            if self.layer_kind(i) == ATTN or True  # MoE applies to FFN slots of all layers
            if self.moe.is_moe_layer(i)
        )

    # ------------------------------------------------------------------
    # Analytic parameter counts (used by roofline's 6·N·D and by the
    # runtime's state-transfer cost model). Matches models/model.py init.
    # ------------------------------------------------------------------
    def param_counts(self) -> Tuple[int, int]:
        """Returns (n_total, n_active) parameter counts, embeddings included
        in totals but excluded from the 6·N·D "active compute" count per the
        usual convention (embedding lookup is a gather, lm_head is counted)."""
        d = self.d_model
        hd = self.resolved_head_dim()
        nq, nkv = self.n_heads, self.n_kv_heads

        def attn_params() -> int:
            p = d * (nq * hd) + d * (nkv * hd) * 2 + (nq * hd) * d
            if self.qkv_bias:
                p += (nq + 2 * nkv) * hd
            if self.qk_norm:
                p += 2 * hd
            return p

        def dense_mlp_params() -> int:
            if self.d_ff == 0:
                return 0
            mult = 3 if self.mlp_act == "swiglu" else 2
            return mult * d * self.d_ff

        def moe_mlp_params() -> Tuple[int, int]:
            assert self.moe is not None
            m = self.moe
            mult = 3 if self.mlp_act == "swiglu" else 2
            per_expert = mult * d * m.d_ff_expert
            router = d * m.n_experts
            total = m.n_experts * per_expert + router
            active = m.top_k * per_expert + router
            return total, active

        def mamba_params() -> int:
            assert self.ssm is not None
            s = self.ssm
            din = s.d_inner(d)
            nh = s.n_heads(d)
            conv_dim = din + 2 * s.n_groups * s.d_state
            p = d * (2 * din + 2 * s.n_groups * s.d_state + nh)  # in_proj
            p += conv_dim * s.conv_kernel + conv_dim  # depthwise conv + bias
            p += nh * 2  # A_log, D
            p += nh  # dt_bias
            p += din  # gated-norm weight
            p += din * d  # out_proj
            return p

        total = 0
        active = 0
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            # mixer
            if kind == ATTN:
                pm = attn_params()
            else:
                pm = mamba_params()
            total += pm
            active += pm
            # ffn slot
            if self.family == "ssm":
                pf_total, pf_active = 0, 0  # pure mamba stack has no FFN slot
            elif self.moe is not None and self.moe.is_moe_layer(i):
                pf_total, pf_active = moe_mlp_params()
            else:
                pf_total = pf_active = dense_mlp_params()
            total += pf_total
            active += pf_active
            # pre-norms: attention/hybrid layers carry (ln1, ln2); a pure
            # SSM layer has no FFN slot and only ln1. LayerNorm carries a
            # bias alongside the scale; RMSNorm is scale-only.
            n_norms = 1 if self.family == "ssm" else 2
            norm_size = 2 * d if self.norm == "layernorm" else d
            total += n_norms * norm_size
            active += n_norms * norm_size

        # final norm
        final_norm = 2 * d if self.norm == "layernorm" else d
        total += final_norm
        active += final_norm
        # lm head (counted as compute); embedding table (gather, not matmul)
        total += d * self.vocab_size  # embedding
        if not self.tie_embeddings:
            total += d * self.vocab_size
        active += d * self.vocab_size  # lm-head matmul compute
        if self.frontend is not None:
            total += self.frontend.feature_dim * d
            active += self.frontend.feature_dim * d
        return total, active


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def applicable_shapes(cfg: ModelConfig) -> Tuple[ShapeSpec, ...]:
    """Shape cells that are live for this architecture (assignment rules)."""
    shapes = []
    for s in ALL_SHAPES:
        if s.is_decode and cfg.is_encoder_only():
            continue  # encoder-only: no decode step
        if s.name == "long_500k" and not cfg.is_subquadratic():
            continue  # quadratic full attention at 524k: skipped by assignment
        shapes.append(s)
    return tuple(shapes)


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    if shape.is_decode and cfg.is_encoder_only():
        return "encoder-only arch: no autoregressive decode step"
    if shape.name == "long_500k" and not cfg.is_subquadratic():
        return "pure full-attention arch: 524k decode requires sub-quadratic attention"
    return None
