"""mamba2-2.7b — attention-free SSD (state-space duality) stack.
[arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,  # pure mamba stack: no FFN slot
    vocab_size=50280,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1),
    source="arXiv:2405.21060",
)
