"""moonshot-v1-16b-a3b — Moonlight-style fine-grained MoE, 64 experts top-6.
[hf:moonshotai/Moonlight-16B-A3B; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,  # every FFN slot is MoE (d_ff_expert=1408 fine-grained experts)
    vocab_size=163840,
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, period=1),
    source="hf:moonshotai/Moonlight-16B-A3B",
)
