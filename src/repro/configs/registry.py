"""Architecture registry: ``--arch <id>`` resolution, shape cells, and
reduced (smoke-test) config derivation."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.configs.base import (
    ALL_SHAPES,
    FrontendConfig,
    HybridConfig,
    ModelConfig,
    MoEConfig,
    ShapeSpec,
    SSMConfig,
    applicable_shapes,
    skip_reason,
)

from repro.configs.qwen1_5_0_5b import CONFIG as _QWEN15_05B
from repro.configs.codeqwen1_5_7b import CONFIG as _CODEQWEN15_7B
from repro.configs.qwen3_8b import CONFIG as _QWEN3_8B
from repro.configs.granite_20b import CONFIG as _GRANITE_20B
from repro.configs.hubert_xlarge import CONFIG as _HUBERT_XL
from repro.configs.phi3_5_moe import CONFIG as _PHI35_MOE
from repro.configs.moonshot_v1_16b import CONFIG as _MOONSHOT_16B
from repro.configs.jamba_1_5_large import CONFIG as _JAMBA_15_LARGE
from repro.configs.internvl2_2b import CONFIG as _INTERNVL2_2B
from repro.configs.mamba2_2_7b import CONFIG as _MAMBA2_27B

ARCHS: Dict[str, ModelConfig] = {
    cfg.arch_id: cfg
    for cfg in (
        _QWEN15_05B,
        _CODEQWEN15_7B,
        _QWEN3_8B,
        _GRANITE_20B,
        _HUBERT_XL,
        _PHI35_MOE,
        _MOONSHOT_16B,
        _JAMBA_15_LARGE,
        _INTERNVL2_2B,
        _MAMBA2_27B,
    )
}

ARCH_IDS: Tuple[str, ...] = tuple(ARCHS.keys())


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(
            f"unknown --arch {arch_id!r}; available: {', '.join(ARCH_IDS)}"
        )
    return ARCHS[arch_id]


def get_shape(name: str) -> ShapeSpec:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}")


def all_cells(include_skipped: bool = False) -> List[Tuple[ModelConfig, ShapeSpec, Optional[str]]]:
    """The full assignment matrix: 10 archs × 4 shapes = 40 cells.

    Returns (config, shape, skip_reason) triples; skip_reason is None for
    live cells. With include_skipped=False only live cells are returned.
    """
    cells = []
    for arch_id in ARCH_IDS:
        cfg = ARCHS[arch_id]
        for shape in ALL_SHAPES:
            reason = skip_reason(cfg, shape)
            if reason is None or include_skipped:
                cells.append((cfg, shape, reason))
    return cells


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests: same family/topology, tiny widths.
# ---------------------------------------------------------------------------
def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Shrink a production config to a CPU-runnable config of the SAME
    family: keeps GQA ratios, MoE routing, hybrid interleave pattern, qk-norm
    and bias flags; shrinks layer count, widths, expert count, vocab."""
    n_layers = 4 if cfg.hybrid is None else cfg.hybrid.block_len
    hybrid = None
    if cfg.hybrid is not None:
        hybrid = dataclasses.replace(cfg.hybrid, block_len=4, attn_index=2)
        n_layers = 8  # two hybrid blocks

    if cfg.n_heads:
        n_heads = min(cfg.n_heads, 4)
        # preserve the GQA grouping ratio where possible
        ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
        n_kv_heads = max(1, n_heads // ratio)
    else:
        n_heads = n_kv_heads = 0

    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe,
            n_experts=min(cfg.moe.n_experts, 8),
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=64,
        )
    ssm = None
    if cfg.ssm is not None:
        ssm = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=16, n_groups=min(cfg.ssm.n_groups, 2),
            chunk_size=16,
        )
    frontend = None
    if cfg.frontend is not None:
        frontend = dataclasses.replace(
            cfg.frontend,
            feature_dim=32,
            n_prefix=4 if cfg.frontend.n_prefix else 0,
        )
    return dataclasses.replace(
        cfg,
        arch_id=cfg.arch_id + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv_heads,
        head_dim=16 if cfg.head_dim else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        moe=moe,
        ssm=ssm,
        hybrid=hybrid,
        frontend=frontend,
        param_dtype="float32",
        activation_dtype="float32",
    )


REDUCED_SHAPE_TRAIN = ShapeSpec("smoke_train", seq_len=32, global_batch=2, kind="train")
REDUCED_SHAPE_PREFILL = ShapeSpec("smoke_prefill", seq_len=32, global_batch=2, kind="prefill")
REDUCED_SHAPE_DECODE = ShapeSpec("smoke_decode", seq_len=32, global_batch=2, kind="decode")
