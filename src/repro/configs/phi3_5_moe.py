"""phi3.5-moe-42b-a6.6b — 16-expert top-2 MoE, GQA kv=8.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=0,  # every FFN slot is MoE
    vocab_size=32064,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=6400, period=1),
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
