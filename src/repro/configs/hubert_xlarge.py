"""hubert-xlarge — encoder-only audio transformer (wav2vec2 arch).

The conv waveform frontend is a STUB per the assignment: ``input_specs``
supplies precomputed 512-d frame features (the conv-stem output width in
the wav2vec2/HuBERT lineage); the model owns the 512→1280 projection.
Output head predicts the 504 k-means target units. [arXiv:2106.07447]
"""
from repro.configs.base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    causal=False,  # encoder-only
    mlp_act="gelu",
    norm="layernorm",
    frontend=FrontendConfig(kind="audio_frames", feature_dim=512),
    source="arXiv:2106.07447",
)
