"""granite-20b — dense code model, MQA (kv=1), llama-style. [arXiv:2405.04324; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    # GPT-BigCode lineage: 2-matrix GELU MLP (a 3-matrix SwiGLU would put the
    # model at 28B, contradicting the 20B name; kv=1 MQA + vocab 49152 are
    # also BigCode signatures).
    mlp_act="gelu",
    norm="layernorm",
    source="arXiv:2405.04324",
)
