"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 interleave with 16-expert
top-2 MoE every other layer. [arXiv:2403.19887; hf]

Hardware-adaptation note (DESIGN.md §8): the Mamba slots use our TPU-native
chunked Mamba-2/SSD block (d_state=128) rather than the paper-exact Mamba-1
selective scan — the SSD dual form is the MXU-friendly formulation.
"""
from repro.configs.base import HybridConfig, ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576, period=2),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=8),
    hybrid=HybridConfig(block_len=8, attn_index=4),
    source="arXiv:2403.19887",
)
