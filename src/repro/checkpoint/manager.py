"""Sharded, atomic, async checkpointing.

Semantics borrowed from the paper's §III.B "keep both outputs" rule: a
speculative (shadow) writer and the primary may BOTH complete a step's
checkpoint; both directories are retained until the commit barrier picks
the first valid one — only then are losers garbage-collected. Commits are
atomic (`os.rename` of a finished tmp dir), so a writer dying mid-save can
never corrupt the latest checkpoint; restart always finds the newest
manifest-complete step.

Layout:
    <dir>/step_000042/            committed
    <dir>/step_000042.tmp-<tag>/  in-flight writer (primary or shadow)
    each dir: manifest.json + one .npy per pytree leaf
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{9})$")


def _leaf_paths(tree: Any) -> List[Tuple[str, Any]]:
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves_with_paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def _fsync_path(path: str) -> None:
    """Best-effort fsync of a file or directory (directory fsync pins the
    rename/creation in the parent's metadata — required for the commit to
    survive power loss, not just process death)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # pragma: no cover - platform without dir-open
        return
    try:
        os.fsync(fd)
    except OSError:  # pragma: no cover - fs without fsync support
        pass
    finally:
        os.close(fd)


def save_pytree(dirpath: str, tree: Any, *, step: int,
                metadata: Optional[Dict[str, Any]] = None,
                tag: str = "primary", fsync: bool = True) -> str:
    """Write one checkpoint dir atomically; returns the committed path.
    If another writer already committed this step, keeps ours as a shadow
    copy (``step_N.shadow-<tag>``) — both outputs retained (§III.B).

    Crash-safe write discipline (DESIGN.md §16.7): every leaf and the
    manifest are flushed+fsynced inside the tmp dir, the tmp dir itself is
    fsynced, THEN the atomic rename commits, then the parent dir is
    fsynced. A writer dying (or machine losing power) at any point leaves
    either the complete previous state or a ``.tmp-`` orphan that
    ``CheckpointManager`` sweeps on startup — never a torn checkpoint.
    The manifest is written last, so its presence certifies every leaf.
    """
    final = os.path.join(dirpath, f"step_{step:09d}")
    tmp = final + f".tmp-{tag}"
    os.makedirs(tmp, exist_ok=True)
    names = {}
    for i, (key, leaf) in enumerate(_leaf_paths(tree)):
        fname = f"leaf_{i:05d}.npy"
        names[fname] = key
        fpath = os.path.join(tmp, fname)
        with open(fpath, "wb") as f:
            np.save(f, np.asarray(leaf))
            if fsync:
                f.flush()
                os.fsync(f.fileno())
    manifest = {"step": step, "leaves": names, "tag": tag,
                "metadata": metadata or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        if fsync:
            f.flush()
            os.fsync(f.fileno())
    if fsync:
        _fsync_path(tmp)
    try:
        os.rename(tmp, final)
        committed = final
    except OSError:
        shadow = final + f".shadow-{tag}"
        shutil.rmtree(shadow, ignore_errors=True)
        os.rename(tmp, shadow)
        committed = shadow
    if fsync:
        _fsync_path(dirpath)
    return committed


def restore_pytree(dirpath: str, like: Any, *, step: Optional[int] = None
                   ) -> Tuple[Any, int, Dict[str, Any]]:
    """Restore the newest (or given) committed step into ``like``'s
    structure. Returns (tree, step, metadata)."""
    if step is None:
        steps = sorted(
            int(m.group(1)) for m in
            (_STEP_RE.match(d) for d in os.listdir(dirpath)) if m)
        if not steps:
            raise FileNotFoundError(f"no committed checkpoints in {dirpath}")
        step = steps[-1]
    d = os.path.join(dirpath, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree_util.tree_flatten(like)
    arrays = []
    for i, ref in enumerate(leaves):
        arr = np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
        if hasattr(ref, "dtype"):
            arr = arr.astype(ref.dtype, copy=False)
        arrays.append(arr)
    return (jax.tree_util.tree_unflatten(treedef, arrays), step,
            manifest.get("metadata", {}))


class CheckpointManager:
    """Async save + retention + commit-barrier GC of shadow copies."""

    def __init__(self, dirpath: str, *, keep: int = 3):
        self.dir = dirpath
        self.keep = keep
        os.makedirs(dirpath, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self._sweep_stale_tmps()

    def _sweep_stale_tmps(self) -> None:
        """Startup recovery: a ``.tmp-`` dir is a writer that died mid-save
        (possibly torn — no manifest, partial leaves); it can never be
        restored from, so it is removed. Shadow copies are committed
        (manifest-complete) and stay until the normal commit-barrier GC."""
        for d in os.listdir(self.dir):
            if ".tmp-" in d:
                shutil.rmtree(os.path.join(self.dir, d),
                              ignore_errors=True)

    # -- writing --------------------------------------------------------
    def save(self, tree: Any, step: int, *, tag: str = "primary",
             metadata: Optional[Dict[str, Any]] = None) -> str:
        path = save_pytree(self.dir, tree, step=step, tag=tag,
                           metadata=metadata)
        self._gc(step)
        return path

    def save_async(self, tree: Any, step: int, *, tag: str = "primary",
                   metadata: Optional[Dict[str, Any]] = None) -> None:
        """Snapshot on the caller's thread (cheap host copy), write on a
        background thread — training continues during the disk write."""
        self.wait()
        snap = jax.tree.map(lambda x: np.array(x), tree)

        def work():
            try:
                self.save(snap, step, tag=tag, metadata=metadata)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- reading --------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = [int(m.group(1)) for m in
                 (_STEP_RE.match(d) for d in os.listdir(self.dir)) if m]
        return max(steps) if steps else None

    def restore(self, like: Any, *, step: Optional[int] = None):
        self.wait()
        return restore_pytree(self.dir, like, step=step)

    # -- retention ------------------------------------------------------
    def _gc(self, newest_step: int) -> None:
        """Commit barrier: once step N is committed, shadow/tmp copies of
        steps ≤ N have lost the race and old steps beyond ``keep`` go."""
        for d in os.listdir(self.dir):
            full = os.path.join(self.dir, d)
            if ".shadow-" in d or ".tmp-" in d:
                try:
                    s = int(d.split("step_")[1].split(".")[0])
                except (IndexError, ValueError):
                    continue
                if s <= newest_step - 1:
                    shutil.rmtree(full, ignore_errors=True)
        steps = sorted(int(m.group(1)) for m in
                       (_STEP_RE.match(d) for d in os.listdir(self.dir))
                       if m)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)
