"""Deterministic, resumable data pipeline.

The training runtime's speculative rollback (§III.C analogue) depends on
one property: a task's progress log — ``(shard, offset, seed)`` — must be
sufficient to regenerate EXACTLY the batches the failed attempt would have
consumed. The pipeline is therefore stateless-functional: batch ``i`` of
shard ``s`` is a pure function of ``(seed, s, i)``; no iterator state
exists that cannot be reconstructed from the three integers.

The synthetic corpus is a Zipf-ish token stream with enough structure
(document boundaries, skewed unigram distribution) to give language-model
training a non-trivial loss curve without any external data dependency.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataState:
    """Complete pipeline state — the rollback log payload."""

    seed: int
    shard_id: int
    n_shards: int
    offset: int  # batches already consumed by this shard

    def advance(self, n: int = 1) -> "DataState":
        return dataclasses.replace(self, offset=self.offset + n)


class TokenDataset:
    """Pure-function batch source: ``batch(shard, index) -> tokens``."""

    def __init__(self, vocab_size: int, seq_len: int, seed: int = 0,
                 doc_len: int = 512):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.seed = seed
        self.doc_len = doc_len
        # Skewed unigram distribution (Zipf-ish) shared by all shards.
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        probs = 1.0 / ranks
        self._probs = probs / probs.sum()

    def batch(self, shard_id: int, index: int, batch_size: int
              ) -> np.ndarray:
        """(batch_size, seq_len+1) int32 — callers split into inputs/labels.

        Deterministic in (seed, shard_id, index); different shards and
        indices are independent streams.
        """
        ss = np.random.SeedSequence(
            entropy=self.seed, spawn_key=(shard_id, index))
        rng = np.random.default_rng(ss)
        toks = rng.choice(self.vocab_size, p=self._probs,
                          size=(batch_size, self.seq_len + 1))
        # Document boundaries: BOS token (0) every ~doc_len positions.
        pos = rng.integers(0, self.doc_len, size=(batch_size, 1))
        grid = (np.arange(self.seq_len + 1)[None, :] + pos) % self.doc_len
        toks = np.where(grid == 0, 0, toks)
        return toks.astype(np.int32)


class ShardedTokenPipeline:
    """Per-host view of the global stream; resumable via ``DataState``."""

    def __init__(self, dataset: TokenDataset, state: DataState,
                 batch_size: int):
        self.dataset = dataset
        self.state = state
        self.batch_size = batch_size

    @classmethod
    def fresh(cls, dataset: TokenDataset, shard_id: int, n_shards: int,
              batch_size: int) -> "ShardedTokenPipeline":
        return cls(dataset,
                   DataState(dataset.seed, shard_id, n_shards, 0),
                   batch_size)

    @classmethod
    def from_state(cls, dataset: TokenDataset, state: DataState,
                   batch_size: int) -> "ShardedTokenPipeline":
        return cls(dataset, state, batch_size)

    def peek(self, ahead: int = 0) -> Dict[str, np.ndarray]:
        toks = self.dataset.batch(self.state.shard_id,
                                  self.state.offset + ahead,
                                  self.batch_size)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def next(self) -> Dict[str, np.ndarray]:
        out = self.peek()
        self.state = self.state.advance()
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next()


def global_batch_specs(global_batch: int, n_hosts: int) -> Tuple[int, int]:
    """(per-host batch, n_shards); global batch must split evenly."""
    assert global_batch % n_hosts == 0, (global_batch, n_hosts)
    return global_batch // n_hosts, n_hosts
