from repro.data.pipeline import (
    DataState,
    ShardedTokenPipeline,
    TokenDataset,
    global_batch_specs,
)

__all__ = ["DataState", "ShardedTokenPipeline", "TokenDataset",
           "global_batch_specs"]
