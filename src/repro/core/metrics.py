"""Eq. 1–4 of the paper, as vectorized, substrate-agnostic math.

Two mirrored implementations are provided:

- numpy (``*_np``) — used by the coordinator / simulator hot path, where a
  single assessment tick covers every node at once and runs millions of
  times inside the discrete-event benchmarks;
- jax (``*_jax``) — jit-able versions used by the live runtime's coordinator
  (assessments over thousands of node rows batch nicely on-device) and by
  the property tests that pin the two implementations together.

Notation follows §III.A:
  ρ(t)   task progress rate  = ζ(t)/τ_t
  P(N^J) NodeProgressRate    = avg over tasks of job J on node N of ρ
  ζ(N^J) node progress score = Σ ProgressScore of *ongoing* tasks
  Δ(N^J) NodeProgressChangeRate (Eq. 2)
  Eq. 1  spatial slow-node test:   P < mean_NH(P) − σ_NH(P)
  Eq. 3  temporal slow-node test:  Δ|Ti < threshold × Δ|Ti−1
  Eq. 4  adaptive unresponsiveness estimate over the last L outages
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

__all__ = [
    "node_progress_rate_np",
    "spatial_slow_mask_np",
    "spatial_slow_mask_batch_np",
    "temporal_slow_mask_np",
    "eq4_estimate_np",
    "eq4_estimate_weights",
    "node_progress_rate_jax",
    "spatial_slow_mask_jax",
    "temporal_slow_mask_jax",
    "eq4_estimate_jax",
]


# ---------------------------------------------------------------------------
# Eq. 1 — spatial neighborhood assessment
# ---------------------------------------------------------------------------
def node_progress_rate_np(progress: np.ndarray, runtime: np.ndarray,
                          node_of_task: np.ndarray, n_nodes: int
                          ) -> np.ndarray:
    """P(N^J) per node: mean ρ(t_i) over the job-J tasks on each node.

    progress/runtime/node_of_task are parallel arrays over the job's
    *running* tasks. Nodes with no tasks get NaN (excluded from Eq. 1).
    """
    rho = progress / np.maximum(runtime, 1e-9)
    sums = np.zeros(n_nodes)
    counts = np.zeros(n_nodes)
    np.add.at(sums, node_of_task, rho)
    np.add.at(counts, node_of_task, 1.0)
    with np.errstate(invalid="ignore"):
        return np.where(counts > 0, sums / np.maximum(counts, 1.0), np.nan)


def spatial_slow_mask_np(P: np.ndarray, neighborhoods: np.ndarray
                         ) -> np.ndarray:
    """Eq. 1: mark node i slow iff
    ``P[i] < mean(P[NH{i}]) − std(P[NH{i}])`` (NaN rows never fire).

    ``neighborhoods`` is (n_nodes, SIZE_NEIGHBOR) int indices of each node's
    neighborhood (including itself, per the paper's NH{N_i} collection).
    """
    Pn = P[neighborhoods]                      # (n, k)
    valid = ~np.isnan(Pn)
    cnt = valid.sum(axis=1)
    with np.errstate(invalid="ignore"):
        mean = np.nansum(Pn, axis=1) / np.maximum(cnt, 1)
        var = np.nansum((Pn - mean[:, None]) ** 2 * valid, axis=1) \
            / np.maximum(cnt, 1)
    std = np.sqrt(var)
    # Need ≥2 live neighbors for variation to be meaningful, and a live P.
    ok = (cnt >= 2) & ~np.isnan(P)
    return ok & (P < (mean - std))


def spatial_slow_mask_batch_np(P: np.ndarray, neighborhoods: np.ndarray
                               ) -> np.ndarray:
    """Eq. 1 batched over assessment groups: ``P`` is (groups, n_nodes) —
    one row per (job, phase) — and the result is (groups, n_nodes).

    Operation-for-operation identical to :func:`spatial_slow_mask_np`
    applied per row (same nansum element order, same clip constants), so
    the vectorized glance path is bit-equivalent to the per-job reference
    loop (DESIGN.md §11.3).
    """
    Pn = P[:, neighborhoods]                   # (g, n, k)
    valid = ~np.isnan(Pn)
    cnt = valid.sum(axis=2)
    with np.errstate(invalid="ignore"):
        mean = np.nansum(Pn, axis=2) / np.maximum(cnt, 1)
        var = np.nansum((Pn - mean[:, :, None]) ** 2 * valid, axis=2) \
            / np.maximum(cnt, 1)
    std = np.sqrt(var)
    ok = (cnt >= 2) & ~np.isnan(P)
    return ok & (P < (mean - std))


# ---------------------------------------------------------------------------
# Eq. 2–3 — temporal assessment
# ---------------------------------------------------------------------------
def temporal_slow_mask_np(zeta_now: np.ndarray, zeta_prev: np.ndarray,
                          dt_now: float, delta_prev: np.ndarray,
                          threshold_slowdown: float = 0.1,
                          min_prev_delta: float = 1e-9) -> np.ndarray:
    """Eq. 2–3 over all nodes at once.

    Returns (slow_mask, delta_now). ``zeta_*`` are per-node sums of ongoing
    ProgressScores (completed tasks excluded — the paper's guard against
    end-of-job decline); ``delta_prev`` is Δ|Ti−1 (NaN before two samples).
    """
    delta_now = (zeta_now - zeta_prev) / max(dt_now, 1e-9)
    with np.errstate(invalid="ignore"):
        slow = (~np.isnan(delta_prev)) \
            & (delta_prev > min_prev_delta) \
            & (delta_now < threshold_slowdown * delta_prev)
    return slow, delta_now


# ---------------------------------------------------------------------------
# Eq. 4 — adaptive failure threshold
# ---------------------------------------------------------------------------
def eq4_estimate_weights(L: int) -> np.ndarray:
    """Weights 2^{L+1-k} for k = 1..L (most recent outage first)."""
    k = np.arange(1, L + 1)
    return 2.0 ** (L + 1 - k)


def eq4_estimate_np(history: Sequence[float], L: int) -> Optional[float]:
    """P_{n+1} = Σ_{k=1..L} 2^{L+1−k}·R_{n+1−k} / Σ_{k=1..L} 2^k.

    ``history`` lists past outage durations, most recent LAST. Uses the last
    ``L`` entries (fewer ⇒ window shrinks to what exists; none ⇒ None).

    Note the paper's denominator Σ 2^k = 2^{L+1} − 2 differs from the
    numerator weight sum (Σ 2^{L+1−k} over k=1..L = 2^{L+1} − 2 as well —
    the two sums are equal, so this *is* a proper weighted mean).
    """
    if not history:
        return None
    h = list(history)[-L:]
    Leff = len(h)
    w = eq4_estimate_weights(Leff)
    # h is oldest→newest; R_{n+1-k} pairs k=1 with the newest entry.
    r = np.asarray(h[::-1], dtype=float)
    denom = float(np.sum(2.0 ** np.arange(1, Leff + 1)))
    return float(np.dot(w, r) / denom)


# ---------------------------------------------------------------------------
# JAX mirrors (imported lazily so the simulator never pays jax startup).
# ---------------------------------------------------------------------------
def node_progress_rate_jax(progress, runtime, node_of_task, n_nodes: int):
    import jax.numpy as jnp

    rho = progress / jnp.maximum(runtime, 1e-9)
    sums = jnp.zeros(n_nodes).at[node_of_task].add(rho)
    counts = jnp.zeros(n_nodes).at[node_of_task].add(1.0)
    return jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), jnp.nan)


def spatial_slow_mask_jax(P, neighborhoods):
    import jax.numpy as jnp

    Pn = P[neighborhoods]
    valid = ~jnp.isnan(Pn)
    cnt = valid.sum(axis=1)
    mean = jnp.nansum(Pn, axis=1) / jnp.maximum(cnt, 1)
    var = jnp.nansum(jnp.where(valid, (Pn - mean[:, None]) ** 2, 0.0),
                     axis=1) / jnp.maximum(cnt, 1)
    std = jnp.sqrt(var)
    ok = (cnt >= 2) & ~jnp.isnan(P)
    return ok & (P < (mean - std))


def temporal_slow_mask_jax(zeta_now, zeta_prev, dt_now, delta_prev,
                           threshold_slowdown: float = 0.1,
                           min_prev_delta: float = 1e-9):
    import jax.numpy as jnp

    delta_now = (zeta_now - zeta_prev) / jnp.maximum(dt_now, 1e-9)
    slow = (~jnp.isnan(delta_prev)) \
        & (delta_prev > min_prev_delta) \
        & (delta_now < threshold_slowdown * delta_prev)
    return slow, delta_now


def eq4_estimate_jax(history, L: int):
    """history: (L,) most recent LAST, NaN-padded at the front."""
    import jax.numpy as jnp

    h = history[-L:]
    # Reverse so index j (0-based) is the j-th most recent sample (k = j+1).
    r = h[::-1]
    v = ~jnp.isnan(r)
    leff = v.sum()  # live window length (may be < L early on)
    j = jnp.arange(L, dtype=h.dtype)
    # weight 2^{Leff+1-k} = 2^{Leff-j}; denominator Σ_{k=1..Leff} 2^k.
    w = jnp.where(v, 2.0 ** (leff - j), 0.0)
    denom = 2.0 ** (leff + 1) - 2.0
    num = jnp.sum(w * jnp.where(v, r, 0.0))
    return jnp.where(leff > 0, num / jnp.maximum(denom, 1.0), jnp.nan)
