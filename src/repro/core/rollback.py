"""Speculative rollback (§III.C): lightweight progress logs + race recovery.

The substrate registers per-task progress logs — the analogue of the paper's
``(spill path, input-split offset)``:

- simulator: (node, spills_completed, split_offset);
- training runtime: (host, step, microbatch index, data-shard offset, RNG).

When a task is reported slow/failed and its *original node is still healthy*,
the policy launches TWO racing attempts (§III.C): a rollback attempt on the
original node resuming from the logged offset, and an ordinary attempt on a
fast node. If the original node is itself the slow/failed party, only the
ordinary attempt is placed ("an additional speculation is not allowed").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.types import ClusterSnapshot, SpeculateTask


@dataclasses.dataclass
class ProgressLog:
    """What survives on the original node for resuming a task."""

    task_id: str
    node_id: str
    # Fraction of the task's work already durable on that node (spills
    # written / microbatches accumulated). Resume skips this fraction.
    offset: float
    # Substrate-opaque handle (spill path / data-pipeline state blob).
    handle: object = None


class RollbackRegistry:
    """Coordinator-side registry of progress logs, fed by heartbeats."""

    def __init__(self):
        self._logs: Dict[str, ProgressLog] = {}

    def record(self, log: ProgressLog) -> None:
        prev = self._logs.get(log.task_id)
        # Keep the most-advanced log per task (later spill wins).
        if prev is None or log.offset >= prev.offset:
            self._logs[log.task_id] = log

    def get(self, task_id: str) -> Optional[ProgressLog]:
        return self._logs.get(task_id)

    def drop_node(self, node_id: str) -> None:
        """A dead node's local logs are gone (they are NOT replicated —
        §III.C explicitly rejects heavyweight remote checkpointing)."""
        self._logs = {t: l for t, l in self._logs.items()
                      if l.node_id != node_id}

    def drop_task(self, task_id: str) -> None:
        self._logs.pop(task_id, None)


def plan_rollback(
    snap: ClusterSnapshot,
    registry: RollbackRegistry,
    launches: Sequence[SpeculateTask],
    unhealthy_nodes: Set[str],
) -> List[SpeculateTask]:
    """Augment a wave of speculative launches with rollback attempts.

    For each planned ordinary launch whose task has a progress log on a
    healthy node, prepend a rollback attempt on that node. The ordinary
    attempt still races it from another node.
    """
    out: List[SpeculateTask] = []
    for action in launches:
        log = registry.get(action.task_id)
        if (log is not None
                and log.node_id not in unhealthy_nodes
                and log.node_id in snap.nodes
                and not snap.nodes[log.node_id].marked_failed
                and log.offset > 0.0):
            out.append(SpeculateTask(
                task_id=action.task_id,
                placement_hint=(log.node_id,),
                rollback=True,
                rollback_node=log.node_id,
                reason=action.reason + "+rollback"))
            # The racing ordinary attempt should avoid the original node.
            hint = tuple(n for n in action.placement_hint
                         if n != log.node_id)
            action = dataclasses.replace(action, placement_hint=hint)
        out.append(action)
    return out
