"""Collective speculation (§III.B): replace YARN's serial
one-speculation-per-heartbeat scheme with a ramped, neighborhood-first
collective launch.

Per straggler wave:
1. every straggler task gets an attempt in the victim node's *neighborhood*
   if containers are free there (cheap state transfer);
2. beyond the neighborhood, launches ramp geometrically —
   ``COLL_INIT_NUM × COLL_MULTIPLY^i`` in round ``i`` — but only while
   speculation is *winning* (some speculative attempt outpaces its
   original), which bounds resource burn when the cluster is merely busy;
3. when any attempt of a task completes, the others are killed (the
   substrate also enforces this; the policy emits the kill for promptness).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.accel.base import AssessmentBackend, get_backend
from repro.core.types import (
    AttemptState,
    ClusterSnapshot,
    KillAttempt,
    SpeculateTask,
    TaskState,
    TaskView,
)
from repro.obs.trace import K_RAMP


@dataclasses.dataclass(frozen=True)
class CollectiveConfig:
    coll_init_num: int = 1
    coll_multiply: int = 2
    # Seconds between ramp rounds ("a very small duration for periodic
    # progress checking" — §III.B).
    check_period: float = 2.0
    # A speculative attempt "wins" when its rate exceeds the original's by
    # this factor.
    win_factor: float = 1.0


class CollectiveSpeculation:
    """Tracks the ramp state and turns straggler sets into launch actions."""

    def __init__(self, cfg: CollectiveConfig = CollectiveConfig(),
                 backend: "Optional[str | AssessmentBackend]" = None):
        self.cfg = cfg
        self.backend = get_backend(backend)
        # Optional flight recorder (repro.obs): one K_RAMP record per
        # ramp round that actually launches.
        self.obs = None
        # Per job: ramp round and last ramp time.
        self._round: Dict[str, int] = {}
        self._last_check: Dict[str, float] = {}
        # Tasks already given a live speculative attempt this wave.
        self._speculated: Set[str] = set()

    # ------------------------------------------------------------------
    def _speculation_winning(self, snap: ClusterSnapshot, job_id: str) -> bool:
        """True if any live speculative attempt outpaces its original —
        the gate for continuing the geometric ramp."""
        arr = getattr(snap, "arrays", None)
        if arr is not None:
            jidx = arr.job_index.get(job_id)
            if jidx is None:
                return False
            return self.backend.winning(arr, snap.now, jidx,
                                        self.cfg.win_factor)
        for t in snap.tasks.values():
            if t.job_id != job_id:
                continue
            orig = [a for a in t.running_attempts() if not a.is_speculative]
            spec = [a for a in t.running_attempts() if a.is_speculative]
            if not spec:
                continue
            if not orig:
                return True  # original is gone; speculation is the job now
            o = max(a.progress_rate(snap.now) for a in orig)
            s = max(a.progress_rate(snap.now) for a in spec)
            if s > o * self.cfg.win_factor:
                return True
        return False

    def _free_in(self, snap: ClusterSnapshot, nodes: Sequence[str]) -> int:
        return sum(snap.nodes[n].free_containers for n in nodes
                   if n in snap.nodes and not snap.nodes[n].marked_failed)

    # ------------------------------------------------------------------
    def plan(
        self,
        snap: ClusterSnapshot,
        stragglers: Sequence[Tuple[TaskView, Optional[str], str]],
        neighborhood: Dict[str, List[str]],
    ) -> List[SpeculateTask]:
        """stragglers: (task, victim_node or None, reason) triples; a task
        appears at most once. ``neighborhood`` maps node → preferred
        placement order (victim's neighbors first)."""
        actions: List[SpeculateTask] = []
        # Drop tasks that already have a live speculative attempt, and let
        # re-waves re-speculate tasks whose speculative attempt died.
        todo: List[Tuple[TaskView, Optional[str], str]] = []
        for task, victim, reason in stragglers:
            if task.has_speculative_running():
                self._speculated.add(task.task_id)
                continue
            todo.append((task, victim, reason))
            self._speculated.discard(task.task_id)
        if not todo:
            return actions

        by_job: Dict[str, List[Tuple[TaskView, Optional[str], str]]] = {}
        for item in todo:
            by_job.setdefault(item[0].job_id, []).append(item)

        for job_id, items in by_job.items():
            rnd = self._round.get(job_id, 0)
            last = self._last_check.get(job_id)
            if last is not None and (snap.now - last) < self.cfg.check_period:
                continue
            self._last_check[job_id] = snap.now

            # Wave 1: fill the neighborhoods' free containers.
            nh_nodes: List[str] = []
            for _, victim, _ in items:
                if victim is not None:
                    nh_nodes.extend(neighborhood.get(victim, []))
            nh_budget = self._free_in(snap, dict.fromkeys(nh_nodes))

            # Beyond the neighborhood: geometric ramp, gated on winning.
            if rnd == 0:
                beyond_budget = self.cfg.coll_init_num
            elif self._speculation_winning(snap, job_id):
                beyond_budget = self.cfg.coll_init_num * (
                    self.cfg.coll_multiply ** rnd)
            else:
                beyond_budget = 0  # hold the ramp; keep what we have
            budget = nh_budget + beyond_budget
            if budget <= 0:
                continue

            launched = 0
            for task, victim, reason in items:
                if launched >= budget:
                    break
                hint = tuple(neighborhood.get(victim, [])) if victim else ()
                actions.append(SpeculateTask(
                    task_id=task.task_id, placement_hint=hint,
                    reason=reason))
                self._speculated.add(task.task_id)
                launched += 1
            if launched > 0:
                self._round[job_id] = rnd + 1
                if self.obs is not None:
                    self.obs.emit(K_RAMP, a=rnd, b=launched,
                                  f0=float(nh_budget),
                                  f1=float(beyond_budget), obj=job_id)

        return actions

    # ------------------------------------------------------------------
    def reap_completed(self, snap: ClusterSnapshot) -> List[KillAttempt]:
        """If either copy of a task finished, terminate the other (§III.B)."""
        arr = getattr(snap, "arrays", None)
        if arr is not None:
            return [KillAttempt(attempt_id=arr.attempt_ids[r],
                                reason="sibling attempt completed")
                    for r in self.backend.reap_rows(arr, snap.now)]
        kills: List[KillAttempt] = []
        for t in snap.tasks.values():
            # Task must be COMPLETED *now*: a re-activated producer (output
            # lost, task running again) has stale completed attempts whose
            # siblings are the recovery — do not reap those.
            if t.state != TaskState.COMPLETED:
                continue
            done = any(a.state == AttemptState.COMPLETED for a in t.attempts)
            if not done:
                continue
            for a in t.attempts:
                if a.state == AttemptState.RUNNING:
                    kills.append(KillAttempt(
                        attempt_id=a.attempt_id,
                        reason="sibling attempt completed"))
        return kills

    def job_done(self, job_id: str) -> None:
        self._round.pop(job_id, None)
        self._last_check.pop(job_id, None)
