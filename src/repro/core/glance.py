"""Neighborhood glance (§III.A): three independent assessments that expand
the speculator's scope in space (Eq. 1), time (Eq. 2–3), and responsiveness
(Eq. 4 adaptive failure threshold).

Stateful pieces (per-node ζ history for Δ, per-node outage windows for
Eq. 4) live here; the math is delegated to ``repro.core.metrics`` so the
simulator and the JAX runtime assess identically.

Two assessment paths share all state semantics (DESIGN.md §11):

- the **reference** per-object path walks ``snap.tasks``/``snap.nodes``
  views — used by the live runtime coordinator and the unit tests;
- the **vectorized** path runs when the substrate attaches a columnar
  ``ArraySnapshot`` (``snap.arrays``): one segmented-reduction pass over
  (job, kind, node) covers every job and both phases at once, and the
  Eq. 4 monitor is a handful of whole-cluster array ops. It is
  bit-equivalent to the reference path (same operand order, same
  accumulation order) — enforced by tests/test_columnar.py.

The vectorized path's dense math runs behind a pluggable
``AssessmentBackend`` (DESIGN.md §13): ``numpy`` (the reference),
``jax`` (jit device kernels), or ``pallas`` — selected via
``GlanceConfig.assess_backend``. All glance *state* (streaks, Δ
histories, outage windows) stays host-side regardless of backend.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.accel.base import AssessmentBackend, get_backend
from repro.core import metrics as M
from repro.core.types import AttemptState, ClusterSnapshot, TaskKind, TaskState
from repro.obs.trace import (
    K_GLANCE_FAIL,
    K_GLANCE_SPATIAL,
    K_GLANCE_TEMPORAL,
    K_THRESH,
)


@dataclasses.dataclass(frozen=True)
class GlanceConfig:
    # Eq. 3 slowdown threshold (paper default 0.1).
    threshold_slowdown: float = 0.1
    # Eq. 4 window length L (paper tunes 1..8; larger = more accurate).
    failure_window: int = 4
    # Nodes per spatial neighborhood, including self (paper: ≥3 useful).
    size_neighbor: int = 4
    # Initial per-node unresponsiveness threshold (s) before any history —
    # deliberately much shorter than YARN's 600 s NM expiry; Eq. 4 then
    # adapts it per node. Floors/caps keep transient hiccups from flapping.
    fail_threshold_init: float = 10.0
    fail_threshold_min: float = 3.0
    fail_threshold_max: float = 120.0
    # Safety factor over the Eq. 4 estimate of the next outage duration.
    fail_threshold_margin: float = 1.5
    # A node is "responsive" when silent for less than this (≈1.5× the
    # substrate's heartbeat period; the training runtime heartbeats every
    # 50 ms and overrides accordingly).
    responsive_window: float = 1.5
    # Minimum seconds between Δ samples (Eq. 2 sampling period).
    temporal_period: float = 3.0
    # Eq. 1 must hold for this many consecutive assessments before a node
    # is reported slow — mean−σ alone fires on the ~16 % Gaussian tail of
    # ordinary execution noise, which burns containers on healthy clusters.
    spatial_consecutive: int = 3
    # Eq. 3 reference window: Δ|Ti is compared against the MAX of the last
    # W samples, not just Δ|Ti−1 — with finite sampling a slowdown cliff
    # always straddles one sample boundary, and the diluted transition
    # sample would otherwise mask the drop from the strict ratio test.
    temporal_window: int = 5
    # Enable flags — Fig. 7(a) ablates these independently.
    enable_spatial: bool = True
    enable_temporal: bool = True
    enable_failure: bool = True
    # Assessment-compute backend for the vectorized (columnar) path:
    # "numpy" | "jax" | "pallas" (DESIGN.md §13).
    assess_backend: str = "numpy"


def build_neighborhoods(node_ids: Sequence[str], size_neighbor: int = 4,
                        topology: Optional[Dict[str, Sequence[str]]] = None
                        ) -> np.ndarray:
    """(n, k) neighborhood index rows. Default = ring segments of
    ``size_neighbor`` (the ICI-torus segment / rack analogue); an
    explicit adjacency overrides. Shared by the glance and the batched
    sweep (DESIGN.md §13.4)."""
    n = len(node_ids)
    k = min(size_neighbor, n)
    if topology is not None:
        node_index = {nid: i for i, nid in enumerate(node_ids)}
        rows = []
        for nid in node_ids:
            nh = [node_index[m] for m in topology[nid]][:k]
            while len(nh) < k:  # pad with self
                nh.append(node_index[nid])
            rows.append(nh)
        return np.asarray(rows, dtype=int)
    # Ring: node i's neighborhood = {i, i±1, ...} wrapped, k wide.
    offsets = np.arange(k) - (k // 2)
    idx = (np.arange(n)[:, None] + offsets[None, :]) % n
    return idx.astype(int)


@dataclasses.dataclass
class GlanceVerdict:
    """One assessment tick's findings."""

    # (job_id, node_id) pairs judged slow, with the assessment that fired.
    slow_nodes: List[Tuple[str, str, str]]  # (job, node, reason)
    # Nodes judged failed by the Eq. 4 monitor.
    failed_nodes: List[str]


class NeighborhoodGlance:
    """Stateful tri-assessment over coordinator snapshots."""

    def __init__(self, node_ids: Sequence[str], cfg: GlanceConfig = GlanceConfig(),
                 topology: Optional[Dict[str, Sequence[str]]] = None,
                 backend: Optional[AssessmentBackend] = None):
        self.cfg = cfg
        self.backend = backend if backend is not None \
            else get_backend(cfg.assess_backend)
        self.node_ids: List[str] = list(node_ids)
        self.node_index = {n: i for i, n in enumerate(self.node_ids)}
        self._neighborhoods = self._build_neighborhoods(topology)
        n = len(self.node_ids)
        # Eq. 2 state per job: {"k": accepted-sample counter, "t": time of
        # the last accepted sample, "prog": {attempt_id: ζ} at that sample
        # (reference path), "hist": Δ-history list of (n_nodes,) arrays}.
        # ζ deltas are computed over attempts alive at BOTH samples — the
        # paper's "only on-going tasks" guard against the end-of-wave
        # ProgressScore decline, done per-attempt so wave transitions can
        # never register as negative acceleration. The vectorized path
        # stores the per-attempt sample membership in two ArraySnapshot
        # scratch columns (sample mark + ζ at mark) instead of "prog".
        self._temporal: Dict[str, dict] = {}
        # Eq. 4 state, array-of-nodes storage shared by both paths:
        # outage-duration history (most recent last), current adaptive
        # threshold, outage bookkeeping (NaN = not currently lost).
        self._outages: Dict[str, List[float]] = {n_: [] for n_ in self.node_ids}
        self._thresholds = np.full(n, cfg.fail_threshold_init)
        self._lost = np.full(n, np.nan)
        self._declared = np.zeros(n, dtype=bool)
        # Debounce state: per (job, node) consecutive Eq. 1 hits
        # (reference path); per-job (n_nodes,) counters (vectorized path).
        self._spatial_streak: Dict[Tuple[str, str], int] = {}
        self._v_streak: Dict[str, np.ndarray] = {}
        # Optional flight recorder (repro.obs): verdict records carrying
        # the Eq. 1–4 inputs at decision time. One branch per fire site.
        self.obs = None

    def _build_neighborhoods(self, topology) -> np.ndarray:
        return build_neighborhoods(self.node_ids, self.cfg.size_neighbor,
                                   topology)

    def neighbors_of(self, node_id: str) -> List[str]:
        row = self._neighborhoods[self.node_index[node_id]]
        return [self.node_ids[i] for i in row if self.node_ids[i] != node_id]

    def threshold_of(self, node_id: str) -> float:
        return float(self._thresholds[self.node_index[node_id]])

    # ------------------------------------------------------------------
    # Assessment tick
    # ------------------------------------------------------------------
    def assess(self, snap: ClusterSnapshot) -> GlanceVerdict:
        arr = getattr(snap, "arrays", None)
        if arr is not None:
            return self._assess_arrays(snap, arr)
        slow: List[Tuple[str, str, str]] = []
        failed = self._assess_failure(snap) if self.cfg.enable_failure else []
        for job_id in snap.job_ids():
            if self.cfg.enable_spatial:
                for node in self._assess_spatial(snap, job_id):
                    slow.append((job_id, node, "spatial"))
            if self.cfg.enable_temporal:
                for node in self._assess_temporal(snap, job_id):
                    slow.append((job_id, node, "temporal"))
        return GlanceVerdict(slow_nodes=slow, failed_nodes=failed)

    # --- Eq. 1 (reference path) ---------------------------------------
    def _assess_spatial(self, snap: ClusterSnapshot, job_id: str) -> List[str]:
        # Assessed PER PHASE: the paper's P(N^J) averages ρ over all of a
        # job's tasks on the node, but map and reduce progress rates differ
        # by an order of magnitude (the dichotomy, §II.B) — mixing them
        # makes every reducer-hosting node look slow. See DESIGN.md §8.
        hits: set = set()
        pstats: Dict[int, Tuple[float, float, float]] = {}
        for kind in (TaskKind.MAP, TaskKind.REDUCE):
            prog, rt, nodes = [], [], []
            for t in snap.tasks.values():
                if t.job_id != job_id or t.state != TaskState.RUNNING \
                        or t.kind != kind:
                    continue
                for a in t.attempts:
                    if a.state != AttemptState.RUNNING:
                        continue
                    prog.append(a.progress)
                    rt.append(max(snap.now - a.start_time, 1e-9))
                    nodes.append(self.node_index[a.node_id])
            if not prog:
                continue
            P = M.node_progress_rate_np(
                np.asarray(prog), np.asarray(rt), np.asarray(nodes),
                len(self.node_ids))
            mask = M.spatial_slow_mask_np(P, self._neighborhoods)
            for i in np.flatnonzero(mask):
                hits.add(self.node_ids[i])
                if self.obs is not None:
                    nh = P[self._neighborhoods[i]]
                    nh = nh[~np.isnan(nh)]
                    mu = float(nh.mean()) if len(nh) else 0.0
                    sd = float(nh.std()) if len(nh) else 0.0
                    pstats[int(i)] = (float(P[i]), mu, sd)
        out = []
        for nid in self.node_ids:
            key = (job_id, nid)
            if nid in hits:
                streak = self._spatial_streak.get(key, 0) + 1
                self._spatial_streak[key] = streak
                if streak >= self.cfg.spatial_consecutive:
                    out.append(nid)
                    if self.obs is not None:
                        i = self.node_index[nid]
                        p, mu, sd = pstats.get(i, (0.0, 0.0, 0.0))
                        self.obs.emit(K_GLANCE_SPATIAL, a=i, b=streak,
                                      f0=p, f1=mu, f2=sd, obj=job_id)
            else:
                self._spatial_streak.pop(key, None)
        return out

    # --- Eq. 2–3 (reference path) -------------------------------------
    def _assess_temporal(self, snap: ClusterSnapshot, job_id: str) -> List[str]:
        n = len(self.node_ids)
        cur: Dict[str, float] = {}
        node_of: Dict[str, int] = {}
        for t in snap.tasks.values():
            if t.job_id != job_id or t.state != TaskState.RUNNING:
                continue
            for a in t.attempts:
                if a.state == AttemptState.RUNNING:
                    cur[a.attempt_id] = a.progress
                    node_of[a.attempt_id] = self.node_index[a.node_id]
        prev = self._temporal.get(job_id)
        if prev is None:
            self._temporal[job_id] = {
                "k": 0, "t": snap.now, "prog": cur, "hist": []}
            return []
        dt = snap.now - prev["t"]
        if dt < self.cfg.temporal_period:
            return []
        prev_prog, history = prev["prog"], prev["hist"]
        # ζ delta per node over attempts alive at both samples.
        zeta_now = np.full(n, np.nan)
        zeta_prev = np.full(n, np.nan)
        for aid, p in cur.items():
            if aid not in prev_prog:
                continue
            i = node_of[aid]
            if np.isnan(zeta_now[i]):
                zeta_now[i] = 0.0
                zeta_prev[i] = 0.0
            zeta_now[i] += p
            zeta_prev[i] += prev_prog[aid]
        slow_mask, delta_now = self._temporal_step(
            history, zeta_now, zeta_prev, dt)
        prev.update(k=prev["k"] + 1, t=snap.now, prog=cur)
        return [self.node_ids[i] for i in np.flatnonzero(slow_mask)]

    def _temporal_step(self, history: List[np.ndarray], zeta_now, zeta_prev,
                       dt: float):
        """Shared Eq. 2–3 core: peak-hold reference over the recent window,
        strict-ratio slowdown test, history update."""
        n = len(self.node_ids)
        if history:
            stacked = np.stack(history)
            any_valid = ~np.isnan(stacked).all(axis=0)
            filled = np.where(np.isnan(stacked), -np.inf, stacked)
            delta_ref = np.where(any_valid, filled.max(axis=0), np.nan)
        else:
            delta_ref = np.full(n, np.nan)
        slow_mask, delta_now = M.temporal_slow_mask_np(
            zeta_now, zeta_prev, dt, delta_ref,
            threshold_slowdown=self.cfg.threshold_slowdown)
        if self.obs is not None:
            for i in np.flatnonzero(slow_mask):
                self.obs.emit(K_GLANCE_TEMPORAL, a=int(i),
                              f0=float(delta_now[i]),
                              f1=float(delta_ref[i]), f2=dt,
                              f3=self.cfg.threshold_slowdown)
        history.append(delta_now)
        del history[:-self.cfg.temporal_window]
        return slow_mask, delta_now

    # --- Eq. 4 (reference path) ---------------------------------------
    def _assess_failure(self, snap: ClusterSnapshot) -> List[str]:
        newly_failed: List[str] = []
        for nid, node in snap.nodes.items():
            i = self.node_index.get(nid)
            if i is None:
                continue
            silent = snap.now - node.last_heartbeat
            lost_at = self._lost[i]
            if silent <= self.cfg.responsive_window:  # responsive this tick
                if not np.isnan(lost_at):
                    # A resuming heartbeat from a previously lost node:
                    # record the outage duration R_n and adapt (Eq. 4).
                    outage = snap.now - lost_at
                    self._record_outage(nid, outage)
                    self._lost[i] = np.nan
                self._declared[i] = False
                continue
            if np.isnan(lost_at):
                self._lost[i] = node.last_heartbeat
            if self._declared[i] or node.marked_failed:
                continue
            if silent > self._thresholds[i]:
                self._declared[i] = True
                newly_failed.append(nid)
                if self.obs is not None:
                    self.obs.emit(K_GLANCE_FAIL, a=i, f0=silent,
                                  f1=float(self._thresholds[i]),
                                  f2=silent - float(self._thresholds[i]))
        return newly_failed

    def _record_outage(self, node_id: str, duration: float) -> None:
        hist = self._outages[node_id]
        hist.append(duration)
        L = self.cfg.failure_window
        del hist[:-L]
        est = M.eq4_estimate_np(hist, L)
        if est is not None:
            i = self.node_index[node_id]
            self._thresholds[i] = float(np.clip(
                est * self.cfg.fail_threshold_margin,
                self.cfg.fail_threshold_min, self.cfg.fail_threshold_max))
            if self.obs is not None:
                self.obs.emit(K_THRESH, a=i, b=len(hist),
                              f0=float(self._thresholds[i]), f1=duration,
                              f2=float(est))

    # Substrate hook: a node confirmed dead externally resets its streak so a
    # replacement with the same id starts from the configured default.
    def reset_node(self, node_id: str) -> None:
        i = self.node_index[node_id]
        self._lost[i] = np.nan
        self._declared[i] = False

    # ==================================================================
    # Vectorized path (columnar snapshots)
    # ==================================================================
    def _assess_arrays(self, snap: ClusterSnapshot, arr) -> GlanceVerdict:
        now = snap.now
        failed = (self._assess_failure_arrays(now, arr)
                  if self.cfg.enable_failure else [])
        active = arr.active_jobs()
        J = len(active)
        spatial_fire = temporal_fire = None
        if J and (self.cfg.enable_spatial or self.cfg.enable_temporal):
            if self.cfg.enable_spatial:
                spatial_fire = self._spatial_arrays(now, arr, active)
            if self.cfg.enable_temporal:
                temporal_fire = self._temporal_arrays(now, arr, active)
        slow: List[Tuple[str, str, str]] = []
        for pos, (jid, _jidx) in enumerate(active):
            if spatial_fire is not None:
                for i in np.flatnonzero(spatial_fire[pos]):
                    slow.append((jid, self.node_ids[i], "spatial"))
            if temporal_fire is not None:
                for i in np.flatnonzero(temporal_fire[pos]):
                    slow.append((jid, self.node_ids[i], "temporal"))
        return GlanceVerdict(slow_nodes=slow, failed_nodes=failed)

    # --- Eq. 1, all jobs × both phases in one backend pass -------------
    def _spatial_arrays(self, now: float, arr, active) -> np.ndarray:
        n = len(self.node_ids)
        J = len(active)
        hits = self.backend.spatial_hits(arr, now, active,
                                         self._neighborhoods)
        fire = np.zeros((J, n), dtype=bool)
        for pos, (jid, _jidx) in enumerate(active):
            streak = self._v_streak.get(jid)
            if streak is None:
                streak = np.zeros(n, dtype=np.int64)
                self._v_streak[jid] = streak
            streak[:] = np.where(hits[pos], streak + 1, 0)
            fire[pos] = streak >= self.cfg.spatial_consecutive
            if self.obs is not None:
                # Vectorized path: the backend consumed the P values; the
                # verdict record carries the streak only (§18.2 waiver).
                for i in np.flatnonzero(fire[pos]):
                    self.obs.emit(K_GLANCE_SPATIAL, a=int(i),
                                  b=int(streak[i]), obj=jid)
        if len(self._v_streak) > 2 * J + 16:  # shed completed jobs' state
            keep = {jid for jid, _ in active}
            self._v_streak = {j: s for j, s in self._v_streak.items()
                              if j in keep}
        return fire

    # --- Eq. 2–3, per-attempt work batched across all sampled jobs -----
    def _temporal_arrays(self, now: float, arr, active) -> np.ndarray:
        n = len(self.node_ids)
        J = len(active)
        fire = np.zeros((J, n), dtype=bool)
        init_flag = np.zeros(J, dtype=bool)
        samp_flag = np.zeros(J, dtype=bool)
        prevk = np.full(J, -2, dtype=np.int64)
        states = []
        for pos, (jid, _jidx) in enumerate(active):
            st = self._temporal.get(jid)
            if st is None:
                st = {"k": 0, "t": now, "hist": []}
                self._temporal[jid] = st
                init_flag[pos] = True
            elif now - st["t"] >= self.cfg.temporal_period:
                samp_flag[pos] = True
                prevk[pos] = st["k"]
            states.append(st)
        zeta_now, zeta_prev = self.backend.temporal_zeta(
            arr, now, active, samp_flag, init_flag, prevk)
        for pos in np.flatnonzero(samp_flag):
            st = states[pos]
            dt = now - st["t"]
            slow_mask, _ = self._temporal_step(
                st["hist"], zeta_now[pos], zeta_prev[pos], dt)
            st["k"] += 1
            st["t"] = now
            fire[pos] = slow_mask
        return fire

    # --- Eq. 4, whole-cluster array ops --------------------------------
    def _assess_failure_arrays(self, now: float, arr) -> List[str]:
        resp, cand = self.backend.failure_masks(
            now, arr.node_hb, arr.node_marked, self._declared,
            self._thresholds, self.cfg.responsive_window)
        resumed = resp & ~np.isnan(self._lost)
        for i in np.flatnonzero(resumed):
            # A resuming heartbeat from a previously lost node (rare):
            # record the outage duration R_n and adapt (Eq. 4).
            self._record_outage(self.node_ids[i], now - self._lost[i])
        self._lost[resp] = np.nan
        self._declared[resp] = False
        newlost = ~resp & np.isnan(self._lost)
        self._lost[newlost] = arr.node_hb[newlost]
        self._declared[cand] = True
        out = [self.node_ids[i] for i in np.flatnonzero(cand)]
        if self.obs is not None:
            for i in np.flatnonzero(cand):
                silent = now - float(arr.node_hb[i])
                self.obs.emit(K_GLANCE_FAIL, a=int(i), f0=silent,
                              f1=float(self._thresholds[i]),
                              f2=silent - float(self._thresholds[i]))
        return out
