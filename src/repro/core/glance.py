"""Neighborhood glance (§III.A): three independent assessments that expand
the speculator's scope in space (Eq. 1), time (Eq. 2–3), and responsiveness
(Eq. 4 adaptive failure threshold).

Stateful pieces (per-node ζ history for Δ, per-node outage windows for
Eq. 4) live here; the math is delegated to ``repro.core.metrics`` so the
simulator and the JAX runtime assess identically.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core import metrics as M
from repro.core.types import AttemptState, ClusterSnapshot, TaskKind, TaskState


@dataclasses.dataclass(frozen=True)
class GlanceConfig:
    # Eq. 3 slowdown threshold (paper default 0.1).
    threshold_slowdown: float = 0.1
    # Eq. 4 window length L (paper tunes 1..8; larger = more accurate).
    failure_window: int = 4
    # Nodes per spatial neighborhood, including self (paper: ≥3 useful).
    size_neighbor: int = 4
    # Initial per-node unresponsiveness threshold (s) before any history —
    # deliberately much shorter than YARN's 600 s NM expiry; Eq. 4 then
    # adapts it per node. Floors/caps keep transient hiccups from flapping.
    fail_threshold_init: float = 10.0
    fail_threshold_min: float = 3.0
    fail_threshold_max: float = 120.0
    # Safety factor over the Eq. 4 estimate of the next outage duration.
    fail_threshold_margin: float = 1.5
    # A node is "responsive" when silent for less than this (≈1.5× the
    # substrate's heartbeat period; the training runtime heartbeats every
    # 50 ms and overrides accordingly).
    responsive_window: float = 1.5
    # Minimum seconds between Δ samples (Eq. 2 sampling period).
    temporal_period: float = 3.0
    # Eq. 1 must hold for this many consecutive assessments before a node
    # is reported slow — mean−σ alone fires on the ~16 % Gaussian tail of
    # ordinary execution noise, which burns containers on healthy clusters.
    spatial_consecutive: int = 3
    # Eq. 3 reference window: Δ|Ti is compared against the MAX of the last
    # W samples, not just Δ|Ti−1 — with finite sampling a slowdown cliff
    # always straddles one sample boundary, and the diluted transition
    # sample would otherwise mask the drop from the strict ratio test.
    temporal_window: int = 5
    # Enable flags — Fig. 7(a) ablates these independently.
    enable_spatial: bool = True
    enable_temporal: bool = True
    enable_failure: bool = True


@dataclasses.dataclass
class GlanceVerdict:
    """One assessment tick's findings."""

    # (job_id, node_id) pairs judged slow, with the assessment that fired.
    slow_nodes: List[Tuple[str, str, str]]  # (job, node, reason)
    # Nodes judged failed by the Eq. 4 monitor.
    failed_nodes: List[str]


class NeighborhoodGlance:
    """Stateful tri-assessment over coordinator snapshots."""

    def __init__(self, node_ids: Sequence[str], cfg: GlanceConfig = GlanceConfig(),
                 topology: Optional[Dict[str, Sequence[str]]] = None):
        self.cfg = cfg
        self.node_ids: List[str] = list(node_ids)
        self.node_index = {n: i for i, n in enumerate(self.node_ids)}
        self._neighborhoods = self._build_neighborhoods(topology)
        # Eq. 2 state per job: (T_{i-1}, {attempt_id: progress},
        # Δ-history deque of shape (W, n_nodes)).
        # ζ deltas are computed over attempts alive at BOTH samples — the
        # paper's "only on-going tasks" guard against the end-of-wave
        # ProgressScore decline, done per-attempt so wave transitions can
        # never register as negative acceleration.
        self._temporal: Dict[str, Tuple[float, Dict[str, float], List[np.ndarray]]] = {}
        # Eq. 4 state: per node → outage-duration history (most recent last),
        # current adaptive threshold, and outage bookkeeping.
        self._outages: Dict[str, List[float]] = {n: [] for n in self.node_ids}
        self._thresholds: Dict[str, float] = {
            n: cfg.fail_threshold_init for n in self.node_ids}
        self._lost_since: Dict[str, Optional[float]] = {
            n: None for n in self.node_ids}
        self._declared_failed: Set[str] = set()
        # Debounce state: per (job, node) consecutive Eq. 1 hits.
        self._spatial_streak: Dict[Tuple[str, str], int] = {}

    # ------------------------------------------------------------------
    # Topology: default = ring segments of size_neighbor (the ICI-torus
    # segment / rack analogue); callers may pass an explicit adjacency.
    # ------------------------------------------------------------------
    def _build_neighborhoods(self, topology) -> np.ndarray:
        n = len(self.node_ids)
        k = min(self.cfg.size_neighbor, n)
        if topology is not None:
            rows = []
            for nid in self.node_ids:
                nh = [self.node_index[m] for m in topology[nid]][:k]
                while len(nh) < k:  # pad with self
                    nh.append(self.node_index[nid])
                rows.append(nh)
            return np.asarray(rows, dtype=int)
        # Ring: node i's neighborhood = {i, i±1, ...} wrapped, k wide.
        offsets = np.arange(k) - (k // 2)
        idx = (np.arange(n)[:, None] + offsets[None, :]) % n
        return idx.astype(int)

    def neighbors_of(self, node_id: str) -> List[str]:
        row = self._neighborhoods[self.node_index[node_id]]
        return [self.node_ids[i] for i in row if self.node_ids[i] != node_id]

    def threshold_of(self, node_id: str) -> float:
        return self._thresholds[node_id]

    # ------------------------------------------------------------------
    # Assessment tick
    # ------------------------------------------------------------------
    def assess(self, snap: ClusterSnapshot) -> GlanceVerdict:
        slow: List[Tuple[str, str, str]] = []
        failed = self._assess_failure(snap) if self.cfg.enable_failure else []
        for job_id in snap.job_ids():
            if self.cfg.enable_spatial:
                for node in self._assess_spatial(snap, job_id):
                    slow.append((job_id, node, "spatial"))
            if self.cfg.enable_temporal:
                for node in self._assess_temporal(snap, job_id):
                    slow.append((job_id, node, "temporal"))
        return GlanceVerdict(slow_nodes=slow, failed_nodes=failed)

    # --- Eq. 1 ---------------------------------------------------------
    def _assess_spatial(self, snap: ClusterSnapshot, job_id: str) -> List[str]:
        # Assessed PER PHASE: the paper's P(N^J) averages ρ over all of a
        # job's tasks on the node, but map and reduce progress rates differ
        # by an order of magnitude (the dichotomy, §II.B) — mixing them
        # makes every reducer-hosting node look slow. See DESIGN.md §8.
        hits: set = set()
        for kind in (TaskKind.MAP, TaskKind.REDUCE):
            prog, rt, nodes = [], [], []
            for t in snap.tasks.values():
                if t.job_id != job_id or t.state != TaskState.RUNNING \
                        or t.kind != kind:
                    continue
                for a in t.attempts:
                    if a.state != AttemptState.RUNNING:
                        continue
                    prog.append(a.progress)
                    rt.append(max(snap.now - a.start_time, 1e-9))
                    nodes.append(self.node_index[a.node_id])
            if not prog:
                continue
            P = M.node_progress_rate_np(
                np.asarray(prog), np.asarray(rt), np.asarray(nodes),
                len(self.node_ids))
            mask = M.spatial_slow_mask_np(P, self._neighborhoods)
            hits |= {self.node_ids[i] for i in np.flatnonzero(mask)}
        out = []
        for nid in self.node_ids:
            key = (job_id, nid)
            if nid in hits:
                streak = self._spatial_streak.get(key, 0) + 1
                self._spatial_streak[key] = streak
                if streak >= self.cfg.spatial_consecutive:
                    out.append(nid)
            else:
                self._spatial_streak.pop(key, None)
        return out

    # --- Eq. 2–3 -------------------------------------------------------
    def _assess_temporal(self, snap: ClusterSnapshot, job_id: str) -> List[str]:
        n = len(self.node_ids)
        cur: Dict[str, float] = {}
        node_of: Dict[str, int] = {}
        for t in snap.tasks.values():
            if t.job_id != job_id or t.state != TaskState.RUNNING:
                continue
            for a in t.attempts:
                if a.state == AttemptState.RUNNING:
                    cur[a.attempt_id] = a.progress
                    node_of[a.attempt_id] = self.node_index[a.node_id]
        prev = self._temporal.get(job_id)
        if prev is None:
            self._temporal[job_id] = (snap.now, cur, [])
            return []
        t_prev, prev_prog, history = prev
        dt = snap.now - t_prev
        if dt < self.cfg.temporal_period:
            return []
        # ζ delta per node over attempts alive at both samples.
        zeta_now = np.full(n, np.nan)
        zeta_prev = np.full(n, np.nan)
        for aid, p in cur.items():
            if aid not in prev_prog:
                continue
            i = node_of[aid]
            if np.isnan(zeta_now[i]):
                zeta_now[i] = 0.0
                zeta_prev[i] = 0.0
            zeta_now[i] += p
            zeta_prev[i] += prev_prog[aid]
        # Peak-hold reference: the max Δ over the recent window.
        if history:
            stacked = np.stack(history)
            any_valid = ~np.isnan(stacked).all(axis=0)
            filled = np.where(np.isnan(stacked), -np.inf, stacked)
            delta_ref = np.where(any_valid, filled.max(axis=0), np.nan)
        else:
            delta_ref = np.full(n, np.nan)
        slow_mask, delta_now = M.temporal_slow_mask_np(
            zeta_now, zeta_prev, dt, delta_ref,
            threshold_slowdown=self.cfg.threshold_slowdown)
        history.append(delta_now)
        del history[:-self.cfg.temporal_window]
        self._temporal[job_id] = (snap.now, cur, history)
        return [self.node_ids[i] for i in np.flatnonzero(slow_mask)]

    # --- Eq. 4 ---------------------------------------------------------
    def _assess_failure(self, snap: ClusterSnapshot) -> List[str]:
        newly_failed: List[str] = []
        for nid, node in snap.nodes.items():
            if nid not in self.node_index:
                continue
            silent = snap.now - node.last_heartbeat
            lost_at = self._lost_since[nid]
            if silent <= self.cfg.responsive_window:  # responsive this tick
                if lost_at is not None:
                    # A resuming heartbeat from a previously lost node:
                    # record the outage duration R_n and adapt (Eq. 4).
                    outage = snap.now - lost_at
                    self._record_outage(nid, outage)
                    self._lost_since[nid] = None
                self._declared_failed.discard(nid)
                continue
            if lost_at is None:
                self._lost_since[nid] = node.last_heartbeat
            if nid in self._declared_failed or node.marked_failed:
                continue
            if silent > self._thresholds[nid]:
                self._declared_failed.add(nid)
                newly_failed.append(nid)
        return newly_failed

    def _record_outage(self, node_id: str, duration: float) -> None:
        hist = self._outages[node_id]
        hist.append(duration)
        L = self.cfg.failure_window
        del hist[:-L]
        est = M.eq4_estimate_np(hist, L)
        if est is not None:
            self._thresholds[node_id] = float(np.clip(
                est * self.cfg.fail_threshold_margin,
                self.cfg.fail_threshold_min, self.cfg.fail_threshold_max))

    # Substrate hook: a node confirmed dead externally resets its streak so a
    # replacement with the same id starts from the configured default.
    def reset_node(self, node_id: str) -> None:
        self._lost_since[node_id] = None
        self._declared_failed.discard(node_id)
