"""Dependency-aware speculation (the cure for §II.D.1
*dependency-oblivious speculation*).

Tracks the producer→consumer graph (map → MOF → reduce; in the training
runtime: microbatch grads → all-reduce, prefill KV → decode) and decides
when a COMPLETED producer must be re-executed:

- two consecutive fetch failures of one producer's output (§III.B), or
- a positive failure assessment of the node(s) holding the only copy of
  that output (proactive: don't wait for the consumer to trip over it).

Outputs of re-executed completed tasks are kept ALONGSIDE the originals
until job completion (§III.B) — enforcement lives in the substrate; the
policy records which producer ids were re-speculated so the substrate knows
not to discard either copy.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Sequence, Set

from repro.core.types import (
    ClusterSnapshot,
    FetchFailure,
    SpeculateTask,
    TaskState,
)


@dataclasses.dataclass(frozen=True)
class DependencyConfig:
    # Consecutive fetch failures of one producer before re-execution
    # (paper: "two consecutive intermediate data fetch failures").
    fetch_failure_threshold: int = 2


class DependencyTracker:
    def __init__(self, cfg: DependencyConfig = DependencyConfig()):
        self.cfg = cfg
        self._consecutive: Dict[str, int] = {}
        # Producers re-speculated this job lifetime (both outputs kept).
        self.respeculated: Set[str] = set()

    # ------------------------------------------------------------------
    def note_fetch_ok(self, producer_task_id: str) -> None:
        self._consecutive.pop(producer_task_id, None)

    def on_fetch_failures(
        self, snap: ClusterSnapshot, failures: Sequence[FetchFailure]
    ) -> List[SpeculateTask]:
        """Count consecutive fetch failures per producer; fire at threshold."""
        out: List[SpeculateTask] = []
        for f in failures:
            c = self._consecutive.get(f.producer_task_id, 0) + 1
            self._consecutive[f.producer_task_id] = c
            if c < self.cfg.fetch_failure_threshold:
                continue
            task = snap.tasks.get(f.producer_task_id)
            if task is None:
                continue
            if task.state == TaskState.COMPLETED and not task.output_available:
                pass  # output already known-lost: definitely re-run
            if self._already_rerunning(snap, f.producer_task_id):
                continue
            out.append(SpeculateTask(
                task_id=f.producer_task_id,
                reason="dependency:fetch-failures"))
            self.respeculated.add(f.producer_task_id)
            self._consecutive[f.producer_task_id] = 0
        return out

    # ------------------------------------------------------------------
    def on_node_failed(
        self, snap: ClusterSnapshot, failed_nodes: Iterable[str]
    ) -> List[SpeculateTask]:
        """Proactively re-execute completed producers whose only output
        copies lived on nodes the Eq. 4 monitor just declared dead."""
        failed = set(failed_nodes)
        if not failed:
            return []
        out: List[SpeculateTask] = []
        for t in snap.tasks.values():
            if t.state != TaskState.COMPLETED:
                continue
            if not t.output_nodes:
                continue
            surviving = [n for n in t.output_nodes if n not in failed]
            if surviving:
                continue
            if self._already_rerunning(snap, t.task_id):
                continue
            out.append(SpeculateTask(
                task_id=t.task_id, reason="dependency:producer-node-failed"))
            self.respeculated.add(t.task_id)
        return out

    # ------------------------------------------------------------------
    @staticmethod
    def _already_rerunning(snap: ClusterSnapshot, task_id: str) -> bool:
        t = snap.tasks.get(task_id)
        return t is not None and bool(t.running_attempts())
