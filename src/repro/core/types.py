"""Shared control-plane types for the binocular-speculation policy engine.

The policy engine (``repro.core``) is deliberately decoupled from any
execution substrate: it consumes immutable :class:`ClusterSnapshot` views and
emits :class:`Action` values. Two substrates drive it:

- ``repro.sim`` — the deterministic discrete-event MapReduce simulator that
  reproduces the paper's own experiments (Figs. 1–9), and
- ``repro.runtime`` — the live JAX training runtime, where "map tasks" are
  per-host microbatch gradient production and "reduce tasks" are the
  all-reduce + optimizer phase (see DESIGN.md §2 for the full mapping).

Keeping one policy implementation behind one snapshot protocol is what makes
the reproduction *faithful*: the math of Eq. 1–4 and the collective ramp are
exercised identically by the paper's benchmarks and by the training runtime.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Mapping, Optional, Sequence, Tuple


class TaskKind(str, enum.Enum):
    MAP = "map"          # short-lived producer (microbatch grad / prefill)
    REDUCE = "reduce"    # long-lived dependent consumer (optimizer / decode)


class TaskState(str, enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    COMPLETED = "completed"
    FAILED = "failed"


class AttemptState(str, enum.Enum):
    RUNNING = "running"
    COMPLETED = "completed"
    FAILED = "failed"
    KILLED = "killed"


@dataclasses.dataclass
class AttemptView:
    """One execution attempt of a task (original or speculative)."""

    attempt_id: str
    task_id: str
    node_id: str
    state: AttemptState
    start_time: float
    # ProgressScore ζ(t) ∈ [0, 1]  (YARN's per-task progress metric).
    progress: float = 0.0
    is_speculative: bool = False
    is_rollback: bool = False

    def progress_rate(self, now: float) -> float:
        """ρ(t) = ζ(t) / τ_t — the LATE/Eq.1 task progress rate."""
        dt = max(now - self.start_time, 1e-9)
        return self.progress / dt


@dataclasses.dataclass
class TaskView:
    task_id: str
    job_id: str
    kind: TaskKind
    state: TaskState
    attempts: List[AttemptView] = dataclasses.field(default_factory=list)
    # Producer dependencies: for a reduce task, the map task ids whose
    # intermediate output (MOF / gradient shard / KV shard) it consumes.
    deps: Tuple[str, ...] = ()
    # Node(s) currently holding this task's committed output (MOF location).
    output_nodes: Tuple[str, ...] = ()
    # True once at least one complete output copy is fetchable.
    output_available: bool = False

    def running_attempts(self) -> List[AttemptView]:
        return [a for a in self.attempts if a.state == AttemptState.RUNNING]

    def has_speculative_running(self) -> bool:
        return any(a.is_speculative for a in self.running_attempts())


@dataclasses.dataclass
class NodeView:
    node_id: str
    # Time of last heartbeat received by the coordinator.
    last_heartbeat: float
    # Containers: total slots and currently-free slots on this node.
    total_containers: int = 1
    free_containers: int = 0
    # Attempts currently placed on this node.
    attempt_ids: Tuple[str, ...] = ()
    # Externally-confirmed dead (e.g. the substrate decommissioned it).
    marked_failed: bool = False


@dataclasses.dataclass
class FetchFailure:
    """A consumer attempt failed to fetch a producer's intermediate output."""

    time: float
    consumer_task_id: str
    producer_task_id: str


@dataclasses.dataclass
class ClusterSnapshot:
    """Immutable coordinator view handed to a speculator on each tick.

    When the substrate maintains a columnar mirror of the same state
    (``repro.core.arrays.ArraySnapshot``), it is attached as ``arrays`` and
    the policies take their vectorized assessment paths; ``nodes``/``tasks``
    may then be lazy mappings that materialize views only on access, so the
    per-object protocol keeps working unchanged (DESIGN.md §11.2). With
    ``arrays is None`` (the live runtime coordinator, unit tests) every
    policy uses the per-object reference path.
    """

    now: float
    nodes: Mapping[str, NodeView]
    tasks: Mapping[str, TaskView]
    # Fetch failures since the previous snapshot (cleared by the substrate).
    fetch_failures: Sequence[FetchFailure] = ()
    # Optional columnar mirror (repro.core.arrays.ArraySnapshot).
    arrays: Optional[object] = None

    def job_tasks(self, job_id: str) -> List[TaskView]:
        return [t for t in self.tasks.values() if t.job_id == job_id]

    def job_ids(self) -> List[str]:
        seen: Dict[str, None] = {}
        for t in self.tasks.values():
            seen.setdefault(t.job_id)
        return list(seen)

    def attempts_on(self, node_id: str) -> List[AttemptView]:
        out = []
        for t in self.tasks.values():
            for a in t.attempts:
                if a.node_id == node_id and a.state == AttemptState.RUNNING:
                    out.append(a)
        return out


# ---------------------------------------------------------------------------
# Actions emitted by a speculator. The substrate executes them.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SpeculateTask:
    """Launch a (speculative) attempt of ``task_id``.

    ``placement_hint`` lists node ids in preference order (neighborhood
    first, per §III.B); the substrate picks the first with a free container.
    ``rollback`` requests resume-from-progress-log on ``rollback_node``
    (§III.C); the substrate falls back to a fresh attempt if the log is gone.
    ``reason`` tags which assessment fired (spatial/temporal/failure/
    dependency/late) — benchmarks aggregate on it.
    """

    task_id: str
    placement_hint: Tuple[str, ...] = ()
    rollback: bool = False
    rollback_node: Optional[str] = None
    reason: str = ""


@dataclasses.dataclass
class KillAttempt:
    attempt_id: str
    reason: str = ""


@dataclasses.dataclass
class MarkNodeFailed:
    """Coordinator verdict from the Eq. 4 failure assessment: treat the node
    as dead *now* instead of waiting for the substrate's long expiry."""

    node_id: str
    reason: str = ""


Action = object  # Union[SpeculateTask, KillAttempt, MarkNodeFailed]
