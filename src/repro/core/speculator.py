"""The two speculation policies the paper compares.

``YarnLateSpeculator`` — the baseline: YARN's default LATE scheduler
(Zaharia et al., OSDI'08) with its documented myopias kept intact:
 * considers only RUNNING tasks (dependency-oblivious);
 * needs progress-rate *variation* among tasks (scope-limited);
 * serial — at most one speculative launch per assessment tick, with a
   fixed delay between launches;
 * capped speculative count; never resumes from partial progress.

``BinocularSpeculator`` — the paper's contribution: neighborhood glance
(Eq. 1–4) + collective speculation ramp + dependency-aware re-execution of
completed producers + speculative rollback.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.accel.base import AssessmentBackend, get_backend
from repro.core.collective import CollectiveConfig, CollectiveSpeculation
from repro.core.dependency import DependencyConfig, DependencyTracker
from repro.core.glance import GlanceConfig, NeighborhoodGlance
from repro.core.rollback import RollbackRegistry, plan_rollback
from repro.core.types import (
    Action,
    AttemptState,
    ClusterSnapshot,
    KillAttempt,
    MarkNodeFailed,
    SpeculateTask,
    TaskKind,
    TaskState,
    TaskView,
)
from repro.obs.trace import K_BUDGET, K_LATE


class Speculator:
    """Common protocol: one assessment tick → actions."""

    # Optional flight recorder (repro.obs); Simulation._wire_obs / the
    # runtime coordinator set it on the instance.
    obs = None

    def assess(self, snap: ClusterSnapshot) -> List[Action]:  # pragma: no cover
        raise NotImplementedError

    def job_done(self, job_id: str) -> None:
        pass


# ---------------------------------------------------------------------------
# Baseline: YARN default (LATE)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LateConfig:
    # LATE defaults (OSDI'08): SpeculativeCap 10%, SlowTaskThreshold 25th
    # percentile of progress rates, one launch per heartbeat round.
    speculative_cap: float = 0.1
    slow_task_percentile: float = 25.0
    # Fixed delay between speculative launches (the "serial scheme ...
    # with a fixed delay interval" of §II.C).
    launch_delay: float = 15.0
    # Don't speculate a task younger than this (YARN default guard).
    min_runtime: float = 10.0


class YarnLateSpeculator(Speculator):
    def __init__(self, cfg: LateConfig = LateConfig(),
                 assess_backend: "Optional[str | AssessmentBackend]" = None):
        self.cfg = cfg
        self.backend = get_backend(assess_backend)
        self._last_launch: Dict[str, float] = {}
        self._spec_count: Dict[str, int] = {}

    def assess(self, snap: ClusterSnapshot) -> List[Action]:
        arr = getattr(snap, "arrays", None)
        if arr is not None:
            return self._assess_arrays(snap, arr)
        actions: List[Action] = []
        # Kill redundant attempts whose sibling finished (standard YARN).
        # Only for tasks still COMPLETED — a re-activated producer's fresh
        # attempt must not be reaped against its stale completed sibling.
        for t in snap.tasks.values():
            if t.state != TaskState.COMPLETED:
                continue
            if any(a.state == AttemptState.COMPLETED for a in t.attempts):
                for a in t.attempts:
                    if a.state == AttemptState.RUNNING:
                        actions.append(KillAttempt(a.attempt_id,
                                                   "sibling completed"))
        for job_id in snap.job_ids():
            action = self._assess_job(snap, job_id)
            if action is not None:
                actions.append(action)
        return actions

    def _assess_job(self, snap: ClusterSnapshot,
                    job_id: str) -> Optional[SpeculateTask]:
        last = self._last_launch.get(job_id, -1e18)
        if snap.now - last < self.cfg.launch_delay:
            return None  # serial speculation with fixed delay
        tasks = [t for t in snap.tasks.values()
                 if t.job_id == job_id and t.state == TaskState.RUNNING]
        n_total = sum(1 for t in snap.tasks.values() if t.job_id == job_id)
        if self._spec_count.get(job_id, 0) >= max(
                1, int(self.cfg.speculative_cap * n_total)):
            return None
        # Progress rates of all RUNNING attempts (completed tasks are
        # invisible — the dependency myopia, faithfully reproduced).
        rates: List[Tuple[float, float, TaskView]] = []
        for t in tasks:
            if t.has_speculative_running():
                continue
            run = t.running_attempts()
            if not run:
                continue
            a = max(run, key=lambda a: a.progress)
            if snap.now - a.start_time < self.cfg.min_runtime:
                continue
            rho = a.progress_rate(snap.now)
            est_remaining = (1.0 - a.progress) / max(rho, 1e-9)
            rates.append((rho, est_remaining, t))
        if len(rates) < 2:
            # LATE needs variation among tasks to rank stragglers — with
            # zero or one candidate there is nothing to compare against
            # (the scope-limited myopia, faithfully reproduced).
            return None
        rhos = np.asarray([r[0] for r in rates])
        thresh = np.percentile(rhos, self.cfg.slow_task_percentile)
        # STRICTLY below the percentile: with identical rates (a whole job
        # frozen on one node) nothing qualifies — the scope-limited myopia.
        slow = [r for r in rates if r[0] < thresh]
        if not slow:
            return None
        # Speculate the slow task with the LONGEST estimated remaining time.
        rho_v, est_v, victim = max(slow, key=lambda r: r[1])
        self._last_launch[job_id] = snap.now
        self._spec_count[job_id] = self._spec_count.get(job_id, 0) + 1
        if self.obs is not None:
            self.obs.emit(K_LATE, f0=rho_v, f1=float(thresh), f2=est_v,
                          obj=victim.task_id)
        return SpeculateTask(task_id=victim.task_id, reason="late")

    def job_done(self, job_id: str) -> None:
        self._last_launch.pop(job_id, None)
        self._spec_count.pop(job_id, None)

    # --- vectorized path (columnar snapshots, DESIGN.md §11/§13) ------
    def _assess_arrays(self, snap: ClusterSnapshot, arr) -> List[Action]:
        now = snap.now
        actions: List[Action] = [
            KillAttempt(arr.attempt_ids[r], "sibling completed")
            for r in self.backend.reap_rows(arr, now)]
        active = arr.active_jobs()
        if not active:
            return actions
        # Serial-speculation and cap gates are host policy state; jobs
        # failing them need no ranking work (and assessment is pure, so
        # backends may rank every job regardless — results are dropped).
        eligible = np.zeros(len(active), dtype=bool)
        for pos, (jid, jidx) in enumerate(active):
            if now - self._last_launch.get(jid, -1e18) \
                    < self.cfg.launch_delay:
                continue  # serial speculation with fixed delay
            n_total = arr.job_task_count(jidx)
            if self._spec_count.get(jid, 0) >= max(
                    1, int(self.cfg.speculative_cap * n_total)):
                continue
            eligible[pos] = True
        if eligible.any():
            victims = self.backend.late_victims(
                arr, now, active, eligible, self.cfg.min_runtime,
                self.cfg.slow_task_percentile)
            for pos, (jid, _jidx) in enumerate(active):
                if not eligible[pos] or victims[pos] < 0:
                    continue
                self._last_launch[jid] = now
                self._spec_count[jid] = self._spec_count.get(jid, 0) + 1
                if self.obs is not None:
                    # Vectorized path: ρ/threshold stay in the backend;
                    # the record pins victim + time only (§18.2 waiver).
                    self.obs.emit(K_LATE, obj=arr.task_ids[victims[pos]])
                actions.append(SpeculateTask(
                    task_id=arr.task_ids[victims[pos]], reason="late"))
        return actions


# ---------------------------------------------------------------------------
# Cross-job policies under a cluster-wide speculation budget (ISSUE 9;
# Xu & Lau, "Optimization for Speculative Execution of Multiple Jobs in
# a MapReduce-like Cluster" and "Task-Cloning Algorithms with
# Competitive Performance Bounds" — PAPERS.md). Both meter backup
# launches *across* jobs instead of per-job: the budget bounds the
# number of concurrently RUNNING speculative copies cluster-wide.
# ---------------------------------------------------------------------------
class SpeculationBudget:
    """Cluster-wide speculative-slot meter.

    Accounting contract (DESIGN.md §19.3): at the start of each
    assessment tick ``begin_tick`` re-bases occupancy on the number of
    speculative copies actually RUNNING; ``admit`` then charges this
    tick's launches against the remaining headroom. Copies admitted but
    still queued at the dispatcher (cluster momentarily full) are not
    double-counted — the budget bounds *running* copies plus one tick's
    admissions, not queue depth; the dispatcher's per-task
    ``has_queued`` guard keeps re-proposals of a queued task out.
    """

    def __init__(self, capacity: int):
        self.capacity = max(0, int(capacity))
        self.in_use = 0
        # Lifetime counters (scorecards / benchmarks).
        self.admitted = 0
        self.denied = 0

    def begin_tick(self, running_spec: int) -> None:
        self.in_use = int(running_spec)

    def admit(self, cost: int = 1) -> bool:
        if self.in_use + cost > self.capacity:
            self.denied += 1
            return False
        self.in_use += cost
        self.admitted += 1
        return True

    @property
    def available(self) -> int:
        return max(0, self.capacity - self.in_use)


def _count_running_spec(snap: ClusterSnapshot) -> int:
    """Budget occupancy: RUNNING speculative attempts across every
    active job (columnar when available; the reference walk matches it
    attempt-for-attempt)."""
    arr = getattr(snap, "arrays", None)
    if arr is not None:
        return arr.n_running_spec()
    n = 0
    for t in snap.tasks.values():
        for a in t.attempts:
            if a.state == AttemptState.RUNNING and a.is_speculative:
                n += 1
    return n


@dataclasses.dataclass(frozen=True)
class BudgetConfig:
    # Budget = max(min_budget, fraction × total container slots).
    budget_fraction: float = 0.05
    min_budget: int = 2
    # The inner detector runs un-throttled (no per-job serial delay, no
    # per-job cap) — throttling is the *global* budget's job.
    late: LateConfig = LateConfig(launch_delay=0.0, speculative_cap=1.0)


class BudgetedSpeculator(Speculator):
    """Cross-job speculation with global admission (Xu & Lau).

    An un-throttled LATE detector proposes per-job straggler candidates;
    a cluster-level admission pass ranks them by estimated remaining
    work (largest first — the copies that buy the most completion-time)
    and admits greedily while the cluster-wide budget of speculative
    copies lasts. Kill/reap actions pass through unmetered.
    """

    def __init__(self, total_slots: int = 160,
                 cfg: BudgetConfig = BudgetConfig(),
                 assess_backend: "Optional[str | AssessmentBackend]" = None,
                 budget: Optional[SpeculationBudget] = None):
        self.cfg = cfg
        self.inner = YarnLateSpeculator(cfg.late,
                                        assess_backend=assess_backend)
        self.budget = budget if budget is not None else SpeculationBudget(
            max(cfg.min_budget,
                int(cfg.budget_fraction * total_slots)))

    # The flight recorder threads through the inner detector (its K_LATE
    # records carry the ranking inputs); the wrapper shares it.
    @property
    def obs(self):
        return self.inner.obs

    @obs.setter
    def obs(self, rec) -> None:
        self.inner.obs = rec

    def _est_remaining(self, snap: ClusterSnapshot, task_id: str) -> float:
        t = snap.tasks.get(task_id)
        if t is None:
            return 0.0
        run = [a for a in t.attempts if a.state == AttemptState.RUNNING]
        if not run:
            return 0.0
        a = max(run, key=lambda a: a.progress)
        rho = a.progress_rate(snap.now)
        return (1.0 - a.progress) / max(rho, 1e-9)

    def assess(self, snap: ClusterSnapshot) -> List[Action]:
        actions = self.inner.assess(snap)
        keep: List[Action] = [a for a in actions
                              if not isinstance(a, SpeculateTask)]
        cands = [a for a in actions if isinstance(a, SpeculateTask)]
        self.budget.begin_tick(_count_running_spec(snap))
        admitted = 0
        if cands:
            # Benefit-greedy: longest estimated remaining work first
            # (stable — ties keep per-job submission order).
            ranked = sorted(
                cands,
                key=lambda a: -self._est_remaining(snap, a.task_id))
            for act in ranked:
                if self.budget.admit():
                    admitted += 1
                    keep.append(dataclasses.replace(
                        act, reason="budgeted"))
        if cands and self.obs is not None:
            self.obs.emit(K_BUDGET, a=self.budget.in_use,
                          b=self.budget.capacity, f0=float(len(cands)),
                          f1=float(admitted),
                          f2=float(len(cands) - admitted))
        return keep

    def job_done(self, job_id: str) -> None:
        self.inner.job_done(job_id)


@dataclasses.dataclass(frozen=True)
class CloneConfig:
    # Jobs with at most this many tasks are cloned upfront; bigger jobs
    # fall back to LATE detection (Xu & Lau's small-job regime — the
    # PACMan mix is 85 % such jobs).
    small_job_tasks: int = 12
    budget_fraction: float = 0.15
    min_budget: int = 4
    late: LateConfig = LateConfig()


class CloneSmallJobs(Speculator):
    """Upfront task cloning for small jobs (Xu & Lau).

    Every task of a small job gets one clone as soon as its first
    attempt runs — straggler *avoidance* rather than detection — metered
    by the cluster-wide budget; large jobs keep LATE detection (whose
    candidates for small jobs are dropped: the clone already covers
    them). The sibling-completion reap kills whichever copy loses.
    """

    def __init__(self, total_slots: int = 160,
                 cfg: CloneConfig = CloneConfig(),
                 assess_backend: "Optional[str | AssessmentBackend]" = None,
                 budget: Optional[SpeculationBudget] = None):
        self.cfg = cfg
        self.inner = YarnLateSpeculator(cfg.late,
                                        assess_backend=assess_backend)
        self.budget = budget if budget is not None else SpeculationBudget(
            max(cfg.min_budget,
                int(cfg.budget_fraction * total_slots)))
        self._cloned: Set[str] = set()  # task_ids already offered a clone

    @property
    def obs(self):
        return self.inner.obs

    @obs.setter
    def obs(self, rec) -> None:
        self.inner.obs = rec

    def _small_jobs(self, snap: ClusterSnapshot) -> Set[str]:
        arr = getattr(snap, "arrays", None)
        thr = self.cfg.small_job_tasks
        if arr is not None:
            return {jid for jid, jidx in arr.active_jobs()
                    if arr.job_task_count(jidx) <= thr}
        counts: Dict[str, int] = {}
        for t in snap.tasks.values():
            counts[t.job_id] = counts.get(t.job_id, 0) + 1
        return {jid for jid, c in counts.items() if c <= thr}

    def _clone_candidates(self, snap: ClusterSnapshot,
                          small: Set[str]) -> List[str]:
        """Uncloned small-job tasks with a running attempt and no
        running speculative sibling, in canonical task order."""
        arr = getattr(snap, "arrays", None)
        out: List[str] = []
        if arr is not None:
            rows = arr.running_rows(snap.now)
            if not len(rows):
                return out
            jobmask = np.zeros(len(arr.job_ids), dtype=bool)
            for jid in small:
                jobmask[arr.job_index[jid]] = True
            srows = rows[jobmask[arr.job[rows]]]
            if not len(srows):
                return out
            torder = arr.skey[srows] >> 20
            starts, inv = arr.task_segments(torder)
            has_spec = np.bincount(inv, weights=arr.spec[srows],
                                   minlength=len(starts)) > 0
            for pos, r in enumerate(srows[starts]):
                if has_spec[pos]:
                    continue
                tid = arr.task_ids[r]
                if tid not in self._cloned:
                    out.append(tid)
            return out
        for t in snap.tasks.values():
            if t.job_id not in small or t.state != TaskState.RUNNING:
                continue
            if t.task_id in self._cloned or t.has_speculative_running():
                continue
            if any(a.state == AttemptState.RUNNING for a in t.attempts):
                out.append(t.task_id)
        return out

    def assess(self, snap: ClusterSnapshot) -> List[Action]:
        actions = self.inner.assess(snap)
        small = self._small_jobs(snap)
        keep: List[Action] = []
        for a in actions:
            if isinstance(a, SpeculateTask):
                tv = snap.tasks.get(a.task_id)
                if tv is not None and tv.job_id in small:
                    continue  # the upfront clone covers this task
            keep.append(a)
        self.budget.begin_tick(_count_running_spec(snap))
        cands = self._clone_candidates(snap, small)
        admitted = 0
        for task_id in cands:
            if not self.budget.admit():
                break
            self._cloned.add(task_id)
            admitted += 1
            keep.append(SpeculateTask(task_id=task_id, reason="clone"))
        if cands and self.obs is not None:
            self.obs.emit(K_BUDGET, a=self.budget.in_use,
                          b=self.budget.capacity, f0=float(len(cands)),
                          f1=float(admitted),
                          f2=float(len(cands) - admitted))
        return keep

    def job_done(self, job_id: str) -> None:
        self.inner.job_done(job_id)
        prefix = job_id + "_"
        self._cloned = {t for t in self._cloned
                        if not t.startswith(prefix)}


# ---------------------------------------------------------------------------
# Binocular speculation
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class _StragglerTask:
    """The slice of the TaskView protocol the collective planner reads —
    lets the columnar path hand it stragglers without building views."""

    task_id: str
    job_id: str
    _has_spec: bool = False

    def has_speculative_running(self) -> bool:
        return self._has_spec


@dataclasses.dataclass(frozen=True)
class BinoConfig:
    glance: GlanceConfig = dataclasses.field(default_factory=GlanceConfig)
    collective: CollectiveConfig = dataclasses.field(
        default_factory=CollectiveConfig)
    dependency: DependencyConfig = dataclasses.field(
        default_factory=DependencyConfig)
    rollback_enabled: bool = True


class BinocularSpeculator(Speculator):
    def __init__(self, node_ids: Sequence[str],
                 cfg: BinoConfig = BinoConfig(),
                 topology: Optional[Dict[str, Sequence[str]]] = None,
                 assess_backend: "Optional[str | AssessmentBackend]" = None):
        self.cfg = cfg
        # One backend instance serves glance + collective (it memoizes the
        # per-tick extraction / device upload across both).
        self.backend = get_backend(
            assess_backend if assess_backend is not None
            else cfg.glance.assess_backend)
        self.glance = NeighborhoodGlance(node_ids, cfg.glance, topology,
                                         backend=self.backend)
        self.collective = CollectiveSpeculation(cfg.collective,
                                                backend=self.backend)
        self.dependency = DependencyTracker(cfg.dependency)
        self.rollback = RollbackRegistry()
        # Nodes currently assessed unhealthy (slow or failed).
        self._unhealthy: Set[str] = set()

    # ------------------------------------------------------------------
    def assess(self, snap: ClusterSnapshot) -> List[Action]:
        actions: List[Action] = []

        # 1. Neighborhood glance: spatial + temporal + failure assessments.
        verdict = self.glance.assess(snap)
        failed = set(verdict.failed_nodes)
        for nid in failed:
            actions.append(MarkNodeFailed(nid, reason="glance:eq4"))
            self.rollback.drop_node(nid)
        slow_by_node: Dict[str, str] = {}
        for _job, node, reason in verdict.slow_nodes:
            slow_by_node.setdefault(node, reason)
        self._unhealthy = failed | set(slow_by_node)

        # 2. Dependency awareness: completed producers on dead nodes, and
        #    fetch-failure streaks, trigger producer re-execution.
        dep_actions = self.dependency.on_node_failed(snap, failed)
        dep_actions += self.dependency.on_fetch_failures(
            snap, snap.fetch_failures)

        # 3. Straggler set: running tasks on slow/failed nodes.
        arr = getattr(snap, "arrays", None)
        if arr is not None:
            stragglers = self._stragglers_arrays(
                snap, arr, failed, slow_by_node)
        else:
            stragglers = self._stragglers_reference(
                snap, failed, slow_by_node)

        # 4. Collective ramp over the straggler wave, neighborhood-first.
        nh = {n: self.glance.neighbors_of(n) for n in
              {v for _, v, _ in stragglers if v is not None}}
        launches = self.collective.plan(snap, stragglers, nh)

        # Dependency re-executions bypass the ramp: they gate job progress
        # (a reducer is already blocked on the lost output).
        launches = list(dep_actions) + launches

        # 5. Rollback: race a resume-from-log attempt where the log's node
        #    is healthy.
        if self.cfg.rollback_enabled:
            launches = plan_rollback(snap, self.rollback, launches,
                                     self._unhealthy)
        actions.extend(launches)

        # 6. Reap siblings of completed attempts.
        actions.extend(self.collective.reap_completed(snap))
        return actions

    # ------------------------------------------------------------------
    # Straggler extraction: first running attempt of a RUNNING task that
    # sits on a slow/failed node decides the task's victim node + reason.
    # ------------------------------------------------------------------
    def _stragglers_reference(
        self, snap: ClusterSnapshot, failed: Set[str],
        slow_by_node: Dict[str, str],
    ) -> List[Tuple[TaskView, Optional[str], str]]:
        stragglers: List[Tuple[TaskView, Optional[str], str]] = []
        seen: Set[str] = set()
        for t in snap.tasks.values():
            if t.state != TaskState.RUNNING:
                continue
            for a in t.running_attempts():
                if t.task_id in seen:
                    break
                if a.node_id in failed:
                    stragglers.append((t, a.node_id, "glance:failure"))
                    seen.add(t.task_id)
                elif a.node_id in slow_by_node:
                    stragglers.append(
                        (t, a.node_id,
                         "glance:" + slow_by_node[a.node_id]))
                    seen.add(t.task_id)
        return stragglers

    def _stragglers_arrays(
        self, snap: ClusterSnapshot, arr, failed: Set[str],
        slow_by_node: Dict[str, str],
    ) -> List[Tuple["_StragglerTask", Optional[str], str]]:
        """Columnar straggler extraction. On a healthy tick (no slow or
        failed nodes — the common case) this is a no-op; otherwise the
        first-bad-attempt-per-task pick and the speculative-sibling check
        are segmented reductions, and the collective planner receives
        lightweight task shims instead of materialized TaskViews."""
        from repro.core.arrays import A_RUNNING, T_RUNNING
        bad = failed | set(slow_by_node)
        if not bad:
            return []
        nodemask = np.zeros(len(arr.node_ids), dtype=bool)
        for nid in bad:
            nodemask[arr.node_index[nid]] = True
        rows = arr.running_rows(snap.now)  # all running attempts, canonical
        if not len(rows):
            return []
        on_bad = nodemask[arr.node[rows]]
        brows = rows[on_bad]
        if not len(brows):
            return []
        # Victim attempt = first bad-node running attempt per task in
        # canonical order — exactly the reference scan's pick. Rows are
        # sorted by task, so segment starts are the per-task firsts,
        # already in task order.
        torder = arr.skey[rows] >> 20
        btorder = torder[on_bad]
        bstarts, _binv = arr.task_segments(btorder)
        vrows = brows[bstarts]
        # has_speculative_running per straggler task, over ALL of the
        # task's running attempts (not just the bad-node ones).
        starts, inv = arr.task_segments(torder)
        has_spec = np.bincount(inv, weights=arr.spec[rows],
                               minlength=len(starts)) > 0
        vspec = has_spec[np.searchsorted(torder[starts], btorder[bstarts])]
        stragglers: List[Tuple[_StragglerTask, Optional[str], str]] = []
        for r, hs in zip(vrows, vspec):
            nid = arr.node_ids[arr.node[r]]
            if nid in failed:
                reason = "glance:failure"
            else:
                reason = "glance:" + slow_by_node[nid]
            stragglers.append((_StragglerTask(
                arr.task_ids[r], arr.job_ids[arr.job[r]], bool(hs)),
                nid, reason))
        return stragglers

    # ------------------------------------------------------------------
    # Substrate hooks
    # ------------------------------------------------------------------
    def record_progress_log(self, log) -> None:
        self.rollback.record(log)

    def note_fetch_ok(self, producer_task_id: str) -> None:
        self.dependency.note_fetch_ok(producer_task_id)

    def job_done(self, job_id: str) -> None:
        self.collective.job_done(job_id)

    @property
    def unhealthy_nodes(self) -> Set[str]:
        return set(self._unhealthy)
