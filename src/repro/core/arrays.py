"""Columnar (struct-of-arrays) cluster snapshots — the vectorized
assessment hot path (DESIGN.md §11).

The per-object ``ClusterSnapshot`` rebuilds every ``TaskView``/``AttemptView``
dataclass on each speculator tick: O(tasks × attempts) allocation and
interpretation per assessment, which caps the simulator near the paper's
21-node testbed. ``ArraySnapshot`` instead keeps one numpy column per
attempt attribute, maintained *incrementally* by the substrate on attempt
start/progress/finish events, so an assessment tick is a handful of
vectorized reductions regardless of cluster size.

Equivalence contract (DESIGN.md §11.3): every query here replicates the
reference per-object arithmetic **operation for operation** — same clip
constants, same operand order, same accumulation order (see
:meth:`order`) — so the vectorized policies emit bit-identical action
sequences. ``tests/test_columnar.py`` enforces this on seeded runs.

Row lifecycle: one row per execution attempt, append-only; rows of
completed jobs are deactivated and physically dropped by opportunistic
compaction (stress workloads submit hundreds of jobs). Substrate objects
that own a row expose a writable ``row`` attribute which compaction
re-targets.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.types import AttemptState, TaskKind, TaskState

__all__ = [
    "ArraySnapshot",
    "DeviceColumns",
    "SHUFFLE_FRACTION",
    "ASTATE",
    "TSTATE",
    "KIND",
]

# Reduce ProgressScore split: 1/3 shuffle, 2/3 sort+reduce (YARN's phases).
# Single source of truth — the simulator imports this constant.
SHUFFLE_FRACTION = 1.0 / 3.0

# Compact integer codes for the enum columns.
ASTATE = {
    AttemptState.RUNNING: 0,
    AttemptState.COMPLETED: 1,
    AttemptState.FAILED: 2,
    AttemptState.KILLED: 3,
}
TSTATE = {
    TaskState.PENDING: 0,
    TaskState.RUNNING: 1,
    TaskState.COMPLETED: 2,
    TaskState.FAILED: 3,
}
KIND = {TaskKind.MAP: 0, TaskKind.REDUCE: 1}

A_RUNNING = ASTATE[AttemptState.RUNNING]
A_COMPLETED = ASTATE[AttemptState.COMPLETED]
T_RUNNING = TSTATE[TaskState.RUNNING]
T_COMPLETED = TSTATE[TaskState.COMPLETED]

# Attempts-per-task fits comfortably below this; the canonical sort key is
# ``task_order * _KEY_STRIDE + attempt_seq``.
_KEY_STRIDE = 1 << 20

_INIT_CAP = 256


class ArraySnapshot:
    """Incrementally-maintained numpy columns over attempts and nodes."""

    def __init__(self, node_ids, n_containers: int = 8):
        self.node_ids: List[str] = list(node_ids)
        self.node_index: Dict[str, int] = {
            n: i for i, n in enumerate(self.node_ids)}
        n = len(self.node_ids)
        # --- node columns -------------------------------------------------
        self.node_hb = np.zeros(n)
        self.node_speed = np.ones(n)
        self.node_free = np.full(n, n_containers, dtype=np.int32)
        self.node_total = np.full(n, n_containers, dtype=np.int32)
        self.node_marked = np.zeros(n, dtype=bool)
        # Liveness + heartbeat-suppression mirrors: the per-second RM
        # tick is one vectorized mask over these instead of a python
        # loop over every SimNode (DESIGN.md §17.5).
        self.node_alive = np.ones(n, dtype=bool)
        self.node_supp = np.zeros(n)
        # --- network columns (DESIGN.md §15) -----------------------------
        # Active shuffle flows per node, link liveness, rack membership
        # and per-rack uplink flow/degradation state. ``init_net`` aliases
        # these to the network model's own arrays, so the model's single
        # write-through store serves both (verified against a recount by
        # ``Simulation.verify_network``). The placeholders below keep
        # standalone snapshots (tests, sweeps) self-contained.
        self.node_flows = np.zeros(n, dtype=np.int32)
        self.node_link_up = np.ones(n, dtype=bool)
        self.node_rack = np.zeros(n, dtype=np.int32)
        self.rack_flows = np.zeros(1, dtype=np.int32)
        self.rack_factor = np.ones(1)
        # --- job registry -------------------------------------------------
        self.job_index: Dict[str, int] = {}
        self.job_ids: List[str] = []
        self._job_active: List[bool] = []
        self._job_tasks: List[int] = []
        # --- attempt columns ----------------------------------------------
        self.n = 0
        cap = _INIT_CAP
        self.a_state = np.zeros(cap, dtype=np.int8)
        self.t_state = np.zeros(cap, dtype=np.int8)
        self.kind = np.zeros(cap, dtype=np.int8)
        self.job = np.zeros(cap, dtype=np.int32)
        self.node = np.zeros(cap, dtype=np.int32)
        self.spec = np.zeros(cap, dtype=bool)
        self.start = np.zeros(cap)
        self.work_done = np.zeros(cap)
        self.work_total = np.ones(cap)
        self.last_sync = np.zeros(cap)
        self.fetched = np.zeros(cap, dtype=np.int32)
        self.deps = np.ones(cap, dtype=np.int32)
        self.compute = np.zeros(cap, dtype=bool)
        self.active = np.zeros(cap, dtype=bool)
        self.skey = np.zeros(cap, dtype=np.int64)
        # Shuffle-health columns (reduce attempts; write-through from the
        # shuffle engine): producers ready-but-unfetched, transfers in
        # flight, failure cycles burning. Together with ``fetched`` they
        # partition the not-yet-waiting dependencies, keeping fetch-health
        # signals vectorized for the policies.
        self.sh_ready = np.zeros(cap, dtype=np.int32)
        self.sh_inflight = np.zeros(cap, dtype=np.int32)
        self.sh_fail = np.zeros(cap, dtype=np.int32)
        self._float_cols = ["start", "work_done", "work_total", "last_sync"]
        self._int_like_cols = ["a_state", "t_state", "kind", "job", "node",
                               "spec", "fetched", "deps", "compute",
                               "active", "skey", "sh_ready", "sh_inflight",
                               "sh_fail"]
        # Parallel python rails (action emission needs the id strings).
        self.attempt_ids: List[str] = []
        self.task_ids: List[str] = []
        self._owners: List[object] = []
        # Policy scratch columns: name -> (array, fill value). Compaction
        # and growth preserve them so stateful assessments (temporal marks)
        # survive row movement.
        self._scratch: Dict[str, Tuple[np.ndarray, object]] = {}
        self._order: Optional[np.ndarray] = None
        self._n_dead = 0
        # Per-tick memo for the shared running-rows extraction (glance and
        # the straggler scan both need it within one assess call).
        self._rr_memo: Tuple[float, Optional[np.ndarray]] = (np.nan, None)

    # ------------------------------------------------------------------
    # Network wiring (DESIGN.md §15)
    # ------------------------------------------------------------------
    def init_net(self, net) -> None:
        """Share storage with the network model's columnar state: the
        model's open/close/cut/degrade write-through lands directly in
        the snapshot (one store, no second mirror to drift)."""
        self.node_flows = net.node_flows
        self.node_link_up = net.node_link_up
        self.node_rack = net.node_rack
        self.rack_flows = net.rack_flows
        self.rack_factor = net.rack_factor

    # ------------------------------------------------------------------
    # Job registry
    # ------------------------------------------------------------------
    def job_started(self, job_id: str) -> int:
        idx = self.job_index.get(job_id)
        if idx is None:
            idx = len(self.job_ids)
            self.job_index[job_id] = idx
            self.job_ids.append(job_id)
            self._job_active.append(True)
            self._job_tasks.append(0)
        else:
            self._job_active[idx] = True
        return idx

    def task_created(self, job_idx: int) -> None:
        self._job_tasks[job_idx] += 1

    def job_task_count(self, job_idx: int) -> int:
        return self._job_tasks[job_idx]

    def job_finished(self, job_id: str) -> None:
        idx = self.job_index.get(job_id)
        if idx is None:
            return
        self._job_active[idx] = False
        dead = self.job[:self.n] == idx
        self.active[:self.n][dead] = False
        self._n_dead += int(dead.sum())
        if self._n_dead > 4096 and self._n_dead * 2 > self.n:
            self._compact()

    def active_jobs(self) -> List[Tuple[str, int]]:
        """Active jobs in registration order — exactly the iteration order
        of the reference snapshot's ``job_ids()``."""
        return [(j, i) for i, j in enumerate(self.job_ids)
                if self._job_active[i]]

    # ------------------------------------------------------------------
    # Row maintenance (substrate write-through)
    # ------------------------------------------------------------------
    def _cols(self):
        for name in self._float_cols + self._int_like_cols:
            yield name, getattr(self, name)

    def _grow(self) -> None:
        cap = max(_INIT_CAP, 2 * len(self.a_state))
        for name, col in list(self._cols()):
            new = np.zeros(cap, dtype=col.dtype)
            new[:self.n] = col[:self.n]
            if name in ("work_total", "deps"):
                new[self.n:] = 1  # avoid div-by-zero on unwritten rows
            setattr(self, name, new)
        for name, (col, fill) in list(self._scratch.items()):
            new = np.full(cap, fill, dtype=col.dtype)
            new[:self.n] = col[:self.n]
            self._scratch[name] = (new, fill)

    def add_attempt(self, owner: object, attempt_id: str, task_id: str,
                    task_order: int, attempt_seq: int, job_idx: int,
                    node_idx: int, kind: TaskKind, is_speculative: bool,
                    start_time: float, work_done: float, work_total: float,
                    n_deps: int, task_state: TaskState) -> int:
        if self.n >= len(self.a_state):
            self._grow()
        r = self.n
        self.n += 1
        self.a_state[r] = A_RUNNING
        self.t_state[r] = TSTATE[task_state]
        self.kind[r] = KIND[kind]
        self.job[r] = job_idx
        self.node[r] = node_idx
        self.spec[r] = is_speculative
        self.start[r] = start_time
        self.work_done[r] = work_done
        self.work_total[r] = work_total
        self.last_sync[r] = start_time
        self.fetched[r] = 0
        self.sh_ready[r] = 0
        self.sh_inflight[r] = 0
        self.sh_fail[r] = 0
        self.deps[r] = max(1, n_deps)
        self.compute[r] = False
        self.active[r] = True
        self.skey[r] = task_order * _KEY_STRIDE + attempt_seq
        self.attempt_ids.append(attempt_id)
        self.task_ids.append(task_id)
        self._owners.append(owner)
        for col, fill in self._scratch.values():
            col[r] = fill
        self._order = None
        return r

    def sync_row(self, row: int, work_done: float, last_sync: float) -> None:
        self.work_done[row] = work_done
        self.last_sync[row] = last_sync

    def set_attempt_state(self, row: int, state: AttemptState) -> None:
        self.a_state[row] = ASTATE[state]

    def set_task_state(self, rows, state: TaskState) -> None:
        code = TSTATE[state]
        for r in rows:
            self.t_state[r] = code

    def write_shuffle_rows(self, rows, fetched, ready, inflight,
                           fail) -> None:
        """Bulk shuffle-health write-through: one fancy-indexed store per
        column for a whole drain's worth of fetch-state transitions
        (DESIGN.md §14.2), instead of four scalar writes per transition.
        ``rows`` are live row indices; the value lists are parallel."""
        idx = np.asarray(rows, dtype=np.int64)
        self.fetched[idx] = fetched
        self.sh_ready[idx] = ready
        self.sh_inflight[idx] = inflight
        self.sh_fail[idx] = fail

    def _compact(self) -> None:
        keep = np.flatnonzero(self.active[:self.n])
        for _, col in self._cols():
            col[:len(keep)] = col[keep]
        for col, _fill in self._scratch.values():
            col[:len(keep)] = col[keep]
        self.attempt_ids = [self.attempt_ids[i] for i in keep]
        self.task_ids = [self.task_ids[i] for i in keep]
        self._owners = [self._owners[i] for i in keep]
        for new_r, owner in enumerate(self._owners):
            owner.row = new_r
        self.n = len(keep)
        self._n_dead = 0
        self._order = None

    # ------------------------------------------------------------------
    # Policy scratch columns
    # ------------------------------------------------------------------
    def scratch(self, name: str, dtype, fill) -> np.ndarray:
        ent = self._scratch.get(name)
        if ent is None:
            col = np.full(len(self.a_state), fill, dtype=dtype)
            self._scratch[name] = (col, fill)
            return col
        return ent[0]

    # ------------------------------------------------------------------
    # Queries (all emit rows in canonical reference order)
    # ------------------------------------------------------------------
    def order(self) -> np.ndarray:
        """Live rows sorted by (task creation order, attempt seq) — the
        exact iteration order of the reference snapshot (active jobs in
        submission order → each job's maps then reduces → each task's
        attempts in creation order). Segmented reductions over rows in
        this order accumulate partial sums identically to the per-object
        loops, which is what makes strict-inequality assessments (Eq. 1/3,
        LATE percentiles) bit-equivalent."""
        if self._order is None:
            self._order = np.argsort(self.skey[:self.n], kind="stable")
        return self._order

    def rows_where(self, mask: np.ndarray) -> np.ndarray:
        """Canonical-order row indices of live rows satisfying ``mask``
        (a boolean array over ``[:n]``)."""
        o = self.order()
        return o[mask[o]]

    def progress_at(self, now: float, rows: np.ndarray) -> np.ndarray:
        """ProgressScore ζ for each row, replicating
        ``SimAttempt.progress`` operation-for-operation: frozen for ended
        attempts, linear accrual at the hosting node's current speed for
        running ones, shuffle/compute split for reduces."""
        accrue = (self.a_state[rows] == A_RUNNING) \
            & ((self.kind[rows] == 0) | self.compute[rows])
        wd = self.work_done[rows] + accrue * (
            (now - self.last_sync[rows]) * self.node_speed[self.node[rows]])
        np.minimum(wd, self.work_total[rows], out=wd)
        comp = wd / self.work_total[rows]
        shuffle = self.fetched[rows] / self.deps[rows]
        return np.where(
            self.kind[rows] == 0, comp,
            SHUFFLE_FRACTION * shuffle + (1 - SHUFFLE_FRACTION) * comp)

    def running_rows(self, now: Optional[float] = None) -> np.ndarray:
        """Attempt RUNNING ∧ task RUNNING ∧ job active — the candidate set
        shared by the Eq. 1/2–3 assessments and the straggler scan. With
        ``now`` given, memoized for the duration of one assessment tick
        (the substrate never mutates state mid-assess, and consecutive
        ticks have distinct timestamps)."""
        if now is not None and self._rr_memo[0] == now:
            return self._rr_memo[1]
        m = self.active[:self.n] & (self.a_state[:self.n] == A_RUNNING) \
            & (self.t_state[:self.n] == T_RUNNING)
        rows = self.rows_where(m)
        if now is not None:
            self._rr_memo = (now, rows)
        return rows

    @staticmethod
    def task_segments(torder: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(starts, inverse) for a NONDECREASING per-row task-order array —
        what ``np.unique(..., return_index/inverse)`` yields on sorted
        input, without its O(k log k) sort. Unique task orders are
        ``torder[starts]``."""
        k = len(torder)
        if not k:
            z = np.empty(0, dtype=np.int64)
            return z, z
        new = np.empty(k, dtype=bool)
        new[0] = True
        np.not_equal(torder[1:], torder[:-1], out=new[1:])
        starts = np.flatnonzero(new)
        inv = np.cumsum(new) - 1
        return starts, inv

    def reap_rows(self) -> np.ndarray:
        """Running attempts of COMPLETED tasks that have a COMPLETED
        sibling — the candidates both policies kill each tick. Tasks whose
        state was re-activated (RUNNING again) are excluded by the
        ``t_state`` check, matching the reference guard."""
        live = self.active[:self.n] & (self.t_state[:self.n] == T_COMPLETED)
        if not live.any():
            return np.empty(0, dtype=np.int64)
        rows = self.rows_where(live)
        starts, inv = self.task_segments(self.skey[rows] // _KEY_STRIDE)
        done = np.bincount(
            inv, weights=self.a_state[rows] == A_COMPLETED,
            minlength=len(starts)) > 0
        victims = done[inv] & (self.a_state[rows] == A_RUNNING)
        return rows[victims]

    def idle_task_rows(self) -> np.ndarray:
        """Canonical-order rows of the *first* attempt of each task whose
        task-state is RUNNING while no attempt row is — the AM
        watchdog's re-enqueue candidates (same segment idiom as
        :meth:`reap_rows`; a RUNNING task always has at least one row,
        since PENDING→RUNNING happens at first attempt start and
        COMPLETED→RUNNING re-activation implies prior attempts)."""
        live = self.active[:self.n] & (self.t_state[:self.n] == T_RUNNING)
        if not live.any():
            return np.empty(0, dtype=np.int64)
        rows = self.rows_where(live)
        starts, inv = self.task_segments(self.skey[rows] // _KEY_STRIDE)
        has_running = np.bincount(
            inv, weights=self.a_state[rows] == A_RUNNING,
            minlength=len(starts)) > 0
        return rows[starts[~has_running]]

    def n_running_spec(self) -> int:
        """RUNNING speculative attempts of active jobs — the cluster-wide
        speculation-budget occupancy (DESIGN.md §19.3). Mirrors the
        reference walk over every active job's attempts exactly: task
        state does not gate it (a completed task's still-running backup
        occupies its slot until reaped)."""
        m = self.active[:self.n] & self.spec[:self.n] \
            & (self.a_state[:self.n] == A_RUNNING)
        return int(m.sum())

    def owner(self, row: int) -> object:
        """The substrate object (attempt) that owns ``row``."""
        return self._owners[row]

    def job_local_map(self, active: List[Tuple[str, int]]) -> np.ndarray:
        """job_idx → position in the active job list (-1 if inactive)."""
        local = np.full(len(self.job_ids), -1, dtype=np.int64)
        for pos, (_jid, jidx) in enumerate(active):
            local[jidx] = pos
        return local

    def clone_for_assessment(self) -> "ArraySnapshot":
        """Deep-copy the columns and registries (NOT the substrate owners)
        so a fault-scenario sweep can perturb node/attempt state without
        touching the live simulation (DESIGN.md §13.4)."""
        c = ArraySnapshot.__new__(ArraySnapshot)
        c.node_ids = list(self.node_ids)
        c.node_index = dict(self.node_index)
        for name in ("node_hb", "node_speed", "node_free", "node_total",
                     "node_marked", "node_alive", "node_supp",
                     "node_flows", "node_link_up",
                     "node_rack", "rack_flows", "rack_factor"):
            # .copy() detaches the net-aliased columns: scenario sweeps
            # may perturb rack/flow state without touching the live model
            setattr(c, name, getattr(self, name).copy())
        c.job_index = dict(self.job_index)
        c.job_ids = list(self.job_ids)
        c._job_active = list(self._job_active)
        c._job_tasks = list(self._job_tasks)
        c.n = self.n
        c._float_cols = list(self._float_cols)
        c._int_like_cols = list(self._int_like_cols)
        for name in c._float_cols + c._int_like_cols:
            setattr(c, name, getattr(self, name).copy())
        c.attempt_ids = list(self.attempt_ids)
        c.task_ids = list(self.task_ids)
        c._owners = [None] * len(self._owners)
        c._scratch = {name: (col.copy(), fill)
                      for name, (col, fill) in self._scratch.items()}
        c._order = None if self._order is None else self._order.copy()
        c._n_dead = self._n_dead
        c._rr_memo = (np.nan, None)
        # Drift guard: a field added to __init__ but not cloned here
        # would leak live state into (or crash) the scenario sweep.
        assert set(c.__dict__) == set(self.__dict__), \
            set(self.__dict__) ^ set(c.__dict__)
        return c


# ---------------------------------------------------------------------------
# Padded device mirrors (DESIGN.md §13.2)
# ---------------------------------------------------------------------------
class DeviceColumns:
    """Padded, fixed-shape host mirrors of an :class:`ArraySnapshot` for
    jit/Pallas assessment kernels (DESIGN.md §13.2).

    Device kernels need static shapes or every tick retraces. This
    exporter keeps one pre-padded buffer per attempt column:

    - row capacity is a power of two (min :data:`MIN_ROWS`), grown by
      doubling and **never shrunk** — a jit specialization is re-used
      until the simulation genuinely outgrows it;
    - pad rows (and rows vacated by compaction) hold neutral fills:
      zeros, except ``work_total``/``deps`` = 1 so unmasked elementwise
      math (the ζ progress projection divides by both) stays finite —
      kernels must still mask with ``position < n_rows`` before any
      reduction;
    - the canonical row order (:meth:`ArraySnapshot.order`) is exported
      zero-padded, so device segmented reductions visit live rows in
      exactly the reference accumulation order (§11.3);
    - the job axis is padded the same way (``jobs_cap`` for the
      job-registry axis, ``jcap`` for the active-job output axis).

    ``refresh`` returns plain numpy arrays; the caller owns the
    host→device transfer (keeping this module import-light).
    """

    MIN_ROWS = 256
    MIN_JOBS = 4

    # Columns exported per attempt row, with their pad fill.
    _FILLS = {
        "a_state": 0, "t_state": 0, "kind": 0, "job": 0, "node": 0,
        "spec": False, "start": 0.0, "work_done": 0.0, "work_total": 1.0,
        "last_sync": 0.0, "fetched": 0, "deps": 1, "compute": False,
        "active": False, "skey": 0, "sh_ready": 0, "sh_inflight": 0,
        "sh_fail": 0,
    }

    def __init__(self, arr: ArraySnapshot):
        self.arr = arr
        self.cap = 0
        self.jobs_cap = 0
        self.jcap = 0
        self._buf: Dict[str, np.ndarray] = {}
        self._scratch_buf: Dict[str, np.ndarray] = {}
        self._order_buf = np.zeros(0, dtype=np.int64)
        self._jl_buf = np.zeros(0, dtype=np.int64)
        self._last_n = 0

    @staticmethod
    def _pow2(n: int, floor: int) -> int:
        cap = floor
        while cap < n:
            cap *= 2
        return cap

    def refresh(self, active: List[Tuple[str, int]],
                scratch_names: Tuple[str, ...] = ()) -> Dict[str, object]:
        """Re-mirror the snapshot; returns the padded column dict."""
        arr = self.arr
        n = arr.n
        cap = self._pow2(max(n, 1), max(self.cap, self.MIN_ROWS))
        if cap != self.cap:
            self.cap = cap
            for name, fill in self._FILLS.items():
                col = getattr(arr, name)
                self._buf[name] = np.full(cap, fill, dtype=col.dtype)
            self._scratch_buf = {}
            self._order_buf = np.zeros(cap, dtype=np.int64)
            self._last_n = 0
        for name in scratch_names:
            if name not in self._scratch_buf:
                col, fill = arr._scratch[name]
                self._scratch_buf[name] = np.full(cap, fill,
                                                  dtype=col.dtype)
        # Rows vacated since the last refresh (compaction) must re-pad.
        clear_to = max(self._last_n, n)
        for name, fill in self._FILLS.items():
            buf = self._buf[name]
            buf[:n] = getattr(arr, name)[:n]
            if clear_to > n:
                buf[n:clear_to] = fill
        for name, buf in self._scratch_buf.items():
            col, fill = arr._scratch[name]
            buf[:n] = col[:n]
            if clear_to > n:
                buf[n:clear_to] = fill
        order = arr.order()
        self._order_buf[:n] = order
        if clear_to > n:
            self._order_buf[n:clear_to] = 0
        self._last_n = n
        # Job axes: the registry axis (job_local gather) and the active
        # axis (per-job kernel outputs) both grow by doubling.
        self.jobs_cap = self._pow2(max(len(arr.job_ids), 1),
                                   max(self.jobs_cap, self.MIN_JOBS))
        jl = arr.job_local_map(active)
        if len(self._jl_buf) != self.jobs_cap:
            self._jl_buf = np.full(self.jobs_cap, -1, dtype=np.int64)
        self._jl_buf[:len(jl)] = jl
        self._jl_buf[len(jl):] = -1
        self.jcap = self._pow2(max(len(active), 1),
                               max(self.jcap, self.MIN_JOBS))
        out: Dict[str, object] = dict(self._buf)
        out.update(self._scratch_buf)
        out["order"] = self._order_buf
        out["job_local"] = self._jl_buf
        out["n_rows"] = n
        out["n_jobs"] = len(active)
        out["node_hb"] = arr.node_hb
        out["node_speed"] = arr.node_speed
        out["node_marked"] = arr.node_marked
        return out
