"""Binocular speculation — the paper's contribution as a reusable policy
engine (see DESIGN.md §2 for the MapReduce → TPU-training mapping).

Layout:
- ``types``       control-plane snapshot/action protocol
- ``arrays``      columnar (struct-of-arrays) snapshot mirror — the
                  vectorized assessment hot path (DESIGN.md §11)
- ``metrics``     Eq. 1–4 math (numpy + jax mirrors)
- ``glance``      neighborhood glance: spatial/temporal/failure assessments
- ``collective``  collective speculation ramp (COLL_INIT_NUM/COLL_MULTIPLY)
- ``dependency``  dependency-aware re-execution of completed producers
- ``rollback``    speculative rollback from lightweight progress logs
- ``speculator``  BinocularSpeculator + YarnLateSpeculator (baseline)
"""
from repro.core.arrays import ArraySnapshot
from repro.core.collective import CollectiveConfig, CollectiveSpeculation
from repro.core.dependency import DependencyConfig, DependencyTracker
from repro.core.glance import GlanceConfig, GlanceVerdict, NeighborhoodGlance
from repro.core.rollback import ProgressLog, RollbackRegistry, plan_rollback
from repro.core.speculator import (
    BinoConfig,
    BinocularSpeculator,
    LateConfig,
    Speculator,
    YarnLateSpeculator,
)
from repro.core.types import (
    Action,
    AttemptState,
    AttemptView,
    ClusterSnapshot,
    FetchFailure,
    KillAttempt,
    MarkNodeFailed,
    NodeView,
    SpeculateTask,
    TaskKind,
    TaskState,
    TaskView,
)

__all__ = [
    "Action", "ArraySnapshot", "AttemptState", "AttemptView", "BinoConfig",
    "BinocularSpeculator", "ClusterSnapshot", "CollectiveConfig",
    "CollectiveSpeculation", "DependencyConfig", "DependencyTracker",
    "FetchFailure", "GlanceConfig", "GlanceVerdict", "KillAttempt",
    "LateConfig", "MarkNodeFailed", "NeighborhoodGlance", "NodeView",
    "ProgressLog", "RollbackRegistry", "Speculator", "SpeculateTask",
    "TaskKind", "TaskState", "TaskView", "YarnLateSpeculator",
    "plan_rollback",
]
