"""Pluggable assessment-compute backends (numpy / jax / pallas) for the
vectorized speculation policies — see DESIGN.md §13."""
from repro.accel.base import (
    BACKENDS,
    TMARK,
    TPROG,
    AssessmentBackend,
    get_backend,
)

__all__ = [
    "AssessmentBackend",
    "BACKENDS",
    "TMARK",
    "TPROG",
    "get_backend",
]
