"""JAX assessment backend: jit-compiled kernels over device-resident
copies of the §11 columns (DESIGN.md §13.2–§13.3).

Bit-exactness strategy (§13.3): every kernel runs in float64 under a
scoped ``enable_x64`` and replicates the numpy reference *accumulation
order* —

- rows are visited in the canonical (§11.3) order: the padded ``order``
  export is gathered first, and every segmented sum is an XLA scatter-add
  whose updates apply sequentially in operand order (bit-equal to
  ``np.bincount`` on CPU);
- small fixed axes (the k-wide neighborhoods) are summed by *unrolled*
  sequential adds — ``jnp.sum`` may re-associate, ``np.nansum`` does not
  for k < 128;
- order-statistic math (LATE's percentile) mirrors ``np.percentile``'s
  linear-interpolation formula term for term;
- order-insensitive reductions (max, any) need no special care;
- ``a ± b·c`` chains are guarded against LLVM's FMA contraction (which
  skips the product's rounding step) by multiplying the product with a
  runtime-opaque ``one``: even if the compiler contracts, ``fma(x, 1, c)``
  rounds exactly like ``x + c``. Constants adjacent to such products
  (e.g. the reduce shuffle fraction) are shipped as opaque scalars too,
  so the HLO simplifier cannot re-fold the guard away.

Shapes are padded by :class:`repro.core.arrays.DeviceColumns` (grow by
doubling), so a jit specialization retraces only when the simulation
outgrows its row/job capacity, never per tick.

The traced cores (``spatial_core`` etc.) are shared: the pallas backend
swaps the hot reductions for hand-written kernels, and the batched sweep
(:mod:`repro.accel.sweep`) ``vmap``s :func:`assess_summary_core` across
fault scenarios.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.accel.base import TMARK, TPROG, AssessmentBackend
from repro.core.arrays import SHUFFLE_FRACTION, ArraySnapshot, DeviceColumns


# ---------------------------------------------------------------------------
# Traced helpers
# ---------------------------------------------------------------------------
def ordered_sum(x):
    """Sum the last axis by sequential left-to-right adds — the same
    association order as ``np.nansum`` over a small axis. ``jnp.sum``
    may re-associate, which breaks bit-exactness (§13.3)."""
    acc = x[..., 0]
    for j in range(1, x.shape[-1]):
        acc = acc + x[..., j]
    return acc


def prep(cols, now):
    """Canonical-order gather + the §11 elementwise projections, traced.

    Returns a dict of (cap,) arrays in canonical row order; ``posv``
    masks live positions, ``tseg`` is the global task-segment id (task
    segments are contiguous in canonical order)."""
    order = cols["order"]
    cap = order.shape[0]
    pos = jnp.arange(cap, dtype=jnp.int64)
    posv = pos < cols["n_rows"]

    def g(name):
        return cols[name][order]

    a_state = g("a_state")
    t_state = g("t_state")
    kind = g("kind")
    node = g("node")
    start = g("start")
    work_total = g("work_total")
    active = g("active") & posv
    one = cols["one"]          # opaque 1.0 — the anti-FMA guard (§13.3)
    sf = cols["sf"]            # opaque SHUFFLE_FRACTION
    # ProgressScore ζ, replicating ArraySnapshot.progress_at op-for-op.
    accrue = (a_state == 0) & ((kind == 0) | g("compute"))
    wd = g("work_done") + (accrue * (
        (now - g("last_sync")) * cols["node_speed"][node])) * one
    wd = jnp.minimum(wd, work_total)
    comp = wd / work_total
    # int/int: numpy promotes to f64, jax to f32 — cast first (§13.3).
    shuffle = g("fetched").astype(jnp.float64) / g("deps").astype(jnp.float64)
    prog = jnp.where(kind == 0, comp,
                     (sf * shuffle) * one + ((one - sf) * comp) * one)
    jl = cols["job_local"][g("job")]
    jls = jnp.where(jl >= 0, jl, 0)
    torder = g("skey") >> 20
    prev_t = jnp.concatenate([torder[:1] - 1, torder[:-1]])
    tseg = jnp.cumsum(torder != prev_t).astype(jnp.int64) - 1
    return {
        "cap": cap, "pos": pos, "posv": posv, "order": order,
        "a_state": a_state, "t_state": t_state, "kind": kind,
        "node": node, "spec": g("spec"), "start": start, "active": active,
        "prog": prog, "jl": jl, "jls": jls, "tseg": tseg,
        "mark": g(TMARK) if TMARK in cols else None,
        "tprog": g(TPROG) if TPROG in cols else None,
        "running": active & (a_state == 0) & (t_state == 1),
    }


def seg_sum(mask, seg, vals, nb):
    """Masked scatter-add into ``nb`` buckets (+1 dump), updates applied
    in operand (canonical) order — bit-equal to np.bincount (§13.3)."""
    idx = jnp.where(mask, seg, nb)
    return jnp.zeros(nb + 1).at[idx].add(jnp.where(mask, vals, 0.0))[:nb]


def seg_sum2(mask, seg, vals_a, vals_b, nb):
    """Two parallel masked bincounts sharing one scatter pass (scatter
    cost is per-update, so fusing the weight vectors halves it).
    Per-bucket accumulation order is operand order, as in seg_sum."""
    idx = jnp.where(mask, seg, nb)
    upd = jnp.stack([jnp.where(mask, vals_a, 0.0),
                     jnp.where(mask, vals_b, 0.0)], axis=-1)
    acc = jnp.zeros((nb + 1, 2)).at[idx].add(upd)[:nb]
    return acc[:, 0], acc[:, 1]


def seg_max(mask, seg, vals, nb, init):
    idx = jnp.where(mask, seg, nb)
    return jnp.full(nb + 1, init).at[idx].max(
        jnp.where(mask, vals, init))[:nb]


def seg_any(mask, seg, nb):
    return seg_sum(mask, seg, jnp.ones(mask.shape), nb) > 0


def spatial_mask(P, nh):
    """Eq. 1 over batched groups — mirror of
    ``metrics.spatial_slow_mask_batch_np`` with unrolled k-sums."""
    Pn = P[:, nh]                                  # (g, n, k)
    valid = ~jnp.isnan(Pn)
    cnt = valid.sum(axis=2)
    mean = ordered_sum(jnp.where(valid, Pn, 0.0)) / jnp.maximum(cnt, 1)
    var = ordered_sum(jnp.where(valid, (Pn - mean[:, :, None]) ** 2, 0.0)) \
        / jnp.maximum(cnt, 1)
    std = jnp.sqrt(var)
    ok = (cnt >= 2) & ~jnp.isnan(P)
    return ok & (P < (mean - std))


def percentile_indexes(m, q, cap, one):
    """numpy's virtual percentile index over ``m`` sorted samples:
    (clipped floor index, clipped ceil index, interpolation weight).
    ``one`` is the opaque anti-FMA guard (§13.3)."""
    v = ((m - 1) * (q / 100.0)) * one
    lo = jnp.floor(v)
    gamma = v - lo
    loi = jnp.clip(lo.astype(jnp.int64), 0, cap - 1)
    hii = jnp.clip(loi + 1, 0, jnp.maximum(m - 1, 0))
    return loi, hii, gamma


def percentile_lerp(a, b, gamma, one):
    """numpy's ``_lerp`` (including its t ≥ 0.5 symmetric form)."""
    diff = b - a
    return jnp.where(gamma >= 0.5, b - (diff * (1 - gamma)) * one,
                     a + (diff * gamma) * one)


def np_percentile_sorted(srt, m, q, one):
    """``np.percentile(x, q)`` given ``srt`` = sorted x padded with +inf
    and ``m`` live entries."""
    loi, hii, gamma = percentile_indexes(m, q, srt.shape[-1], one)
    a = jnp.take_along_axis(srt, loi[..., None], axis=-1)[..., 0]
    b = jnp.take_along_axis(srt, hii[..., None], axis=-1)[..., 0]
    return percentile_lerp(a, b, gamma, one)


# ---------------------------------------------------------------------------
# Cores (traced; shared by jit entry points, the pallas backend and the
# batched sweep)
# ---------------------------------------------------------------------------
def spatial_core(cols, nh, now, jcap):
    """(jcap, n_nodes) Eq. 1 hits (both phases merged)."""
    p = prep(cols, now)
    n = nh.shape[0]
    rt = jnp.maximum(now - p["start"], 1e-9)
    rho = p["prog"] / rt
    seg = (p["jls"] * 2 + p["kind"]) * n + p["node"]
    nb = jcap * 2 * n
    m = p["running"]
    sums, counts = seg_sum2(m, seg, rho, jnp.ones(rho.shape), nb)
    P = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0),
                  jnp.nan).reshape(jcap * 2, n)
    fired = spatial_mask(P, nh)
    return fired.reshape(jcap, 2, n).any(axis=1)


def temporal_core(cols, now, samp, init, prevk, n_nodes):
    """ζ sums per (job, node) over attempts alive at both samples, plus
    the scratch write-back (returned, applied host-side)."""
    p = prep(cols, now)
    jcap = samp.shape[0]
    n = n_nodes
    m = p["running"]
    samp_r = m & samp[p["jls"]]
    init_r = m & init[p["jls"]]
    alive = samp_r & (p["mark"] == prevk[p["jls"]])
    seg = p["jls"] * n + p["node"]
    nb = jcap * n
    zn = seg_sum(alive, seg, p["prog"], nb)
    zp = seg_sum(alive, seg, p["tprog"], nb)
    cnt = seg_sum(alive, seg, jnp.ones(p["prog"].shape), nb)
    zeta_now = jnp.where(cnt > 0, zn, jnp.nan).reshape(jcap, n)
    zeta_prev = jnp.where(cnt > 0, zp, jnp.nan).reshape(jcap, n)
    wmask = samp_r | init_r
    newk = jnp.where(samp, prevk + 1, 0)
    newmark = jnp.where(wmask, newk[p["jls"]], p["mark"])
    newtprog = jnp.where(wmask, p["prog"], p["tprog"])
    return zeta_now, zeta_prev, wmask, newmark, newtprog


def failure_core(now, node_hb, node_marked, declared, thresholds,
                 responsive_window):
    silent = now - node_hb
    resp = silent <= responsive_window
    cand = ~resp & ~declared & ~node_marked & (silent > thresholds)
    return resp, cand


def _block_starts(keys, jcap):
    """Per-job (count, exclusive-start) over a job-keyed sorted array
    (dump entries carry key == jcap). Integer sums are exact under any
    association, so the (jcap, cap) count matrix is bit-safe."""
    jrow = jnp.arange(jcap, dtype=keys.dtype)[:, None]
    cnt = (keys[None, :] == jrow).sum(axis=1)
    return cnt, jnp.cumsum(cnt) - cnt


def late_core(cols, now, min_runtime, q, jcap):
    """(jcap,) LATE victim rows (-1 = no victim).

    Selection runs on multi-key sorts instead of per-bucket scatters:
    grouping keys first, value keys second, the canonical position as
    the final tie-break — so 'max ζ, first-wins' and 'max estimate,
    lowest segment' come out of block heads exactly as the reference
    picks them, and the per-job percentile reads order statistics from
    a job-keyed sorted run (§13.3: order statistics and first-of-max
    picks are order-insensitive, hence bit-exact)."""
    p = prep(cols, now)
    cap = p["cap"]
    m = p["running"]
    big = jnp.int64(cap)
    k1 = jnp.where(m, p["tseg"], big)
    # Per-task best running attempt: max ζ first, canonical position as
    # the tie-break (= Python max()'s first-wins).
    k1s, _negp, bpos, best_prog, best_start, sjl = jax.lax.sort(
        (k1, -p["prog"], p["pos"], p["prog"], p["start"], p["jls"]),
        num_keys=3)
    first = jnp.concatenate([jnp.ones(1, dtype=bool), k1s[1:] != k1s[:-1]])
    rep = (k1s < big) & first                  # block head = the best row
    # Any speculative sibling among the task's running attempts: same
    # block structure (identical key multiset), spec-first ordering.
    _k1s2, negspec = jax.lax.sort(
        (k1, -p["spec"].astype(jnp.int64)), num_keys=2)
    has_spec = negspec == -1
    ok = rep & ~has_spec & (now - best_start >= min_runtime)
    rho = best_prog / jnp.maximum(now - best_start, 1e-9)
    est = (1.0 - best_prog) / jnp.maximum(rho, 1e-9)
    # Per-job percentile over the ok candidates: job-keyed sorted run +
    # numpy's linear interpolation on the block's order statistics.
    kj = jnp.where(ok, sjl, jnp.int64(jcap))
    kjs, rhos = jax.lax.sort((kj, rho), num_keys=2)
    msel_j, starts_j = _block_starts(kjs, jcap)
    one = cols["one"]
    loi, hii, gamma = percentile_indexes(msel_j, q, cap, one)
    a = rhos[jnp.clip(starts_j + loi, 0, cap - 1)]
    b = rhos[jnp.clip(starts_j + hii, 0, cap - 1)]
    thresh = percentile_lerp(a, b, gamma, one)
    slow = ok & (rho < thresh[sjl])
    # Victim = max est_remaining among slow, lowest task on ties.
    kv = jnp.where(slow, sjl, jnp.int64(jcap))
    kvs, _nege, _tie, vpos = jax.lax.sort((kv, -est, k1s, bpos),
                                          num_keys=3)
    nslow_j, starts_v = _block_starts(kvs, jcap)
    vict_row = cols["order"][vpos[jnp.clip(starts_v, 0, cap - 1)] % cap]
    nrows_j, _ = _block_starts(jnp.where(m, p["jls"], jnp.int64(jcap)),
                               jcap)
    good = (nrows_j >= 2) & (msel_j >= 2) & (nslow_j > 0)
    return jnp.where(good, vict_row, -1)


def winning_core(cols, now, win_factor, jcap):
    """(jcap,) collective 'speculation is winning' verdicts."""
    p = prep(cols, now)
    cap = p["cap"]
    m = p["active"] & (p["a_state"] == 0)    # running attempts, any task
    tseg = p["tseg"]
    rate = p["prog"] / jnp.maximum(now - p["start"], 1e-9)
    hi = seg_max(m & p["spec"], tseg, rate, cap, -jnp.inf)
    lo = seg_max(m & ~p["spec"], tseg, rate, cap, -jnp.inf)
    has_spec = seg_any(m & p["spec"], tseg, cap)
    has_orig = seg_any(m & ~p["spec"], tseg, cap)
    win_seg = has_spec & (~has_orig | (hi > lo * win_factor))
    wjl = seg_max(m, tseg, p["jls"], cap, jnp.int64(-1))
    return seg_any(win_seg & (wjl >= 0), jnp.where(wjl >= 0, wjl, 0), jcap)


def reap_core(cols, now):
    """(cap,) canonical-position mask of reapable sibling attempts."""
    p = prep(cols, now)
    cap = p["cap"]
    live = p["active"] & (p["t_state"] == 2)
    done = seg_any(live & (p["a_state"] == 1), p["tseg"], cap)
    return live & done[p["tseg"]] & (p["a_state"] == 0)


def assess_summary_core(cols, nh, now, min_runtime, q, win_factor,
                        declared, thresholds, responsive_window, jcap):
    """One whole assessment step as a pure function — the unit the
    batched sweep vmaps across fault scenarios (§13.4). Temporal state
    is scenario-independent here: the sweep scores a single step, so ζ
    deltas (which need two samples) are not part of the summary."""
    hits = spatial_core(cols, nh, now, jcap)
    resp, cand = failure_core(now, cols["node_hb"], cols["node_marked"],
                              declared, thresholds, responsive_window)
    victims = late_core(cols, now, min_runtime, q, jcap)
    win = winning_core(cols, now, win_factor, jcap)
    reap = reap_core(cols, now)
    return {
        "spatial_hits": hits,
        "responsive": resp,
        "failed": cand,
        "late_victims": victims,
        "winning": win,
        "n_reap": reap.sum(),
    }


# ---------------------------------------------------------------------------
# Jit entry points (module-level: the compile cache is shared across
# simulations; padded shapes keep it warm)
# ---------------------------------------------------------------------------
_spatial_jit = jax.jit(spatial_core, static_argnames=("jcap",))
_temporal_jit = jax.jit(temporal_core, static_argnames=("n_nodes",))
_failure_jit = jax.jit(failure_core)
_late_jit = jax.jit(late_core, static_argnames=("jcap",))
_winning_jit = jax.jit(winning_core, static_argnames=("jcap",))
_reap_jit = jax.jit(reap_core)


class JaxBackend(AssessmentBackend):
    name = "jax"

    # Entry points — the pallas subclass overrides the hot two.
    _spatial_fn = staticmethod(_spatial_jit)
    _temporal_fn = staticmethod(_temporal_jit)
    _late_fn = staticmethod(_late_jit)
    _winning_fn = staticmethod(_winning_jit)
    _reap_fn = staticmethod(_reap_jit)

    def __init__(self) -> None:
        self._dc: Optional[DeviceColumns] = None
        self._memo: Tuple[float, Optional[tuple]] = (np.nan, None)
        # The collective queries winning() once per straggler job within
        # a tick; the whole (jcap,) vector is computed on the first call.
        self._win_memo = (np.nan, np.nan, None, None)
        self._nh_dev = None
        self._nh_host = None

    # ------------------------------------------------------------------
    def _cols(self, arr: ArraySnapshot, now: float, active) -> tuple:
        """Upload the padded mirror once per tick (assessments never
        mutate state mid-tick; the clock strictly increases). Keyed on
        the snapshot too — an instance may be shared across sims."""
        if self._memo[0] == now and self._dc is not None \
                and self._dc.arr is arr:
            return self._memo[1]
        if self._dc is None or self._dc.arr is not arr:
            self._dc = DeviceColumns(arr)
        arr.scratch(TMARK, np.int64, -1)
        arr.scratch(TPROG, np.float64, np.nan)
        host = self._dc.refresh(active, scratch_names=(TMARK, TPROG))
        with enable_x64():
            dev = {}
            for k, v in host.items():
                if isinstance(v, np.ndarray):
                    dev[k] = jnp.asarray(v)
                else:
                    dev[k] = jnp.asarray(np.int64(v))
            # Opaque scalars: anti-FMA guard + the shuffle fraction
            # (shipped as data so the simplifier cannot re-fold, §13.3).
            dev["one"] = jnp.float64(1.0)
            dev["sf"] = jnp.float64(SHUFFLE_FRACTION)
        out = (dev, self._dc.jcap)
        self._memo = (now, out)
        return out

    def _nh(self, neighborhoods: np.ndarray):
        if self._nh_host is not neighborhoods:
            with enable_x64():
                self._nh_dev = jnp.asarray(
                    np.asarray(neighborhoods, dtype=np.int64))
            self._nh_host = neighborhoods
        return self._nh_dev

    # ------------------------------------------------------------------
    def spatial_hits(self, arr, now, active, neighborhoods):
        cols, jcap = self._cols(arr, now, active)
        with enable_x64():
            hits = self._spatial_fn(cols, self._nh(neighborhoods),
                                    jnp.float64(now), jcap=jcap)
        return np.asarray(hits)[:len(active)]

    def temporal_zeta(self, arr, now, active, samp_flag, init_flag, prevk):
        cols, jcap = self._cols(arr, now, active)
        J = len(active)
        n = len(arr.node_ids)
        sampd = np.zeros(jcap, dtype=bool)
        sampd[:J] = samp_flag
        initd = np.zeros(jcap, dtype=bool)
        initd[:J] = init_flag
        prevkd = np.full(jcap, -2, dtype=np.int64)
        prevkd[:J] = prevk
        with enable_x64():
            zn, zp, wmask, newmark, newtprog = self._temporal_fn(
                cols, jnp.float64(now), jnp.asarray(sampd),
                jnp.asarray(initd), jnp.asarray(prevkd), n_nodes=n)
        # Scratch write-back: the device computed this sample's marks in
        # canonical order; apply them to the host columns.
        n_rows = arr.n
        w = np.asarray(wmask)[:n_rows]
        if w.any():
            rows = arr.order()[w]
            arr.scratch(TMARK, np.int64, -1)[rows] = \
                np.asarray(newmark)[:n_rows][w]
            arr.scratch(TPROG, np.float64, np.nan)[rows] = \
                np.asarray(newtprog)[:n_rows][w]
        return np.asarray(zn)[:J], np.asarray(zp)[:J]

    def failure_masks(self, now, node_hb, node_marked, declared,
                      thresholds, responsive_window):
        with enable_x64():
            resp, cand = _failure_jit(
                jnp.float64(now), jnp.asarray(node_hb),
                jnp.asarray(node_marked), jnp.asarray(declared),
                jnp.asarray(thresholds), jnp.float64(responsive_window))
        return np.asarray(resp), np.asarray(cand)

    def late_victims(self, arr, now, active, eligible, min_runtime,
                     slow_task_percentile):
        cols, jcap = self._cols(arr, now, active)
        with enable_x64():
            victims = self._late_fn(cols, jnp.float64(now),
                                    jnp.float64(min_runtime),
                                    jnp.float64(slow_task_percentile),
                                    jcap=jcap)
        return np.asarray(victims)[:len(active)]

    def winning(self, arr, now, job_idx, win_factor):
        active = arr.active_jobs()
        if self._win_memo[0] == now and self._win_memo[1] == win_factor \
                and self._win_memo[3] is arr:
            win = self._win_memo[2]
        else:
            cols, jcap = self._cols(arr, now, active)
            with enable_x64():
                win = np.asarray(self._winning_fn(
                    cols, jnp.float64(now), jnp.float64(win_factor),
                    jcap=jcap))
            self._win_memo = (now, win_factor, win, arr)
        jl = arr.job_local_map(active)
        pos = jl[job_idx] if 0 <= job_idx < len(jl) else -1
        if pos < 0:
            return False
        return bool(win[pos])

    def reap_rows(self, arr, now):
        active = arr.active_jobs()
        cols, _jcap = self._cols(arr, now, active)
        with enable_x64():
            reap = self._reap_fn(cols, jnp.float64(now))
        mask = np.asarray(reap)[:arr.n]
        return arr.order()[mask]


__all__ = [
    "JaxBackend",
    "assess_summary_core",
    "late_core",
    "np_percentile_sorted",
    "ordered_sum",
    "percentile_indexes",
    "percentile_lerp",
    "prep",
    "reap_core",
    "spatial_core",
    "spatial_mask",
    "temporal_core",
    "winning_core",
]
