"""Bulk-launch network solver backends (DESIGN.md §17.2).

The ε-fair model's per-drain work factors into two dense steps over the
columnar flow/link tables:

- ``waterfill(eff, links, valid)`` — the ε-fair max-min solve: per-link
  equilibrium shares plus per-flow rates (the §15.3 water-fill,
  previously inlined in ``FairNetwork._recompute``);
- ``price(share, links, valid)`` — batch pricing: the frozen-rate rule
  ``max(min(share[links]), 1)`` for a *batch* of flows at once (used by
  the drain-boundary re-allocation of in-flight transfers, §17.4).

Mirroring the :class:`repro.accel.base.AssessmentBackend` discipline,
three implementations ship behind one protocol:

- ``numpy`` — the bit-exact reference (the PR 5 solver loop, verbatim);
- ``jax`` — the same rounds as a jit ``lax.while_loop`` in scoped
  float64; per-round link loads are scatter-adds of exact small
  integers, so CPU runs match numpy bit-for-bit;
- ``pallas`` — jax water-fill plus a hand-written Pallas pricing kernel
  (``interpret=True`` by default; ``REPRO_PALLAS_COMPILE=1`` lowers to
  a real device).

Backends are resolved lazily (:func:`get_bulk_backend`) so the numpy
path never pays jax import cost; the network layer stays import-clean
of the simulator.
"""
from __future__ import annotations

from typing import Tuple, Union

import numpy as np

BULK_BACKENDS = ("numpy", "jax", "pallas")


class BulkBackend:
    """One drain's dense network math. Stateless w.r.t. the flow tables;
    may cache jit specializations / padded device buffers internally."""

    name: str = "?"

    def waterfill(self, eff: np.ndarray, links: np.ndarray,
                  valid: np.ndarray, eps: float
                  ) -> Tuple[np.ndarray, np.ndarray]:
        """ε-fair max-min solve over ``k`` flows and ``nL`` links.

        ``eff`` (nL,) effective link capacities; ``links`` (k, 4) int
        link ids, -1 padded; ``valid = links >= 0``. Returns
        ``(share, rate)``: per-link equilibrium shares (never-bottleneck
        links expose residual headroom) and per-flow equilibrium rates.
        """
        raise NotImplementedError

    def price(self, share: np.ndarray, links: np.ndarray,
              valid: np.ndarray) -> np.ndarray:
        """Frozen-rate batch pricing: per-flow ``max(min(share[links
        over valid]), 1.0)`` — the launch rule applied to many flows in
        one step."""
        raise NotImplementedError


# ---------------------------------------------------------------------------
# numpy — bit-exact reference
# ---------------------------------------------------------------------------
class NumpyBulk(BulkBackend):
    name = "numpy"

    def waterfill(self, eff, links, valid, eps):
        nL = len(eff)
        k = len(links)
        share = eff.copy()
        rate = np.zeros(k)
        if not k:
            return share, rate
        flat_links = np.where(valid, links, 0)
        rem = eff.copy()
        alive = valid.any(axis=1)
        was_bott = np.zeros(nL, dtype=bool)
        eps1 = 1.0 + eps
        while True:
            a_links = flat_links[alive][valid[alive]]
            if not len(a_links):
                break
            cnt = np.bincount(a_links, minlength=nL)
            live = cnt > 0
            s_all = np.where(live, rem / np.maximum(cnt, 1), np.inf)
            s = float(s_all.min())
            bott = live & (s_all <= s * eps1)
            hit = alive & (bott[flat_links] & valid).any(axis=1)
            rate[hit] = s
            h_links = flat_links[hit][valid[hit]]
            rem = np.maximum(
                rem - np.bincount(h_links, minlength=nL) * s, 0.0)
            share[bott] = s
            was_bott |= bott
            alive &= ~hit
        free = ~was_bott
        share[free] = rem[free]
        return share, rate

    def price(self, share, links, valid):
        if not len(links):
            return np.zeros(0)
        per = np.where(valid, share[np.where(valid, links, 0)], np.inf)
        return np.maximum(per.min(axis=1), 1.0)


# ---------------------------------------------------------------------------
# jax — jit while_loop rounds, f64, padded specializations
# ---------------------------------------------------------------------------
class JaxBulk(BulkBackend):
    """Same rounds as the reference under ``lax.while_loop``. Flow count
    is padded to the next power of two so the jit specializes per
    (link-count, capacity) pair, not per call; padded rows carry no
    valid links and can never be hit."""

    name = "jax"

    def __init__(self):
        self._fills = {}
        self._prices = {}

    @staticmethod
    def _pad(k: int) -> int:
        cap = 16
        while cap < k:
            cap *= 2
        return cap

    def _fill_fn(self, nL: int, cap: int, eps: float):
        key = (nL, cap, eps)
        fn = self._fills.get(key)
        if fn is None:
            fn = _make_waterfill(nL, eps)
            self._fills[key] = fn
        return fn

    def waterfill(self, eff, links, valid, eps):
        k = len(links)
        if not k:
            return eff.copy(), np.zeros(0)
        import jax.numpy as jnp
        from jax.experimental import enable_x64
        cap = self._pad(k)
        L = np.zeros((cap, 4), dtype=np.int32)
        V = np.zeros((cap, 4), dtype=bool)
        L[:k] = np.where(valid, links, 0)
        V[:k] = valid
        with enable_x64():
            fn = self._fill_fn(len(eff), cap, float(eps))
            share, rate = fn(jnp.asarray(eff, jnp.float64),
                             jnp.asarray(L), jnp.asarray(V),
                             jnp.float64(1.0))
            return np.asarray(share), np.asarray(rate)[:k]

    def _price_core(self):
        import jax
        import jax.numpy as jnp

        @jax.jit
        def price(share, L, V):
            per = jnp.where(V, share[L], jnp.inf)
            return jnp.maximum(per.min(axis=1), 1.0)
        return price

    def price(self, share, links, valid):
        k = len(links)
        if not k:
            return np.zeros(0)
        import jax.numpy as jnp
        from jax.experimental import enable_x64
        cap = self._pad(k)
        L = np.zeros((cap, 4), dtype=np.int32)
        V = np.zeros((cap, 4), dtype=bool)
        L[:k] = np.where(valid, links, 0)
        V[:k] = valid
        fn = self._prices.get("price")
        if fn is None:
            fn = self._prices["price"] = self._price_core()
        with enable_x64():
            out = fn(jnp.asarray(share, jnp.float64), jnp.asarray(L),
                     jnp.asarray(V))
            return np.asarray(out)[:k]


def _make_waterfill(nL: int, eps: float):
    import jax
    import jax.numpy as jnp

    eps1 = 1.0 + eps

    @jax.jit
    def fill(eff, L, V, one):
        # ``one`` is the runtime-opaque anti-FMA guard (jax_backend
        # §13.3): ``rem - cnt·s`` must round the product before the
        # subtract, exactly as the numpy reference does.
        k = L.shape[0]
        has_link = V.any(axis=1)

        def cond(st):
            alive = st[0]
            return alive.any()

        def body(st):
            alive, rem, share, rate, was_bott = st
            w = alive[:, None] & V
            cnt = jnp.zeros(nL, eff.dtype).at[L].add(
                jnp.where(w, 1.0, 0.0))
            live = cnt > 0
            s_all = jnp.where(live, rem / jnp.maximum(cnt, 1.0), jnp.inf)
            s = s_all.min()
            bott = live & (s_all <= s * eps1)
            hit = alive & (bott[L] & V).any(axis=1)
            rate = jnp.where(hit, s, rate)
            hw = hit[:, None] & V
            dec = (jnp.zeros(nL, eff.dtype).at[L].add(
                jnp.where(hw, 1.0, 0.0)) * s) * one
            rem = jnp.maximum(rem - dec, 0.0)
            share = jnp.where(bott, s, share)
            was_bott = was_bott | bott
            alive = alive & ~hit
            return alive, rem, share, rate, was_bott

        init = (has_link, eff, eff,
                jnp.zeros(k, eff.dtype), jnp.zeros(nL, bool))
        alive, rem, share, rate, was_bott = jax.lax.while_loop(
            cond, body, init)
        share = jnp.where(was_bott, share, rem)
        return share, rate

    return fill


# ---------------------------------------------------------------------------
# pallas — jax water-fill + hand-written pricing kernel
# ---------------------------------------------------------------------------
class PallasBulk(JaxBulk):
    """Water-fill inherits the jax rounds (a data-dependent while_loop
    has no natural grid); the batch pricing step — the §17.4 re-pricing
    of every in-flight transfer at a drain boundary — runs as a Pallas
    gather-min kernel."""

    name = "pallas"

    def _price_core(self):
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        from repro.accel.pallas_backend import INTERPRET

        def kernel(share_ref, links_ref, valid_ref, out_ref):
            L = links_ref[...]
            ok = valid_ref[...]
            per = jnp.where(ok, share_ref[...][L], jnp.inf)
            out_ref[...] = jnp.maximum(per.min(axis=1), 1.0)

        def price(share, L, V):
            import jax
            cap = L.shape[0]
            fn = pl.pallas_call(
                kernel,
                out_shape=jax.ShapeDtypeStruct((cap,), share.dtype),
                interpret=INTERPRET)
            return fn(share, L, V)
        return price


def get_bulk_backend(spec: Union[str, BulkBackend, None]) -> BulkBackend:
    """Resolve a bulk backend name (or pass an instance through); jax
    and pallas import lazily, mirroring :func:`repro.accel.base.
    get_backend`."""
    if isinstance(spec, BulkBackend):
        return spec
    name = (spec or "numpy").lower()
    if name == "numpy":
        return NumpyBulk()
    if name == "jax":
        return JaxBulk()
    if name == "pallas":
        return PallasBulk()
    raise ValueError(
        f"unknown bulk backend {spec!r}; expected one of {BULK_BACKENDS}")
