"""Pallas assessment backend: hand-written kernels for the two hottest
assessment reductions (DESIGN.md §13.2).

Kernel layout
-------------
- **Glance kernel** (grid = one program per padded job): the Eq. 1
  spatial pass — per-(phase, node) ρ sums/counts accumulated by a
  sequential row scan in canonical order (bit-equal to ``np.bincount``),
  then the neighborhood mean−σ test with unrolled k-sums — and, in the
  temporal variant, the Eq. 2–3 ζ accumulation over attempts alive at
  both samples.
- **LATE/collective kernel** (grid = one program per padded job): the
  per-task segment scan (best running attempt first-wins, speculative
  flags, original-vs-speculative max rates), LATE's percentile rank +
  victim pick, and the collective winning verdict. A gridless sibling
  scans sibling-reap candidates.

Elementwise projections (ζ progress, rates, masks) are prepared by the
shared :func:`repro.accel.jax_backend.prep` — the kernels own the
*reductions*, which is where the assessment wall is (ROADMAP).

``interpret=True`` is the default so CI and laptop runs execute without
a TPU/GPU; set ``REPRO_PALLAS_COMPILE=1`` to lower to Mosaic on real
devices. Compiled-mode caveats (f32, in-kernel sort support) are the
documented §13.3 exactness waivers; interpret mode is bit-exact against
the numpy backend and gated so by tests/test_accel.py.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.accel.jax_backend import (
    JaxBackend,
    np_percentile_sorted,
    ordered_sum,
    prep,
)

# Interpret by default: the baked container has no TPU, and CI pins
# JAX_PLATFORMS=cpu. Real devices opt in explicitly.
INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "") in ("", "0")


# ---------------------------------------------------------------------------
# Glance kernel — Eq. 1 spatial pass (+ Eq. 2–3 ζ accumulation variant)
# ---------------------------------------------------------------------------
def _spatial_kernel(rho_ref, node_ref, kind_ref, jls_ref, run_ref, nh_ref,
                    fired_ref, sums_ref, counts_ref):
    j = pl.program_id(0)
    cap = rho_ref.shape[0]
    n = nh_ref.shape[0]
    sums_ref[...] = jnp.zeros((2, n), sums_ref.dtype)
    counts_ref[...] = jnp.zeros((2, n), counts_ref.dtype)

    def body(i, carry):
        # Sequential scan in canonical order: per-bucket partial sums
        # round exactly like the reference bincount (§13.3). Masked rows
        # add 0.0 — a bitwise no-op on the (non-negative) accumulators.
        use = (run_ref[i] == 1) & (jls_ref[i] == j)
        ph = kind_ref[i]
        nd = node_ref[i]
        sums_ref[ph, nd] = sums_ref[ph, nd] + jnp.where(use, rho_ref[i], 0.0)
        counts_ref[ph, nd] = counts_ref[ph, nd] + jnp.where(use, 1.0, 0.0)
        return carry

    jax.lax.fori_loop(0, cap, body, 0)
    sums = sums_ref[...]
    counts = counts_ref[...]
    P = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), jnp.nan)
    Pn = P[:, nh_ref[...]]                       # (2, n, k)
    valid = ~jnp.isnan(Pn)
    cnt = valid.sum(axis=2)
    mean = ordered_sum(jnp.where(valid, Pn, 0.0)) / jnp.maximum(cnt, 1)
    var = ordered_sum(jnp.where(valid, (Pn - mean[:, :, None]) ** 2, 0.0)) \
        / jnp.maximum(cnt, 1)
    std = jnp.sqrt(var)
    ok = (cnt >= 2) & ~jnp.isnan(P)
    fired_ref[0] = ok & (P < (mean - std))


def _temporal_kernel(prog_ref, tprog_ref, node_ref, jls_ref, alive_ref,
                     zn_ref, zp_ref, cnt_ref):
    j = pl.program_id(0)
    cap = prog_ref.shape[0]
    n = zn_ref.shape[1]
    zn_ref[...] = jnp.zeros((1, n), zn_ref.dtype)
    zp_ref[...] = jnp.zeros((1, n), zp_ref.dtype)
    cnt_ref[...] = jnp.zeros((n,), cnt_ref.dtype)

    def body(i, carry):
        use = (alive_ref[i] == 1) & (jls_ref[i] == j)
        nd = node_ref[i]
        zn_ref[0, nd] = zn_ref[0, nd] + jnp.where(use, prog_ref[i], 0.0)
        zp_ref[0, nd] = zp_ref[0, nd] + jnp.where(use, tprog_ref[i], 0.0)
        cnt_ref[nd] = cnt_ref[nd] + jnp.where(use, jnp.int32(1),
                                              jnp.int32(0))
        return carry

    jax.lax.fori_loop(0, cap, body, 0)
    have = cnt_ref[...] > 0
    zn_ref[0] = jnp.where(have, zn_ref[0], jnp.nan)
    zp_ref[0] = jnp.where(have, zp_ref[0], jnp.nan)


# ---------------------------------------------------------------------------
# LATE / collective kernel — per-task segment pass
# ---------------------------------------------------------------------------
def _late_kernel(prog_ref, start_ref, rate_ref, spec_ref,
                 tseg_ref, jls_ref, run_ref, att_ref, order_ref, params_ref,
                 vict_ref, win_ref,
                 bprog_ref, bpos_ref, bstart_ref, hspec_ref,
                 hi_ref, lo_ref, sp2_ref, org_ref):
    j = pl.program_id(0)
    cap = prog_ref.shape[0]
    now = params_ref[0]
    min_runtime = params_ref[1]
    q = params_ref[2]
    win_factor = params_ref[3]
    one = params_ref[4]
    neg = -jnp.inf
    bprog_ref[...] = jnp.full((cap + 1,), neg, bprog_ref.dtype)
    bpos_ref[...] = jnp.full((cap + 1,), cap, bpos_ref.dtype)
    bstart_ref[...] = jnp.zeros((cap + 1,), bstart_ref.dtype)
    hspec_ref[...] = jnp.zeros((cap + 1,), hspec_ref.dtype)
    hi_ref[...] = jnp.full((cap + 1,), neg, hi_ref.dtype)
    lo_ref[...] = jnp.full((cap + 1,), neg, lo_ref.dtype)
    sp2_ref[...] = jnp.zeros((cap + 1,), sp2_ref.dtype)
    org_ref[...] = jnp.zeros((cap + 1,), org_ref.dtype)

    def body(i, nrows):
        s = tseg_ref[i]
        sp = spec_ref[i] == 1
        # LATE candidate rows: per-task max-ζ attempt, FIRST-wins on ties
        # (strictly-greater update in ascending canonical order).
        is_run = (run_ref[i] == 1) & (jls_ref[i] == j)
        sl = jnp.where(is_run, s, cap)
        take = is_run & (prog_ref[i] > bprog_ref[sl])
        bprog_ref[sl] = jnp.where(take, prog_ref[i], bprog_ref[sl])
        bpos_ref[sl] = jnp.where(take, jnp.asarray(i, jnp.int32),
                                 bpos_ref[sl])
        bstart_ref[sl] = jnp.where(take, start_ref[i], bstart_ref[sl])
        hspec_ref[sl] = jnp.maximum(
            hspec_ref[sl], jnp.where(is_run & sp, jnp.int32(1),
                                     jnp.int32(0)))
        # Collective winning rows: any running attempt of the task.
        is_att = (att_ref[i] == 1) & (jls_ref[i] == j)
        sa = jnp.where(is_att, s, cap)
        hi_ref[sa] = jnp.maximum(hi_ref[sa],
                                 jnp.where(is_att & sp, rate_ref[i], neg))
        lo_ref[sa] = jnp.maximum(lo_ref[sa],
                                 jnp.where(is_att & ~sp, rate_ref[i], neg))
        sp2_ref[sa] = jnp.maximum(
            sp2_ref[sa], jnp.where(is_att & sp, jnp.int32(1), jnp.int32(0)))
        org_ref[sa] = jnp.maximum(
            org_ref[sa], jnp.where(is_att & ~sp, jnp.int32(1),
                                   jnp.int32(0)))
        return nrows + jnp.where(is_run, 1, 0)

    nrows = jax.lax.fori_loop(0, cap, body, 0)

    # --- LATE percentile rank over the per-task candidates -------------
    bpos = bpos_ref[:cap]
    seg_ok = bpos < cap
    best_prog = bprog_ref[:cap]
    best_start = bstart_ref[:cap]
    okm = seg_ok & (hspec_ref[:cap] == 0) \
        & (now - best_start >= min_runtime)
    rho = jnp.where(seg_ok, best_prog, 0.0) \
        / jnp.maximum(now - best_start, 1e-9)
    est = (1.0 - jnp.where(seg_ok, best_prog, 0.0)) \
        / jnp.maximum(rho, 1e-9)
    m = okm.astype(jnp.int32).sum()
    srt = jnp.sort(jnp.where(okm, rho, jnp.inf))
    thresh = np_percentile_sorted(srt, m, q, one)
    slow = okm & (rho < thresh)
    est_m = jnp.where(slow, est, neg)
    vict = jnp.argmax(est_m)                 # first-of-max = lowest tseg
    good = (nrows >= 2) & (m >= 2) & (est_m[vict] > neg)
    vict_ref[0] = jnp.where(good, order_ref[bpos[vict]], jnp.int32(-1))

    # --- collective winning verdict ------------------------------------
    win_seg = (sp2_ref[:cap] == 1) \
        & ((org_ref[:cap] == 0) | (hi_ref[:cap] > lo_ref[:cap] * win_factor))
    win_ref[0] = win_seg.any().astype(jnp.int32)


def _reap_kernel(astate_ref, tseg_ref, live_ref, out_ref, done_ref):
    cap = astate_ref.shape[0]
    done_ref[...] = jnp.zeros((cap + 1,), done_ref.dtype)

    def mark(i, carry):
        live = live_ref[i] == 1
        s = jnp.where(live, tseg_ref[i], cap)
        done_ref[s] = jnp.maximum(
            done_ref[s], jnp.where(live & (astate_ref[i] == 1),
                                   jnp.int32(1), jnp.int32(0)))
        return carry

    jax.lax.fori_loop(0, cap, mark, 0)

    def emit(i, carry):
        live = live_ref[i] == 1
        s = jnp.where(live, tseg_ref[i], cap)
        out_ref[i] = jnp.where(
            live & (astate_ref[i] == 0) & (done_ref[s] == 1),
            jnp.int32(1), jnp.int32(0))
        return carry

    jax.lax.fori_loop(0, cap, emit, 0)


# ---------------------------------------------------------------------------
# Wrappers (same signatures as the jax backend entry points)
# ---------------------------------------------------------------------------
def _i32(x):
    return x.astype(jnp.int32)


def _pallas_spatial(cols, nh, now, jcap):
    p = prep(cols, now)
    cap = p["cap"]
    n = nh.shape[0]
    rho = p["prog"] / jnp.maximum(now - p["start"], 1e-9)
    fired = pl.pallas_call(
        _spatial_kernel,
        grid=(jcap,),
        in_specs=[pl.BlockSpec((cap,), lambda j: (0,))] * 5
        + [pl.BlockSpec(nh.shape, lambda j: (0, 0))],
        out_specs=pl.BlockSpec((1, 2, n), lambda j: (j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((jcap, 2, n), jnp.bool_),
        scratch_shapes=[
            pltpu_vmem((2, n), jnp.float64),
            pltpu_vmem((2, n), jnp.float64),
        ],
        interpret=INTERPRET,
    )(rho, _i32(p["node"]), _i32(p["kind"]), _i32(p["jls"]),
      _i32(p["running"]), _i32(nh))
    return fired.any(axis=1)


def _pallas_temporal(cols, now, samp, init, prevk, n_nodes):
    p = prep(cols, now)
    cap = p["cap"]
    jcap = samp.shape[0]
    n = n_nodes
    m = p["running"]
    samp_r = m & samp[p["jls"]]
    init_r = m & init[p["jls"]]
    alive = samp_r & (p["mark"] == prevk[p["jls"]])
    zn, zp = pl.pallas_call(
        _temporal_kernel,
        grid=(jcap,),
        in_specs=[pl.BlockSpec((cap,), lambda j: (0,))] * 5,
        out_specs=(pl.BlockSpec((1, n), lambda j: (j, 0)),
                   pl.BlockSpec((1, n), lambda j: (j, 0))),
        out_shape=(jax.ShapeDtypeStruct((jcap, n), jnp.float64),
                   jax.ShapeDtypeStruct((jcap, n), jnp.float64)),
        scratch_shapes=[pltpu_vmem((n,), jnp.int32)],
        interpret=INTERPRET,
    )(p["prog"], jnp.where(alive, p["tprog"], 0.0), _i32(p["node"]),
      _i32(p["jls"]), _i32(alive))
    wmask = samp_r | init_r
    newk = jnp.where(samp, prevk + 1, 0)
    newmark = jnp.where(wmask, newk[p["jls"]], p["mark"])
    newtprog = jnp.where(wmask, p["prog"], p["tprog"])
    return zn, zp, wmask, newmark, newtprog


def _late_call(cols, now, min_runtime, q, win_factor, jcap):
    p = prep(cols, now)
    cap = p["cap"]
    rate = p["prog"] / jnp.maximum(now - p["start"], 1e-9)
    runatt = p["active"] & (p["a_state"] == 0)
    params = jnp.stack([now, min_runtime, q, win_factor, cols["one"]])
    f64 = jnp.float64
    victims, win = pl.pallas_call(
        _late_kernel,
        grid=(jcap,),
        in_specs=[pl.BlockSpec((cap,), lambda j: (0,))] * 9
        + [pl.BlockSpec((5,), lambda j: (0,))],
        out_specs=(pl.BlockSpec((1,), lambda j: (j,)),
                   pl.BlockSpec((1,), lambda j: (j,))),
        out_shape=(jax.ShapeDtypeStruct((jcap,), jnp.int32),
                   jax.ShapeDtypeStruct((jcap,), jnp.int32)),
        scratch_shapes=[
            pltpu_vmem((cap + 1,), f64),        # best prog
            pltpu_vmem((cap + 1,), jnp.int32),  # best pos
            pltpu_vmem((cap + 1,), f64),        # best start
            pltpu_vmem((cap + 1,), jnp.int32),  # has speculative
            pltpu_vmem((cap + 1,), f64),        # max spec rate
            pltpu_vmem((cap + 1,), f64),        # max orig rate
            pltpu_vmem((cap + 1,), jnp.int32),  # any spec
            pltpu_vmem((cap + 1,), jnp.int32),  # any orig
        ],
        interpret=INTERPRET,
    )(p["prog"], p["start"], rate, _i32(p["spec"]),
      _i32(p["tseg"]), _i32(p["jls"]), _i32(p["running"]), _i32(runatt),
      _i32(cols["order"]), params)
    return victims.astype(jnp.int64), win == 1


def _pallas_late(cols, now, min_runtime, q, jcap):
    victims, _win = _late_call(cols, now, min_runtime, q,
                               jnp.float64(1.0), jcap)
    return victims


def _pallas_winning(cols, now, win_factor, jcap):
    _victims, win = _late_call(cols, now, jnp.float64(10.0),
                               jnp.float64(25.0), win_factor, jcap)
    return win


def _pallas_reap(cols, now):
    p = prep(cols, now)
    cap = p["cap"]
    live = p["active"] & (p["t_state"] == 2)
    out = pl.pallas_call(
        _reap_kernel,
        out_shape=jax.ShapeDtypeStruct((cap,), jnp.int32),
        scratch_shapes=[pltpu_vmem((cap + 1,), jnp.int32)],
        interpret=INTERPRET,
    )(_i32(p["a_state"]), _i32(p["tseg"]), _i32(live))
    return out == 1


def pltpu_vmem(shape, dtype):
    """VMEM scratch allocator — indirected so interpret mode works on
    CPU-only installs where the TPU plugin may be absent."""
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


_pallas_spatial_jit = jax.jit(_pallas_spatial, static_argnames=("jcap",))
_pallas_temporal_jit = jax.jit(_pallas_temporal,
                               static_argnames=("n_nodes",))
_pallas_late_jit = jax.jit(_pallas_late, static_argnames=("jcap",))
_pallas_winning_jit = jax.jit(_pallas_winning, static_argnames=("jcap",))
_pallas_reap_jit = jax.jit(_pallas_reap)


class PallasBackend(JaxBackend):
    """Device layout, upload discipline and host glue are inherited from
    the jax backend; the hot reductions run as Pallas kernels."""

    name = "pallas"

    _spatial_fn = staticmethod(_pallas_spatial_jit)
    _temporal_fn = staticmethod(_pallas_temporal_jit)
    _late_fn = staticmethod(_pallas_late_jit)
    _winning_fn = staticmethod(_pallas_winning_jit)
    _reap_fn = staticmethod(_pallas_reap_jit)


__all__ = ["PallasBackend", "INTERPRET"]
