"""Batched multi-scenario assessment sweeps (DESIGN.md §13.4).

Speculation policies are compared across *many* fault scenarios — the
multi-job speculative-execution literature scores a policy by sweeping
fault grids, and the ROADMAP's assess-bound sweeps re-run the same
per-tick reductions once per scenario. :class:`BatchedSweep` instead
stacks N perturbed copies of the §11 columns along a leading scenario
axis and ``vmap``s one whole assessment step
(:func:`repro.accel.jax_backend.assess_summary_core`) across them: one
device dispatch scores every scenario at once, amortizing both the
Python tick overhead and the kernel launch cost N ways.

Scenario kinds mirror the :mod:`repro.sim.faults` injectors, as column
perturbations rather than event-schedule edits:

- ``crash``    — victim node's clock stops and heartbeats go silent
  (Eq. 4 territory; frozen ζ drags Eq. 1/LATE);
- ``delay``    — victim node slowed to ``factor`` (Eq. 1/Eq. 3 territory);
- ``mof_loss`` — a few reducers lose an already-fetched map output and
  burn a failure cycle (shuffle-health regression);
- ``fetch_quorum`` — every running reducer regresses one partition with
  stacked failure cycles (the AM-quorum stall shape).

``run_serial`` evaluates the identical clones one at a time on the
numpy reference backend — the baseline the perf gate compares against,
and the parity oracle for ``run_batched`` (bit-exact on CPU, §13.3).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.accel.numpy_backend import NumpyBackend
from repro.core.arrays import SHUFFLE_FRACTION, ArraySnapshot, DeviceColumns

__all__ = ["Scenario", "scenario_grid", "BatchedSweep"]


@dataclasses.dataclass(frozen=True)
class Scenario:
    kind: str            # baseline | crash | delay | mof_loss |
    #                      fetch_quorum | rack_degrade
    node: int = -1       # victim node index (crash / delay)
    factor: float = 1.0  # speed multiplier (delay) / uplink factor
    width: int = 2       # reducers hit (mof_loss)
    silent_s: float = 12.0   # heartbeat silence injected (crash)
    rack: int = -1       # victim rack (rack_degrade; §15 net columns)


def scenario_grid(n_scenarios: int, n_nodes: int,
                  seed: int = 0, n_racks: int = 1) -> List[Scenario]:
    """A deterministic grid cycling the fault kinds over distinct
    victims/intensities — the sweep analogue of the benchmark fault
    grids (benches × fracs × seeds). With a rack topology
    (``n_racks > 1``) the cycle includes ``rack_degrade`` — the
    degraded-uplink shape driven from the §15 ``node_rack`` column."""
    rng = np.random.default_rng(seed)
    kinds = ("crash", "delay", "mof_loss", "fetch_quorum")
    if n_racks > 1:
        kinds = kinds + ("rack_degrade",)
    out: List[Scenario] = []
    for i in range(n_scenarios):
        kind = kinds[i % len(kinds)]
        node = int(rng.integers(0, n_nodes))
        k = len(kinds)
        if kind == "crash":
            out.append(Scenario(kind, node=node,
                                silent_s=float(11 + 7 * (i // k % 3))))
        elif kind == "delay":
            out.append(Scenario(kind, node=node,
                                factor=float(0.02 + 0.03 * (i // k % 3))))
        elif kind == "mof_loss":
            out.append(Scenario(kind, width=1 + i // k % 3))
        elif kind == "rack_degrade":
            out.append(Scenario(kind, rack=int(rng.integers(0, n_racks)),
                                factor=float(0.02 + 0.04 * (i // k % 3))))
        else:
            out.append(Scenario(kind))
    return out


def apply_scenario(arr: ArraySnapshot, sc: Scenario, now: float) -> None:
    """Perturb a cloned snapshot in place (host numpy)."""
    if sc.kind == "baseline":
        return
    if sc.kind == "crash":
        v = sc.node % len(arr.node_ids)
        arr.node_speed[v] = 0.0
        arr.node_hb[v] = now - sc.silent_s
        return
    if sc.kind == "delay":
        v = sc.node % len(arr.node_ids)
        arr.node_speed[v] = sc.factor
        return
    n = arr.n
    reducing = np.flatnonzero(
        arr.active[:n] & (arr.kind[:n] == 1) & (arr.a_state[:n] == 0)
        & (arr.fetched[:n] > 0))
    if sc.kind == "mof_loss":
        hit = reducing[:sc.width]
        arr.fetched[hit] -= 1
        arr.sh_fail[hit] += 1
    elif sc.kind == "rack_degrade":
        # Sick rack switch (§15 net columns): every running reducer
        # hosted in the rack sees its shuffle health sag — transfers
        # stall (inflight drains into failure pressure) and fetched
        # partitions regress, more of them the sicker the uplink — while
        # node clocks and heartbeats stay perfectly healthy. The
        # glance's ζ must attribute this to the rack's fetch plane, not
        # to any single node. (``rack_factor`` documents the scenario on
        # the clone; the assessment-visible perturbation is the
        # severity-scaled shuffle columns.)
        # len(rack_factor) IS the topology's rack count (aliased from
        # the net model) — node_rack.max()+1 would diverge from the
        # live fault path whenever ceil-division leaves trailing racks
        # empty (an empty victim rack perturbs nothing, same as live).
        rack = sc.rack % max(1, len(arr.rack_factor))
        arr.rack_factor[rack] = max(sc.factor, 1e-3)
        severity = 1 + int(sc.factor < 0.05)
        hit = reducing[arr.node_rack[arr.node[reducing]] == rack]
        arr.fetched[hit] = np.maximum(arr.fetched[hit] - severity, 0)
        arr.sh_fail[hit] += severity
        arr.sh_inflight[hit] = 0
    else:  # fetch_quorum: every running reducer regresses one partition
        arr.fetched[reducing] -= 1
        arr.sh_fail[reducing] += 2
        arr.sh_inflight[reducing] = 0


@functools.lru_cache(maxsize=None)
def _sweep_jit(jcap: int):
    import jax
    from repro.accel.jax_backend import assess_summary_core
    step = functools.partial(assess_summary_core, jcap=jcap)
    return jax.jit(jax.vmap(
        step, in_axes=(0, None, None, None, None, None, None, None, None)))


class BatchedSweep:
    """One assessment step × N fault scenarios, on one device dispatch.

    ``prepare`` clones the live snapshot once per scenario and applies
    the perturbation; ``run_batched`` stacks the padded mirrors and
    vmaps the assessment step; ``run_serial`` walks the same clones on
    the numpy backend (the throughput baseline / parity oracle)."""

    def __init__(self, arr: ArraySnapshot, now: float, *,
                 neighborhoods: Optional[np.ndarray] = None,
                 min_runtime: float = 10.0,
                 slow_task_percentile: float = 25.0,
                 win_factor: float = 1.0,
                 fail_threshold: float = 10.0,
                 responsive_window: float = 1.5):
        self.arr = arr
        self.now = float(now)
        n = len(arr.node_ids)
        if neighborhoods is None:
            from repro.core.glance import build_neighborhoods
            neighborhoods = build_neighborhoods(arr.node_ids)
        self.neighborhoods = np.asarray(neighborhoods, dtype=np.int64)
        self.min_runtime = min_runtime
        self.slow_task_percentile = slow_task_percentile
        self.win_factor = win_factor
        self.thresholds = np.full(n, fail_threshold)
        self.declared = np.zeros(n, dtype=bool)
        self.responsive_window = responsive_window
        self.active = arr.active_jobs()
        self.clones: List[ArraySnapshot] = []
        self._stacked: Optional[Dict[str, np.ndarray]] = None
        self._jcap = 0

    # ------------------------------------------------------------------
    def prepare(self, scenarios: Sequence[Scenario]) -> "BatchedSweep":
        self.clones = []
        stacked: Dict[str, List[np.ndarray]] = {}
        jcap = 0
        for sc in scenarios:
            clone = self.arr.clone_for_assessment()
            apply_scenario(clone, sc, self.now)
            self.clones.append(clone)
            dc = DeviceColumns(clone)
            host = dc.refresh(self.active)
            jcap = max(jcap, dc.jcap)
            for k, v in host.items():
                stacked.setdefault(k, []).append(
                    np.asarray(v) if isinstance(v, np.ndarray)
                    else np.asarray(np.int64(v)))
        self._jcap = max(jcap, DeviceColumns.MIN_JOBS)
        self._stacked = {k: np.stack(v) for k, v in stacked.items()}
        N = len(scenarios)
        self._stacked["one"] = np.ones(N)
        self._stacked["sf"] = np.full(N, SHUFFLE_FRACTION)
        return self

    # ------------------------------------------------------------------
    def run_batched(self) -> List[Dict[str, np.ndarray]]:
        """All scenarios in one vmapped device step."""
        assert self._stacked is not None, "call prepare() first"
        import jax.numpy as jnp
        from jax.experimental import enable_x64
        J = len(self.active)
        with enable_x64():
            cols = {k: jnp.asarray(v) for k, v in self._stacked.items()}
            out = _sweep_jit(self._jcap)(
                cols, jnp.asarray(self.neighborhoods),
                jnp.float64(self.now), jnp.float64(self.min_runtime),
                jnp.float64(self.slow_task_percentile),
                jnp.float64(self.win_factor), jnp.asarray(self.declared),
                jnp.asarray(self.thresholds),
                jnp.float64(self.responsive_window))
        host = {k: np.asarray(v) for k, v in out.items()}
        return [
            {
                "spatial_hits": host["spatial_hits"][i][:J],
                "failed": host["failed"][i],
                "late_victims": host["late_victims"][i][:J],
                "winning": host["winning"][i][:J],
                "n_reap": int(host["n_reap"][i]),
            }
            for i in range(len(self.clones))
        ]

    # ------------------------------------------------------------------
    def run_serial(self) -> List[Dict[str, np.ndarray]]:
        """The same clones, one at a time, on the numpy reference — the
        baseline the ≥ 2× sweep gate compares against."""
        assert self.clones, "call prepare() first"
        out = []
        J = len(self.active)
        eligible = np.ones(J, dtype=bool)
        for clone in self.clones:
            b = NumpyBackend()
            hits = b.spatial_hits(clone, self.now, self.active,
                                  self.neighborhoods)
            _resp, cand = b.failure_masks(
                self.now, clone.node_hb, clone.node_marked, self.declared,
                self.thresholds, self.responsive_window)
            victims = b.late_victims(clone, self.now, self.active,
                                     eligible, self.min_runtime,
                                     self.slow_task_percentile)
            winning = np.array(
                [b.winning(clone, self.now, jidx, self.win_factor)
                 for _jid, jidx in self.active], dtype=bool)
            out.append({
                "spatial_hits": hits,
                "failed": cand,
                "late_victims": victims,
                "winning": winning,
                "n_reap": len(b.reap_rows(clone, self.now)),
            })
        return out
