"""The pluggable assessment-compute backend protocol (DESIGN.md §13.1).

Every per-tick dense reduction of the vectorized policies — the Eq. 1
spatial pass, the Eq. 2–3 ζ accumulation, the Eq. 4 responsiveness masks,
LATE's percentile ranking, collective winning and sibling reaping — runs
behind :class:`AssessmentBackend`. The policies keep *all* control flow
and mutable policy state (streaks, ramp rounds, outage histories) on the
host; a backend only turns columnar snapshots into small dense results.

Three implementations ship:

- ``numpy`` (:mod:`repro.accel.numpy_backend`) — the PR-1 columnar path,
  verbatim. The bit-exact reference; zero new dependencies.
- ``jax`` (:mod:`repro.accel.jax_backend`) — jit-compiled kernels over
  padded device mirrors (:class:`repro.core.arrays.DeviceColumns`),
  float64 via a scoped ``enable_x64`` so CPU runs match numpy bit-exactly.
- ``pallas`` (:mod:`repro.accel.pallas_backend`) — hand-written Pallas
  kernels for the two hottest reductions (glance and LATE/collective
  segment passes), ``interpret=True`` by default so CI runs without a
  TPU/GPU.

The equivalence contract — which results are bit-exact and where f32
device math waives exactness — is DESIGN.md §13.3, gated by
``tests/test_accel.py``.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, List, Tuple, Union

import numpy as np

if TYPE_CHECKING:  # pragma: no cover — cycle guard: core imports us back
    from repro.core.arrays import ArraySnapshot

# ArraySnapshot scratch columns holding the Eq. 2 per-attempt sample
# membership (sample mark + ζ at mark). Named here so every backend and
# the glance share one registry slot.
TMARK = "glance_tmark"
TPROG = "glance_tprog"

BACKENDS = ("numpy", "jax", "pallas")


class AssessmentBackend:
    """One assessment tick's dense math. Stateless w.r.t. policy decisions;
    may cache per-tick extractions / device buffers internally (ticks are
    identified by ``now`` — the simulation clock is strictly increasing
    between assessments and state never changes mid-assess)."""

    name: str = "?"

    # -- Eq. 1 ----------------------------------------------------------
    def spatial_hits(self, arr: ArraySnapshot, now: float,
                     active: List[Tuple[str, int]],
                     neighborhoods: np.ndarray) -> np.ndarray:
        """(J, n_nodes) bool: Eq. 1 fired per (active job, node), both
        phases merged — pre-debounce (the streak filter stays host-side).
        """
        raise NotImplementedError

    # -- Eq. 2–3 --------------------------------------------------------
    def temporal_zeta(self, arr: ArraySnapshot, now: float,
                      active: List[Tuple[str, int]],
                      samp_flag: np.ndarray, init_flag: np.ndarray,
                      prevk: np.ndarray
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """Per-(job, node) ζ sums over attempts alive at both Eq. 2
        samples: ``(zeta_now, zeta_prev)``, each (J, n_nodes) float64 with
        NaN where a node hosts no surviving attempt. Also records this
        sample's per-attempt ζ into the TMARK/TPROG scratch columns for
        sampled and newly-seen jobs."""
        raise NotImplementedError

    # -- Eq. 4 ----------------------------------------------------------
    def failure_masks(self, now: float, node_hb: np.ndarray,
                      node_marked: np.ndarray, declared: np.ndarray,
                      thresholds: np.ndarray, responsive_window: float
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """(responsive, newly_failed-candidate) bool masks over nodes.
        Pure elementwise comparisons; the caller owns the lost/declared
        state transitions and outage recording."""
        raise NotImplementedError

    # -- LATE -----------------------------------------------------------
    def late_victims(self, arr: ArraySnapshot, now: float,
                     active: List[Tuple[str, int]], eligible: np.ndarray,
                     min_runtime: float, slow_task_percentile: float
                     ) -> np.ndarray:
        """(J,) int64: per active job, the columnar row of the LATE
        speculation victim, or -1 (no variation / all fast / under the
        candidate floor). Jobs with ``eligible[pos] == False`` may skip
        work; their entry is ignored by the caller."""
        raise NotImplementedError

    # -- collective -----------------------------------------------------
    def winning(self, arr: ArraySnapshot, now: float, job_idx: int,
                win_factor: float) -> bool:
        """True iff any of the job's tasks has a live speculative attempt
        outpacing its original (or running without one)."""
        raise NotImplementedError

    def reap_rows(self, arr: ArraySnapshot, now: float) -> np.ndarray:
        """Canonical-order rows of running attempts whose task completed
        with a finished sibling — the per-tick kill set."""
        raise NotImplementedError


def get_backend(spec: Union[str, AssessmentBackend, None]
                ) -> AssessmentBackend:
    """Resolve a backend name (or pass an instance through). The jax and
    pallas modules import lazily so the numpy path never pays device
    toolchain startup."""
    if isinstance(spec, AssessmentBackend):
        return spec
    name = (spec or "numpy").lower()
    if name == "numpy":
        from repro.accel.numpy_backend import NumpyBackend
        return NumpyBackend()
    if name == "jax":
        from repro.accel.jax_backend import JaxBackend
        return JaxBackend()
    if name == "pallas":
        from repro.accel.pallas_backend import PallasBackend
        return PallasBackend()
    raise ValueError(
        f"unknown assessment backend {spec!r}; expected one of {BACKENDS}")
