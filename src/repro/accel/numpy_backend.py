"""The reference assessment backend: PR 1's columnar numpy reductions,
moved verbatim behind :class:`~repro.accel.base.AssessmentBackend`.

This is the bit-exactness anchor: every op replicates the per-object
reference arithmetic operation-for-operation (DESIGN.md §11.3), and the
jax/pallas backends are in turn gated bit-exact against *this* module
(§13.3, tests/test_accel.py).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.accel.base import TMARK, TPROG, AssessmentBackend
from repro.core import metrics as M
from repro.core.arrays import A_RUNNING, T_RUNNING, ArraySnapshot


class NumpyBackend(AssessmentBackend):
    name = "numpy"

    def __init__(self) -> None:
        # Per-tick memo of the shared running-row extraction (glance
        # spatial + temporal both consume it within one assess call; the
        # clock strictly increases between assessments). Keyed on the
        # snapshot too — a backend instance may be shared across sims.
        self._memo: Tuple[float, Optional[ArraySnapshot], Optional[tuple]] \
            = (np.nan, None, None)

    # ------------------------------------------------------------------
    def _tick(self, arr: ArraySnapshot, now: float,
              active: List[Tuple[str, int]]) -> tuple:
        if self._memo[0] == now and self._memo[1] is arr:
            return self._memo[2]
        rows = arr.running_rows(now)
        prog = arr.progress_at(now, rows)
        jl = arr.job_local_map(active)[arr.job[rows]]
        data = (rows, prog, jl)
        self._memo = (now, arr, data)
        return data

    # -- Eq. 1 ----------------------------------------------------------
    def spatial_hits(self, arr, now, active, neighborhoods):
        rows, prog, jl = self._tick(arr, now, active)
        n = len(arr.node_ids)
        J = len(active)
        fired = np.zeros((J * 2, n), dtype=bool)
        if len(rows):
            rt = np.maximum(now - arr.start[rows], 1e-9)
            rho = prog / rt
            seg = (jl * 2 + arr.kind[rows]) * n + arr.node[rows]
            # bincount accumulates sequentially in input order — the same
            # partial-sum order as the reference append loops.
            sums = np.bincount(seg, weights=rho, minlength=J * 2 * n)
            counts = np.bincount(seg, minlength=J * 2 * n).astype(float)
            with np.errstate(invalid="ignore"):
                P = np.where(counts > 0, sums / np.maximum(counts, 1.0),
                             np.nan).reshape(J * 2, n)
            fired = M.spatial_slow_mask_batch_np(P, neighborhoods)
        return fired.reshape(J, 2, n).any(axis=1)

    # -- Eq. 2–3 --------------------------------------------------------
    def temporal_zeta(self, arr, now, active, samp_flag, init_flag, prevk):
        rows, prog, jl = self._tick(arr, now, active)
        n = len(arr.node_ids)
        J = len(active)
        mark = arr.scratch(TMARK, np.int64, -1)
        tprog = arr.scratch(TPROG, np.float64, np.nan)
        if not len(rows):
            return np.full((J, n), np.nan), np.full((J, n), np.nan)
        # Sampled jobs: ζ sums by (job, node) over attempts alive at both
        # samples, one bincount pass for every job at once.
        smask = samp_flag[jl]
        srows, sprog, sjl = rows[smask], prog[smask], jl[smask]
        alive = mark[srows] == prevk[sjl]
        arows, ajl = srows[alive], sjl[alive]
        seg = ajl * n + arr.node[arows]
        zn = np.bincount(seg, weights=sprog[alive], minlength=J * n)
        zp = np.bincount(seg, weights=tprog[arows], minlength=J * n)
        cnt = np.bincount(seg, minlength=J * n)
        zeta_now = np.where(cnt > 0, zn, np.nan).reshape(J, n)
        zeta_prev = np.where(cnt > 0, zp, np.nan).reshape(J, n)
        # Record this sample's per-attempt ζ (sampled + newly seen jobs).
        wmask = smask | init_flag[jl]
        wrows = rows[wmask]
        newk = np.where(samp_flag, prevk + 1, 0)
        mark[wrows] = newk[jl[wmask]]
        tprog[wrows] = prog[wmask]
        return zeta_now, zeta_prev

    # -- Eq. 4 ----------------------------------------------------------
    def failure_masks(self, now, node_hb, node_marked, declared,
                      thresholds, responsive_window):
        silent = now - node_hb
        resp = silent <= responsive_window
        cand = ~resp & ~declared & ~node_marked & (silent > thresholds)
        return resp, cand

    # -- LATE -----------------------------------------------------------
    def late_victims(self, arr, now, active, eligible, min_runtime,
                     slow_task_percentile):
        victims = np.full(len(active), -1, dtype=np.int64)
        for pos, (_jid, jidx) in enumerate(active):
            if eligible[pos]:
                victims[pos] = self._late_victim(
                    arr, now, jidx, min_runtime, slow_task_percentile)
        return victims

    def _late_victim(self, arr, now, job_idx, min_runtime,
                     slow_task_percentile) -> int:
        m = arr.active[:arr.n] & (arr.job[:arr.n] == job_idx) \
            & (arr.a_state[:arr.n] == A_RUNNING) \
            & (arr.t_state[:arr.n] == T_RUNNING)
        rows = arr.rows_where(m)
        if len(rows) < 2:
            return -1
        # Segment per task (rows are canonical, so task segments are
        # contiguous); per task pick the max-progress running attempt,
        # first-wins on ties — exactly Python's max() over attempt order.
        torder = arr.skey[rows] >> 20
        starts, inv = arr.task_segments(torder)
        has_spec = np.bincount(inv, weights=arr.spec[rows],
                               minlength=len(starts)) > 0
        prog = arr.progress_at(now, rows)
        segmax = np.maximum.reduceat(prog, starts)
        cand = np.flatnonzero(prog == segmax[inv])
        _, first = np.unique(inv[cand], return_index=True)
        best = cand[first]                      # one row-position per task
        ok = ~has_spec & (now - arr.start[rows[best]] >= min_runtime)
        sel = best[ok]
        if len(sel) < 2:
            # LATE needs variation among tasks to rank stragglers — with
            # zero or one candidate there is nothing to compare against
            # (the scope-limited myopia, faithfully reproduced).
            return -1
        p = prog[sel]
        rho = p / np.maximum(now - arr.start[rows[sel]], 1e-9)
        est_remaining = (1.0 - p) / np.maximum(rho, 1e-9)
        thresh = np.percentile(rho, slow_task_percentile)
        slow = np.flatnonzero(rho < thresh)
        if not len(slow):
            return -1
        return int(rows[sel][slow[np.argmax(est_remaining[slow])]])

    # -- collective -----------------------------------------------------
    def winning(self, arr, now, job_idx, win_factor):
        """Per-task max progress rate of original vs speculative running
        attempts, any task wins ⇒ ramp. Boolean-equivalent to the
        reference scan (max is order-free and each rate is computed with
        identical arithmetic)."""
        m = arr.active[:arr.n] & (arr.job[:arr.n] == job_idx) \
            & (arr.a_state[:arr.n] == A_RUNNING)
        rows = arr.rows_where(m)
        if not len(rows) or not arr.spec[rows].any():
            return False
        rate = arr.progress_at(now, rows) \
            / np.maximum(now - arr.start[rows], 1e-9)
        starts, inv = arr.task_segments(arr.skey[rows] >> 20)
        k = len(starts)
        lo = np.full(k, -np.inf)   # max original rate per task
        hi = np.full(k, -np.inf)   # max speculative rate per task
        sp = arr.spec[rows]
        np.maximum.at(hi, inv[sp], rate[sp])
        np.maximum.at(lo, inv[~sp], rate[~sp])
        has_spec = np.bincount(inv, weights=sp, minlength=k) > 0
        has_orig = np.bincount(inv, weights=~sp, minlength=k) > 0
        win = has_spec & (~has_orig | (hi > lo * win_factor))
        return bool(win.any())

    def reap_rows(self, arr, now):
        return arr.reap_rows()
