"""Error-feedback int8 gradient compression (1-bit-Adam-family trick,
Seide et al. / Karimireddy et al.): quantize the gradient to int8 with a
per-tensor scale, carry the quantization residual into the next step. Cuts
DP all-reduce bytes 4× (fp32) / 2× (bf16) while preserving convergence
(the EF residual makes the compounded error bounded).

Integration point: applied to the *accumulated* per-step gradient before the
optimizer (the reduction itself is inserted by XLA SPMD; compressing the
operand shrinks the all-reduce payload accordingly when enabled via
``TrainConfig.grad_compression``).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def ef_state_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_int8_compress(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def error_feedback_step(grads, ef_state):
    """Returns (compressed-then-decompressed grads, new ef_state)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = ef_int8_compress(corrected)
        deq = ef_int8_decompress(q, s)
        return deq.astype(g.dtype), corrected - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_e = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_g, new_e
