"""AdamW in pure JAX (no optax). Moments are fp32 regardless of param dtype;
bf16 params are updated through an fp32 round-trip (no separate fp32 master
copy — the memory/precision trade-off is recorded in DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple, Union

import jax
import jax.numpy as jnp

OptState = Dict[str, Any]


def adamw_init(params) -> OptState:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    grads,
    state: OptState,
    params,
    *,
    lr: Union[float, jax.Array, Callable[[jax.Array], jax.Array]],
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip_norm: float = 1.0,
) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    count = state["count"] + 1
    if callable(lr):
        lr_t = lr(count)
    else:
        lr_t = jnp.asarray(lr, jnp.float32)

    # global-norm clip (fp32 accumulation)
    gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(gsq)
    clip = jnp.minimum(1.0, grad_clip_norm / (gnorm + 1e-12))

    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * clip
        m_new = b1 * m + (1.0 - b1) * gf
        v_new = b2 * v + (1.0 - b2) * jnp.square(gf)
        mhat = m_new / bc1
        vhat = v_new / bc2
        step = mhat / (jnp.sqrt(vhat) + eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr_t * (step + weight_decay * pf)
        return pf.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr_t}
    return new_params, {"m": new_m, "v": new_v, "count": count}, metrics
