from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule, linear_warmup_cosine
from repro.optim.compress import (
    ef_int8_compress,
    ef_int8_decompress,
    ef_state_init,
    error_feedback_step,
)

__all__ = [
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "linear_warmup_cosine",
    "ef_int8_compress",
    "ef_int8_decompress",
    "ef_state_init",
    "error_feedback_step",
]
