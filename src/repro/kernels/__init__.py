"""Pallas TPU kernels for the compute hot-spots, each shipped as a triple:

- ``<name>.py``  -- ``pl.pallas_call`` + explicit BlockSpec VMEM tiling
- ``ops.py``     -- jit'd public wrapper (impl selection, custom_vjp)
- ``ref.py``     -- pure-jnp oracle used for validation and as the
                   autodiff-able fallback path on CPU

Kernels: flash_attention (train/prefill), decode_attention (single-token
query vs long KV), ssd (Mamba-2 chunked state-space dual scan).
"""
