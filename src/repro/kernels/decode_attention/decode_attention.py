"""Pallas TPU decode-attention kernel (one new token vs. a long KV cache).

Design: decode is HBM-bandwidth-bound (the KV cache read dominates), so the
kernel's job is to stream KV blocks through VMEM exactly once while keeping
the whole GQA query group resident. grid = (batch, kv_head, kv_blocks);
the (group × head_dim) query tile and the online-softmax state stay in VMEM
scratch across the sequential kv-block walk. The per-batch valid length
(cache fill level) arrives via scalar-prefetch SMEM so masked tail blocks
contribute zeros.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _decode_kernel(valid_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr,
                   *, scale: float, block_k: int):
    b = pl.program_id(0)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    valid = valid_ref[b]
    q = q_ref[0, 0].astype(jnp.float32)               # (G, d)
    k = k_ref[0, 0].astype(jnp.float32)               # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    s = jnp.where(k_pos < valid, s, NEG_INF)

    m_prev = m_scr[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = jnp.broadcast_to(
        corr * l_scr[:, :1] + jnp.sum(p, axis=1, keepdims=True), l_scr.shape)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_scr[:, :1]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l_safe).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array, kv_valid_len: jax.Array, *,
    scale: Optional[float] = None, block_k: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """q: (b, h, d); k/v: (b, sk, hkv, d); kv_valid_len: (b,) int32."""
    b, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    block_k = min(block_k, sk)
    assert sk % block_k == 0
    if interpret is None:
        interpret = _interpret_default()

    qg = q.reshape(b, hkv, group, d)
    kt = k.transpose(0, 2, 1, 3)                      # (b, hkv, sk, d)
    vt = v.transpose(0, 2, 1, 3)

    grid = (b, hkv, sk // block_k)
    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, group, d), lambda b_, hk, ik, *_: (b_, hk, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, hk, ik, *_: (b_, hk, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, hk, ik, *_: (b_, hk, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d), lambda b_, hk, ik, *_: (b_, hk, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 128), jnp.float32),
            pltpu.VMEM((group, 128), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, d), q.dtype),
        interpret=interpret,
    )(kv_valid_len.astype(jnp.int32), qg, kt, vt)
    return out.reshape(b, hq, d)
