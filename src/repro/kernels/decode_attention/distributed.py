"""Distributed flash decode: sequence-parallel attention over a sharded KV
cache (the §Perf fix for collective-bound decode).

Layout problem it solves: with the KV cache sharded seq→``model`` and query
heads sharded heads→``model``, plain XLA SPMD must all-gather the whole
cache on every layer (and re-shard the scatter writeback) — ~19 GB/token
per device for granite-20b decode_32k. But softmax is an online
reduction: each model-shard can attend over its LOCAL seq chunk and emit
``(o_partial, lse_partial)``; combining across shards costs
``heads × (head_dim + 1)`` floats per sequence — five orders of magnitude
less traffic.

Under ``shard_map`` (over the ``model`` axis):
  1. the token's K/V is written into the ONE local chunk that owns
     position ``pos`` (masked dynamic-update — no resharding);
  2. each shard runs the decode kernel/oracle over its chunk with a
     per-shard valid length clip(pos+1 − chunk_start, 0, chunk);
  3. partials merge with the standard online-softmax combine via
     ``jax.lax.all_gather`` over the axis.

Heads stay replicated across the model axis inside this op (they ride
batch/data outside); the cache is the thing worth sharding at 32k–500k
context.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.decode_attention import ref as _ref


def _local_attend(q, k, v, valid, scale):
    """Partial attention over a local chunk → (o, lse); safe when valid==0.
    q: (b, h, d); k/v: (b, c, kv, d); valid: (b,) int32.

    GQA/MQA via a grouped einsum — NEVER ``jnp.repeat`` the cache: at
    kv=1 / 48 q-heads that materializes 48× the cache bytes and turns the
    whole op memory-bound (measured: 169 GB/device on granite decode)."""
    b, h, d = q.shape
    _, c, kvh, _ = k.shape
    group = h // kvh
    qg = q.reshape(b, kvh, group, d)
    # MXU-native mixed precision: bf16 operands, f32 accumulation — no
    # materialized f32 copy of the cache chunk.
    logits = jnp.einsum("bkgd,bckd->bkgc", qg, k,
                        preferred_element_type=jnp.float32) * scale
    mask = (jnp.arange(c)[None, None, None, :]
            < valid[:, None, None, None])
    logits = jnp.where(mask, logits, -1e30)
    m = jnp.max(logits, axis=-1)                       # (b, kv, g)
    p = jnp.exp(logits - m[..., None])
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1)                            # (b, kv, g)
    # standard flash practice: PV in bf16 with f32 accumulation
    o = jnp.einsum("bkgc,bckd->bkgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    l_safe = jnp.maximum(l, 1e-30)
    lse = jnp.where(l > 0, m + jnp.log(l_safe), -jnp.inf)
    return (o / l_safe[..., None]).reshape(b, h, d), \
        lse.reshape(b, h)


def dist_decode_update_attend(
    q: jax.Array,            # (b, h, d)
    new_k: jax.Array,        # (b, kv, d) this token's key
    new_v: jax.Array,        # (b, kv, d)
    cache_k: jax.Array,      # (b, S, kv, d) seq-sharded over `axis`
    cache_v: jax.Array,
    pos: jax.Array,          # (b,) write position (== tokens so far)
    *,
    axis: str = "model",
    mesh=None,
    scale: Optional[float] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (attn_out (b,h,d), new_cache_k, new_cache_v).

    Must run under a mesh containing ``axis``; cache_k/v are expected
    sharded P(batch_axes, axis, None, None).
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    if mesh is None:
        from repro.parallel.sharding import current_mesh
        mesh = current_mesh()
    n_shards = mesh.shape[axis]
    S = cache_k.shape[1]
    chunk = S // n_shards
    # batch stays wherever the rule table puts it (data/pod); only the
    # cache seq dim rides `axis` inside this op. q arrives heads-sharded
    # over `axis` from the projection — the implied gather is b×h×d bytes,
    # noise next to the cache traffic this op eliminates.
    from repro.parallel.sharding import _current_rules, physical_spec
    _, act_rules = _current_rules()
    bspec = physical_spec((q.shape[0],), ("batch",),
                          act_rules, mesh)[0]

    def body(q, nk, nv, ck, cv, pos):
        idx = jax.lax.axis_index(axis)
        start = idx * chunk
        # 1. local masked writeback of the new token
        local = pos - start                          # (b,)
        in_range = (local >= 0) & (local < chunk)
        li = jnp.clip(local, 0, chunk - 1)
        bidx = jnp.arange(q.shape[0])
        ck_new = ck.at[bidx, li].set(
            jnp.where(in_range[:, None, None], nk, ck[bidx, li]))
        cv_new = cv.at[bidx, li].set(
            jnp.where(in_range[:, None, None], nv, cv[bidx, li]))
        # 2. partial attention over the local chunk
        valid = jnp.clip(pos + 1 - start, 0, chunk)
        o, lse = _local_attend(q, ck_new, cv_new, valid, scale)
        # 3. online-softmax combine across shards via psum (an all-gather
        # would move n× these bytes; the reduction form is the minimum)
        m = jax.lax.pmax(lse, axis)                  # (b, h)
        w = jnp.exp(lse - m)
        w = jnp.where(jnp.isfinite(w), w, 0.0)       # empty shard → 0
        num = jax.lax.psum(o * w[..., None], axis)   # (b, h, d)
        den = jnp.maximum(jax.lax.psum(w, axis), 1e-30)
        out = num / den[..., None]
        return out.astype(q.dtype), ck_new, cv_new

    pspec_cache = P(bspec, axis, None, None)
    pspec_bhd = P(bspec, None, None)
    specs = dict(
        mesh=mesh,
        in_specs=(pspec_bhd, pspec_bhd, pspec_bhd,
                  pspec_cache, pspec_cache, P(bspec)),
        out_specs=(pspec_bhd, pspec_cache, pspec_cache))
    if hasattr(jax, "shard_map"):  # jax ≥ 0.6
        mapped = jax.shard_map(body, check_vma=False, **specs)
    else:  # older jax: same semantics under the experimental name
        from jax.experimental.shard_map import shard_map as _shard_map
        mapped = _shard_map(body, check_rep=False, **specs)
    return mapped(q, new_k, new_v, cache_k, cache_v, pos)


def reference(q, new_k, new_v, cache_k, cache_v, pos, *, scale=None):
    """Oracle: plain update + full decode attention."""
    b = q.shape[0]
    bidx = jnp.arange(b)
    ck = cache_k.at[bidx, pos].set(new_k)
    cv = cache_v.at[bidx, pos].set(new_v)
    out = _ref.decode_attention_reference(q, ck, cv, pos + 1, scale=scale)
    return out, ck, cv
