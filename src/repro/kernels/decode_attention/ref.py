"""Oracle for single-token decode attention against a (partially filled)
KV cache. q: (batch, n_heads, head_dim); k/v: (batch, kv_len, n_kv_heads,
head_dim); kv_valid_len: (batch,) int32. Returns (batch, n_heads, head_dim).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ref import attention_reference


def decode_attention_reference(q, k, v, kv_valid_len, *,
                               scale: Optional[float] = None) -> jax.Array:
    out = attention_reference(
        q[:, None], k, v, causal=False, scale=scale,
        kv_valid_len=kv_valid_len)
    return out[:, 0]
