"""Public decode-attention op (forward-only: serving path, no grads)."""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.decode_attention import ref as _ref
from repro.kernels.decode_attention import decode_attention as _dec


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


def decode_attention(q, k, v, kv_valid_len, *, scale: Optional[float] = None,
                     impl: str = "ref", block_k: int = 512) -> jax.Array:
    """q: (b, h, d) single-token queries; k/v: (b, sk, hkv, d) cache."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.decode_attention_reference(q, k, v, kv_valid_len,
                                               scale=scale)
    return _dec.decode_attention_pallas(q, k, v, kv_valid_len, scale=scale,
                                        block_k=block_k)
