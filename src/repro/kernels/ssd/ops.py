"""Public SSD op: impl selection + custom_vjp.

For ``impl="pallas"`` the forward runs the Pallas kernel; the backward is
the VJP of the jnp oracle (identical math, so gradients are exact w.r.t.
the reference semantics). A hand-written backward kernel is a possible
future perf iteration — recorded in EXPERIMENTS.md §Perf candidates.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax

from repro.kernels.ssd import ref as _ref
from repro.kernels.ssd import ssd as _ssd


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def _pallas_ssd(x, dt, A, B, C, D, chunk):
    y, _ = _ssd.ssd_pallas(x, dt, A, B, C, D, chunk=chunk)
    return y


def _pallas_ssd_fwd(x, dt, A, B, C, D, chunk):
    y, _ = _ssd.ssd_pallas(x, dt, A, B, C, D, chunk=chunk)
    return y, (x, dt, A, B, C, D)


def _pallas_ssd_bwd(chunk, res, dy):
    x, dt, A, B, C, D = res
    _, vjp = jax.vjp(
        lambda *a: _ref.ssd_reference(*a, chunk=chunk)[0], x, dt, A, B, C, D)
    return vjp(dy)


_pallas_ssd.defvjp(_pallas_ssd_fwd, _pallas_ssd_bwd)


def ssd(x, dt, A, B, C, D, *, chunk: int = 128,
        impl: str = "ref") -> jax.Array:
    """Chunked SSD scan; returns y with x.shape (state discarded)."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.ssd_reference(x, dt, A, B, C, D, chunk=chunk)[0]
    return _pallas_ssd(x, dt, A, B, C, D, chunk)


def ssd_with_state(x, dt, A, B, C, D, *, chunk: int = 128,
                   impl: str = "ref") -> Tuple[jax.Array, jax.Array]:
    """Prefill entry point: returns (y, final_state) for decode handoff."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.ssd_reference(x, dt, A, B, C, D, chunk=chunk)
    return _ssd.ssd_pallas(x, dt, A, B, C, D, chunk=chunk)


ssd_decode_step = _ref.ssd_decode_step
