"""Pure-jnp oracle for the Mamba-2 SSD (state-space dual) chunked scan.

Convention (matches the Pallas kernel and ``models/mamba2.py``)::

    x  : (batch, seq, n_heads, head_dim)   -- pre-gated SSM input
    dt : (batch, seq, n_heads)             -- positive step sizes (softplus'd)
    A  : (n_heads,)                        -- negative decay rates
    B  : (batch, seq, n_groups, d_state)
    C  : (batch, seq, n_groups, d_state)
    D  : (n_heads,)                        -- skip connection

Returns (y, final_state) with y: x.shape and final_state:
(batch, n_heads, head_dim, d_state) — the recurrent state handed to decode.

Semantics are the discretized SSM recurrence
``h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ; y_t = C_t·h_t + D x_t``,
evaluated chunk-wise: quadratic attention-like intra-chunk term plus an
inter-chunk state recurrence (the "dual form", arXiv:2405.21060).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _chunk_body(state, inputs, *, A, D):
    """One chunk of the SSD dual form. state: (B, H, P, N) f32."""
    x, dt, Bm, Cm = inputs  # (B,Q,H,P), (B,Q,H), (B,Q,H,N), (B,Q,H,N)
    a = dt * A[None, None, :]                      # (B,Q,H) log-decay
    a_cs = jnp.cumsum(a, axis=1)                   # inclusive cumsum
    # intra-chunk ("diagonal") term: causal decay-weighted attention
    # L[s->l] = exp(a_cs[l] - a_cs[s]) for s <= l
    seg = a_cs[:, :, None, :] - a_cs[:, None, :, :]        # (B,l,s,H)
    q = jnp.arange(x.shape[1])
    causal = (q[:, None] >= q[None, :])[None, :, :, None]
    # mask BEFORE exp: the anti-causal branch has positive seg that can
    # overflow to inf, and where(…, inf, 0) still poisons the gradient
    L = jnp.where(causal, jnp.exp(jnp.where(causal, seg, 0.0)), 0.0)
    scores = jnp.einsum("blhn,bshn->blsh", Cm, Bm) * L      # (B,l,s,H)
    xdt = x * dt[..., None]
    y_diag = jnp.einsum("blsh,bshp->blhp", scores, xdt)

    # inter-chunk: contribution of the carried state
    decay_out = jnp.exp(a_cs)                               # (B,Q,H)
    y_off = jnp.einsum("blhn,bhpn->blhp", Cm, state) * decay_out[..., None]

    # state update for the next chunk
    total = a_cs[:, -1, :]                                  # (B,H)
    decay_in = jnp.exp(total[:, None, :] - a_cs)            # (B,Q,H)
    chunk_state = jnp.einsum("bshn,bshp->bhpn", Bm * (dt * decay_in)[..., None], x)
    new_state = state * jnp.exp(total)[:, :, None, None] + chunk_state

    y = y_diag + y_off + D[None, None, :, None] * x
    return new_state, y


def ssd_reference(
    x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array, C: jax.Array,
    D: jax.Array, *, chunk: int = 64,
    initial_state: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array]:
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert h % g == 0
    rep = h // g
    # broadcast groups to heads
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    chunk = min(chunk, s)
    if s % chunk:
        # zero-pad the tail: dt=0 ⇒ exp(0)=1 decay (state preserved) and a
        # zero input contribution, so padding is exactly identity.
        pad = chunk - s % chunk
        xf = jnp.pad(xf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dtf = jnp.pad(dtf, ((0, 0), (0, pad), (0, 0)))
        Bh = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Ch = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = xf.shape[1] // chunk

    def split(z):
        return z.reshape(b, nc, chunk, *z.shape[2:]).swapaxes(0, 1)

    xs = (split(xf), split(dtf), split(Bh), split(Ch))
    state0 = (jnp.zeros((b, h, p, n), jnp.float32)
              if initial_state is None else initial_state.astype(jnp.float32))
    import functools
    final_state, ys = jax.lax.scan(
        functools.partial(_chunk_body, A=A.astype(jnp.float32),
                          D=D.astype(jnp.float32)),
        state0, xs)
    y = ys.swapaxes(0, 1).reshape(b, nc * chunk, h, p)[:, :s].astype(x.dtype)
    return y, final_state


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t, D):
    """Single-token recurrence. state: (B,H,P,N); x_t: (B,H,P);
    dt_t: (B,H); B_t/C_t: (B,G,N). Returns (new_state, y_t)."""
    h = x_t.shape[1]
    g = B_t.shape[1]
    rep = h // g
    Bh = jnp.repeat(B_t, rep, axis=1).astype(jnp.float32)   # (B,H,N)
    Ch = jnp.repeat(C_t, rep, axis=1).astype(jnp.float32)
    dtf = dt_t.astype(jnp.float32)
    xf = x_t.astype(jnp.float32)
    decay = jnp.exp(dtf * A[None, :])                        # (B,H)
    new_state = (state * decay[..., None, None]
                 + jnp.einsum("bhn,bhp->bhpn", Bh * dtf[..., None], xf))
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch) + D[None, :, None] * xf
    return new_state, y.astype(x_t.dtype)
