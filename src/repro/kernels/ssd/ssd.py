"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

TPU-native adaptation (DESIGN.md §8): the chunk recurrence maps onto the
*sequential* TPU grid — grid = (batch, head, n_chunks) with the recurrent
(head_dim × d_state) state living in VMEM scratch across chunk iterations.
Each grid step computes the quadratic intra-chunk term on the MXU
((chunk × chunk) decay-masked scores) plus the rank-N inter-chunk
correction, then advances the state. Chunk=128–256 keeps every operand
128-aligned for the MXU.

Inputs are pre-arranged per head so the kernel never sees the group
broadcast: B/C arrive group-indexed via their BlockSpec index maps.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _ssd_kernel(a_ref, d_ref, x_ref, dt_ref, b_ref, c_ref,
                y_ref, fs_ref, state_scr,
                *, chunk: int):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    A_h = a_ref[0]                                   # scalar decay rate
    D_h = d_ref[0]
    x = x_ref[0, 0, 0].astype(jnp.float32)           # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)         # (Q, 1) padded lane dim
    Bm = b_ref[0, 0, 0].astype(jnp.float32)          # (Q, N)
    Cm = c_ref[0, 0, 0].astype(jnp.float32)          # (Q, N)

    a = dt[:, 0] * A_h                               # (Q,)
    a_cs = jnp.cumsum(a)                             # (Q,)

    # intra-chunk decay-masked scores
    seg = a_cs[:, None] - a_cs[None, :]              # (Q, Q) l - s
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(row >= col, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * L       # (Q, Q)
    xdt = x * dt                                      # (Q, P)
    y = jax.lax.dot_general(scores, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # carried-state contribution: (Q,N) @ (N,P)
    decay_out = jnp.exp(a_cs)[:, None]                # (Q, 1)
    y += jax.lax.dot_general(Cm, state_scr[...], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32) * decay_out

    # state update: (N,Q) @ (Q,P) -> (N,P)
    total = a_cs[chunk - 1]
    decay_in = jnp.exp(total - a_cs)[:, None]         # (Q, 1)
    chunk_state = jax.lax.dot_general(
        Bm * (dt * decay_in), x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # (N, P)
    state_scr[...] = state_scr[...] * jnp.exp(total) + chunk_state

    y_ref[0, 0, 0] = (y + D_h * x).astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _finish():
        fs_ref[0, 0] = state_scr[...].astype(fs_ref.dtype)


def ssd_pallas(
    x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array, C: jax.Array,
    D: jax.Array, *, chunk: int = 128,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Same contract as ``ref.ssd_reference`` (zero initial state)."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert h % g == 0
    rep = h // g
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    if interpret is None:
        interpret = _interpret_default()

    # head-major chunked layouts
    xh = x.transpose(0, 2, 1, 3).reshape(b, h, nc, chunk, p)
    dth = dt.transpose(0, 2, 1).reshape(b, h, nc, chunk, 1)
    Bg = B.transpose(0, 2, 1, 3).reshape(b, g, nc, chunk, n)
    Cg = C.transpose(0, 2, 1, 3).reshape(b, g, nc, chunk, n)

    grid = (b, h, nc)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, fstate = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b_, h_, ic: (h_,)),          # A
            pl.BlockSpec((1,), lambda b_, h_, ic: (h_,)),          # D
            pl.BlockSpec((1, 1, 1, chunk, p), lambda b_, h_, ic: (b_, h_, ic, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, 1), lambda b_, h_, ic: (b_, h_, ic, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, n),
                         lambda b_, h_, ic, r=rep: (b_, h_ // r, ic, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, n),
                         lambda b_, h_, ic, r=rep: (b_, h_ // r, ic, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p), lambda b_, h_, ic: (b_, h_, ic, 0, 0)),
            pl.BlockSpec((1, 1, n, p), lambda b_, h_, ic: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, nc, chunk, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(A.astype(jnp.float32), D.astype(jnp.float32), xh, dth, Bg, Cg)

    y = y.reshape(b, h, s, p).transpose(0, 2, 1, 3)
    # ref convention: final_state (b, h, p, n)
    return y, fstate.transpose(0, 1, 3, 2)
