"""Public attention op: impl selection + custom_vjp wiring.

``impl``:
- ``"ref"``    — pure-jnp oracle (autodiff-able; the CPU/test default)
- ``"pallas"`` — Pallas TPU kernels (fwd + bwd), interpret=True off-TPU
- ``"auto"``   — pallas on TPU, ref elsewhere
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ref as _ref
from repro.kernels.flash_attention import flash_attention as _fa


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _pallas_attention(q, k, v, causal, window, scale, block_q, block_k):
    out, _ = _fa.flash_attention_fwd(
        q, k, v, causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k)
    return out


def _pallas_fwd(q, k, v, causal, window, scale, block_q, block_k):
    out, lse = _fa.flash_attention_fwd(
        q, k, v, causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k)
    return out, (q, k, v, out, lse)


def _pallas_bwd(causal, window, scale, block_q, block_k, res, do):
    q, k, v, out, lse = res
    dq, dk, dv = _fa.flash_attention_bwd(
        q, k, v, out, lse, do, causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k)
    return dq, dk, dv


_pallas_attention.defvjp(_pallas_fwd, _pallas_bwd)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
    kv_valid_len: Optional[jax.Array] = None,
    impl: str = "ref",
    block_q: int = 128,
    block_k: int = 512,
) -> jax.Array:
    """(b, sq, h, d) × (b, sk, hkv, d)² → (b, sq, h, d)."""
    impl = _resolve(impl)
    if impl == "ref" or kv_valid_len is not None:
        # the cache-masked decode path goes through the oracle (the
        # dedicated decode kernel lives in kernels/decode_attention)
        return _ref.attention_reference(
            q, k, v, causal=causal, window=window, scale=scale,
            kv_valid_len=kv_valid_len)
    if scale is None:
        scale = q.shape[-1] ** -0.5
    return _pallas_attention(q, k, v, causal, window, scale, block_q, block_k)
