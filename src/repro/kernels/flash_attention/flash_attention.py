"""Pallas TPU flash attention (forward + backward), GQA-aware.

TPU-native design notes (DESIGN.md §8):
- grid iterations on TPU execute *sequentially*; the innermost grid dim
  walks KV blocks while VMEM scratch (m, l, acc) carries the online-softmax
  state across iterations — the TPU analogue of the CUDA inner loop.
- BlockSpecs stage (block_q × head_dim) / (block_k × head_dim) tiles into
  VMEM; block sizes default to 128/512 — multiples of the 128-wide MXU/VPU
  lanes.
- GQA is folded into the index maps (`h // group` on the KV operands), so
  no materialized `jnp.repeat` of K/V ever reaches HBM.
- backward = two kernels (dKV with Q innermost, dQ with KV innermost) so
  every output block is written by consecutive grid steps only (TPU output
  revisit rule); the GQA group dim rides the grid between the KV-block and
  Q-block dims of the dKV kernel and is reduced in VMEM scratch.

Validated in interpret mode on CPU against ``ref.attention_reference``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------
def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                m_scr, l_scr, acc_scr,
                *, scale: float, causal: bool, window: int,
                block_q: int, block_k: int, kv_len: int, q_offset: int):
    ik = pl.program_id(3)
    iq = pl.program_id(2)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) + q_offset
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # Static skip of fully-masked blocks: the causal upper triangle and,
    # with a sliding window, blocks entirely below the band (their -inf
    # rows would otherwise produce exp(-inf - -inf) = NaN).
    run = True
    if causal:
        run = (ik * block_k) <= (iq * block_q + q_offset + block_q - 1)
    if window:
        live = ((ik + 1) * block_k - 1) > (iq * block_q + q_offset - window)
        run = live if run is True else (run & live)

    @pl.when(run if (causal or window) else True)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)

        mask = None
        if causal:
            mask = k_pos <= q_pos
        if window:
            w = k_pos > (q_pos - window)
            mask = w if mask is None else (mask & w)
        if mask is not None:
            s = jnp.where(mask, s, -1e30)  # finite: keeps online softmax NaN-free

        m_prev = m_scr[:, :1]                         # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                        # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                # (bq, 1)
        l_new = corr * l_scr[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_scr[:, :1]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l_safe).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_scr[:, 0] + jnp.log(l_safe[:, 0])).astype(lse_ref.dtype)


def flash_attention_fwd(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: int = 0, scale: Optional[float] = None,
    block_q: int = 128, block_k: int = 512,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (out (b, sq, h, d), lse (b, h, sq) float32)."""
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, block_q, sk, block_k)
    if interpret is None:
        interpret = _interpret_default()

    # (b, s, h, d) -> (b, h, s, d) blocks
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    grid = (b, hq, sq // block_q, sk // block_k)
    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, kv_len=sk, q_offset=sk - sq)

    out, lse = _fwd_call(kernel, grid, b, hq, sq, sk, d, block_q, block_k,
                         group, qt, kt, vt, q.dtype, interpret)
    return out.transpose(0, 2, 1, 3), lse


def _fwd_call(kernel, grid, b, hq, sq, sk, d, block_q, block_k, group,
              qt, kt, vt, out_dtype, interpret):
    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, iq, ik, g=group: (b_, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, iq, ik, g=group: (b_, h // g, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b_, h, iq, ik: (b_, h, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, sq, d), out_dtype),
            jax.ShapeDtypeStruct((b, hq, sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),   # m
            pltpu.VMEM((block_q, 128), jnp.float32),   # l
            pltpu.VMEM((block_q, d), jnp.float32),     # acc
        ],
        interpret=interpret,
    )(qt, kt, vt)


# ---------------------------------------------------------------------------
# Backward: dKV kernel (grid: b, kv_head, kv_block, group, q_block)
# ---------------------------------------------------------------------------
def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_scr, dv_scr,
                *, scale: float, causal: bool, window: int,
                block_q: int, block_k: int, q_offset: int):
    ikv = pl.program_id(2)
    g = pl.program_id(3)
    iq = pl.program_id(4)
    ng = pl.num_programs(3)
    nq = pl.num_programs(4)

    @pl.when((g == 0) & (iq == 0))
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    run = True
    if causal:
        run = (ikv * block_k) <= (iq * block_q + q_offset + block_q - 1)
    if window:
        live = ((ikv + 1) * block_k - 1) > (iq * block_q + q_offset - window)
        run = live if run is True else (run & live)

    @pl.when(run if (causal or window) else True)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)       # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)       # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)     # (bq, d)
        lse = lse_ref[0, 0].astype(jnp.float32)   # (bq,)
        delta = delta_ref[0, 0].astype(jnp.float32)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0) + q_offset
        k_pos = ikv * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = None
        if causal:
            mask = k_pos <= q_pos
        if window:
            w = k_pos > (q_pos - window)
            mask = w if mask is None else (mask & w)
        p = jnp.exp(s - lse[:, None])
        if mask is not None:
            p = jnp.where(mask, p, 0.0)

        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)          # (bk, d)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (bq, bk)
        ds = p * (dp - delta[:, None]) * scale
        dk_scr[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)          # (bk, d)

    @pl.when((g == ng - 1) & (iq == nq - 1))
    def _finish():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


# ---------------------------------------------------------------------------
# Backward: dQ kernel (grid: b, head, q_block, kv_block)
# ---------------------------------------------------------------------------
def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
               dq_ref, dq_scr,
               *, scale: float, causal: bool, window: int,
               block_q: int, block_k: int, q_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    run = True
    if causal:
        run = (ik * block_k) <= (iq * block_q + q_offset + block_q - 1)
    if window:
        live = ((ik + 1) * block_k - 1) > (iq * block_q + q_offset - window)
        run = live if run is True else (run & live)

    @pl.when(run if (causal or window) else True)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0].astype(jnp.float32)
        delta = delta_ref[0, 0].astype(jnp.float32)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0) + q_offset
        k_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = None
        if causal:
            mask = k_pos <= q_pos
        if window:
            w = k_pos > (q_pos - window)
            mask = w if mask is None else (mask & w)
        p = jnp.exp(s - lse[:, None])
        if mask is not None:
            p = jnp.where(mask, p, 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dq_scr[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finish():
        dq_ref[0, 0] = dq_scr[...].astype(dq_ref.dtype)


def flash_attention_bwd(
    q, k, v, out, lse, do, *,
    causal: bool = True, window: int = 0, scale: Optional[float] = None,
    block_q: int = 128, block_k: int = 512,
    interpret: Optional[bool] = None,
):
    from jax.experimental.pallas import tpu as pltpu

    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    if interpret is None:
        interpret = _interpret_default()
    q_offset = sk - sq

    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1).transpose(0, 2, 1)  # (b, h, sq)

    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    dot = do.transpose(0, 2, 1, 3)

    # --- dK/dV: group dim on the grid, reduced in scratch -----------------
    grid_kv = (b, hkv, sk // block_k, group, sq // block_q)
    dkv_kernel = functools.partial(
        _dkv_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, q_offset=q_offset)
    dk_t, dv_t = pl.pallas_call(
        dkv_kernel,
        grid=grid_kv,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, hk, ikv, g, iq, G=group: (b_, hk * G + g, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, hk, ikv, g, iq: (b_, hk, ikv, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, hk, ikv, g, iq: (b_, hk, ikv, 0)),
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, hk, ikv, g, iq, G=group: (b_, hk * G + g, iq, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b_, hk, ikv, g, iq, G=group: (b_, hk * G + g, iq)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b_, hk, ikv, g, iq, G=group: (b_, hk * G + g, iq)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, hk, ikv, g, iq: (b_, hk, ikv, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, hk, ikv, g, iq: (b_, hk, ikv, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, sk, d), k.dtype),
            jax.ShapeDtypeStruct((b, hkv, sk, d), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, d), jnp.float32),
            pltpu.VMEM((block_k, d), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt, dot, lse, delta)

    # --- dQ ----------------------------------------------------------------
    grid_q = (b, hq, sq // block_q, sk // block_k)
    dq_kernel = functools.partial(
        _dq_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, q_offset=q_offset)
    dq_t = pl.pallas_call(
        dq_kernel,
        grid=grid_q,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, iq, ik, g=group: (b_, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, iq, ik, g=group: (b_, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b_, h, iq, ik: (b_, h, iq)),
            pl.BlockSpec((1, 1, block_q), lambda b_, h, iq, ik: (b_, h, iq)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype)],
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        interpret=interpret,
    )(qt, kt, vt, dot, lse, delta)[0]

    return (dq_t.transpose(0, 2, 1, 3),
            dk_t.transpose(0, 2, 1, 3),
            dv_t.transpose(0, 2, 1, 3))
