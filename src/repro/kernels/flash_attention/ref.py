"""Pure-jnp oracle for (GQA) attention. Shapes follow the framework-wide
convention::

    q: (batch, q_len, n_heads, head_dim)
    k: (batch, kv_len, n_kv_heads, head_dim)
    v: (batch, kv_len, n_kv_heads, head_dim)

``n_heads`` must be a multiple of ``n_kv_heads`` (GQA broadcast). Masking:
``causal`` lower-triangular (offset so the last q row attends to the last kv
row — supports decode where q_len < kv_len), optional sliding ``window``,
optional ``kv_valid_len`` for decode against a partially filled cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def _mask(q_len: int, kv_len: int, causal: bool, window: int,
          kv_valid_len: Optional[jax.Array]) -> Optional[jax.Array]:
    rows = jnp.arange(q_len)[:, None] + (kv_len - q_len)  # global q positions
    cols = jnp.arange(kv_len)[None, :]
    m = None
    if causal:
        m = cols <= rows
    if window:
        w = cols > (rows - window)
        m = w if m is None else (m & w)
    if kv_valid_len is not None:
        valid = cols < kv_valid_len  # may broadcast (batch,1,1,kv)
        m = valid if m is None else (m & valid)
    return m


def attention_reference(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
    kv_valid_len: Optional[jax.Array] = None,
) -> jax.Array:
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5

    # broadcast kv heads across the query group
    k = jnp.repeat(k, group, axis=2)
    v = jnp.repeat(v, group, axis=2)

    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32)
    logits = logits.astype(jnp.float32) * scale

    if kv_valid_len is not None and kv_valid_len.ndim == 1:
        kv_valid_len = kv_valid_len[:, None, None, None]
    m = _mask(sq, sk, causal, window,
              kv_valid_len if kv_valid_len is not None else None)
    if m is not None:
        logits = jnp.where(m, logits, -jnp.inf)

    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out.astype(q.dtype)


def attention_reference_with_lse(q, k, v, *, causal=True, window=0,
                                 scale=None):
    """Reference that also returns the per-row logsumexp (used to validate
    the Pallas forward's saved statistics)."""
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    k = jnp.repeat(k, group, axis=2)
    v = jnp.repeat(v, group, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    m = _mask(sq, sk, causal, window, None)
    if m is not None:
        logits = jnp.where(m, logits, -jnp.inf)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)  # (b, h, q)
    probs = jnp.exp(logits - lse[..., None])
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out.astype(q.dtype), lse
