"""Batched serving example: continuous-batching decode over a prefill-built
KV/SSM cache, with per-request lengths and throughput reporting.

    PYTHONPATH=src python examples/serve.py --arch mamba2-2.7b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.models import model as MODEL
from repro.train.loop import TrainConfig, make_serve_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    if cfg.is_encoder_only():
        raise SystemExit(f"{args.arch} is encoder-only: no decode path")
    tc = TrainConfig()
    b, p, g = args.requests, args.prompt_len, args.gen_len
    max_len = p + g

    key = jax.random.PRNGKey(0)
    params = MODEL.init_params(cfg, key)
    prompts = jax.random.randint(key, (b, p), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch = {"tokens": prompts,
                 "feats": jnp.zeros((b, cfg.frontend.n_prefix,
                                     cfg.frontend.feature_dim), jnp.float32)}

    t0 = time.time()
    logits, cache = MODEL.prefill(cfg, params, batch, max_len=max_len)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    print(f"prefill: {b} requests × {p} tokens in {t_prefill:.2f}s "
          f"(incl. compile)")

    serve = jax.jit(make_serve_step(cfg, tc))
    tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    pos = jnp.full((b,), p, jnp.int32)
    out = [np.asarray(tokens)]
    t0 = time.time()
    for i in range(g - 1):
        logits, cache = serve(params, cache, tokens, pos)
        tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos = pos + 1
        out.append(np.asarray(tokens))
    jax.block_until_ready(tokens)
    dt = time.time() - t0
    print(f"decode: {b}×{g - 1} tokens in {dt:.2f}s "
          f"→ {b * (g - 1) / dt:.1f} tok/s (batched, incl. compile)")
    gen = np.stack(out, axis=1)
    print("sample generation (token ids):", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
