"""Chaos-hardened runtime demo: drive the live coordinator under a
declarative fault script and validate every committed model update.

Runs the same control plane as ``examples/train_lm.py`` but against the
chaos plane (DESIGN.md §16): pick a recovery policy, pick a fault script
(a named pinned script or an inline ``kind:victim:x:y,...`` spec — the
same vocabulary ``sim/faults.py`` interprets), and the process exits
non-zero if any committed update is corrupted (non-finite parameters or
loss) or a step wedges past its retries.

    PYTHONPATH=src python examples/serve.py --policy bino --chaos crash
    PYTHONPATH=src python examples/serve.py --policy restart \
        --chaos "drop:1:0.1:0.5,dup:0:0.05:0.9" --steps 6

Exit codes: 0 ok, 2 corrupted model update, 3 wedged (retries exhausted).
"""
from __future__ import annotations

import argparse
import sys

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.runtime import (
    ChaosController,
    RuntimeConfig,
    StepWedged,
    TrainerRuntime,
    parse_script,
)
from repro.runtime.chaos import PINNED_SCRIPTS
from repro.train.loop import TrainConfig


def _update_corrupted(trainer) -> bool:
    for leaf in jax.tree.leaves(trainer.state["params"]):
        if not np.all(np.isfinite(np.asarray(leaf))):
            return True
    return False


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--hosts", type=int, default=4)
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--policy", default="bino", choices=["bino", "restart"])
    ap.add_argument("--chaos", default=None, metavar="SCRIPT",
                    help="named pinned script (%s) or inline "
                         "kind:victim:x:y[,...]" % ", ".join(PINNED_SCRIPTS))
    ap.add_argument("--horizon", type=float, default=20.0,
                    help="chaos horizon in seconds (x/y map into it)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record the run with the flight recorder and "
                         "export a Chrome/Perfetto trace (DESIGN.md §18; "
                         "see examples/TRACES.md)")
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    chaos = (ChaosController(parse_script(args.chaos),
                             horizon=args.horizon, seed=args.seed)
             if args.chaos else None)
    recorder = None
    if args.trace:
        from repro.obs import TraceRecorder
        # chaos emits fault markers from its own scheduler thread
        recorder = TraceRecorder(thread_safe=True)
    rt = RuntimeConfig(
        n_hosts=args.hosts, microbatches_per_shard=args.microbatches,
        recovery=args.policy, compute_delay=0.02,
        repair_timeout=1.0, restart_timeout=3.0)
    trainer = TrainerRuntime(cfg, TrainConfig(), rt,
                             seq_len=args.seq_len, per_shard_batch=2,
                             seed=args.seed, chaos=chaos, obs=recorder)
    print(f"policy={args.policy} hosts={args.hosts} "
          f"chaos={args.chaos or 'none'}")
    try:
        try:
            reports = trainer.run(args.steps)
        except StepWedged as e:
            print(f"FATAL: step {e.step} wedged past retry limit",
                  file=sys.stderr)
            return 3
        bad = False
        for r in reports:
            loss = r.metrics.get("loss", float("nan"))
            line = (f"step {r.step:3d}  loss {loss:7.3f}  "
                    f"wall {r.wall_s:6.2f}s  mb {r.mb_executed}/{r.mb_needed}")
            if r.restarts:
                line += f"  restarts={r.restarts}"
            if r.wedges:
                line += f"  wedges={r.wedges}"
            for rec in r.recoveries:
                line += f"\n      recovery: {rec}"
            print(line)
            if not np.isfinite(loss):
                bad = True
        if chaos is not None:
            active = {k: v for k, v in chaos.stats.items() if v}
            print(f"chaos stats: {active or 'no events fired'}")
        if recorder is not None:
            from repro.obs import scorecard, write_chrome_trace
            hosts = [f"h{i:02d}" for i in range(args.hosts)]
            path = write_chrome_trace(recorder, args.trace,
                                      node_names=hosts)
            card = scorecard(recorder, policy=args.policy)
            print(f"trace: {len(recorder)} records "
                  f"({recorder.dropped} dropped) -> {path} "
                  f"(open in https://ui.perfetto.dev)")
            if chaos is not None:
                print(f"scorecard: recall={card['recall']} "
                      f"precision={card['precision']} ttd={card['ttd']}")
        if bad or _update_corrupted(trainer):
            print("FATAL: corrupted model update detected", file=sys.stderr)
            return 2
        print("ok: all committed updates finite")
        return 0
    finally:
        trainer.shutdown()


if __name__ == "__main__":
    sys.exit(main())
