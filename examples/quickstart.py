"""Quickstart: build any assigned architecture, run one train step and one
decode step on CPU, and print the speculation policy in action on a toy
cluster snapshot.

    PYTHONPATH=src python examples/quickstart.py --arch qwen3-8b
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import (
    ARCH_IDS, REDUCED_SHAPE_TRAIN, get_config, reduced_config)
from repro.models import model as MODEL
from repro.models.inputs import input_specs, materialize
from repro.train.loop import (
    TrainConfig, make_serve_step, make_train_step, train_state_init)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=ARCH_IDS)
    args = ap.parse_args()

    full = get_config(args.arch)
    cfg = reduced_config(full)  # CPU-sized twin of the same family
    n_total, n_active = full.param_counts()
    print(f"[{args.arch}] family={full.family} "
          f"params={n_total/1e9:.2f}B (active {n_active/1e9:.2f}B); "
          f"running the reduced twin on CPU")

    tc = TrainConfig()
    key = jax.random.PRNGKey(0)
    state = train_state_init(cfg, key, tc)
    batch = materialize(input_specs(cfg, REDUCED_SHAPE_TRAIN), key,
                        cfg.vocab_size)

    train_step = jax.jit(make_train_step(cfg, tc))
    t0 = time.time()
    state, metrics = train_step(state, batch)
    print(f"train step: loss={float(metrics['loss']):.3f} "
          f"grad_norm={float(metrics['grad_norm']):.3f} "
          f"({time.time()-t0:.1f}s incl. compile)")

    if not cfg.is_encoder_only():
        serve = jax.jit(make_serve_step(cfg, tc))
        cache = MODEL.init_cache(cfg, batch=2, max_len=64)
        tokens = jnp.array([1, 2], jnp.int32)
        pos = jnp.zeros((2,), jnp.int32)
        t0 = time.time()
        logits, cache = serve(state["params"], cache, tokens, pos)
        print(f"decode step: logits {logits.shape} "
              f"({time.time()-t0:.1f}s incl. compile)")
    print("ok")


if __name__ == "__main__":
    main()
