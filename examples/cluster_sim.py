"""The paper in one terminal screen: a 1 GB Terasort job on a 20-node YARN
cluster, one node crash at 50 % map progress, under both speculation
policies — with the recovery timeline printed, plus a shuffle-substrate
profile comparing the batched macro-event fetch plane (the default) and
the event-driven engine against the seed's rescan path (fetch slots
filled per unit of candidate-selection work; DESIGN.md §12/§14).

``--assess-backend {numpy,jax,pallas}`` runs the policies' assessment
math on the chosen compute backend (byte-identical decisions, DESIGN.md
§13) and prints the per-backend assessment-tick profile; ``--sweep N``
demos the batched multi-scenario sweep (one vmapped device step scoring
N fault scenarios vs scoring them serially on numpy).

    PYTHONPATH=src python examples/cluster_sim.py
    PYTHONPATH=src python examples/cluster_sim.py --assess-backend jax --sweep 8
"""
from __future__ import annotations

import argparse
import time

from repro.sim import JobSpec, Simulation, faults


def run(policy: str, gb: float, frac: float, seed: int,
        shuffle: str = "batch", assess_backend: str = "numpy",
        net: str = "flat", racks: int = 0, obs=None, model=None):
    sim = Simulation(policy=policy, seed=seed, shuffle=shuffle,
                     assess_backend=assess_backend, net=net, racks=racks,
                     obs=obs)
    if model is not None:
        sim.speculator.load_checkpoint(model)
    job = sim.submit(JobSpec("demo", "terasort", gb))
    faults.crash_busiest_node_at_map_progress(sim, job, frac)

    timeline = []
    orig = Simulation._start_attempt
    def patched(self, req, node_id):
        if req.speculative or req.rollback or req.reason:
            timeline.append((self.engine.now, f"launch {req.task.task_id} "
                             f"on {node_id} ({req.reason or 'speculative'}"
                             f"{'+rollback' if req.rollback else ''})"))
        return orig(self, req, node_id)
    Simulation._start_attempt = patched
    orig_nl = Simulation.node_lost
    def pnl(self, node_id, by_policy=False):
        timeline.append((self.engine.now,
                         f"node {node_id} declared lost "
                         f"({'policy Eq.4' if by_policy else 'NM expiry 600s'})"))
        return orig_nl(self, node_id, by_policy=by_policy)
    Simulation.node_lost = pnl
    try:
        sim.run()
    finally:
        Simulation._start_attempt = orig
        Simulation.node_lost = orig_nl
    return job.result, timeline, sim


def _print_shuffle_profile(batch_prof, gb: float, frac: float,
                           seed: int, net: str = "flat",
                           racks: int = 0) -> None:
    """The substrate win, demoed: same crashed run under all three
    engines — identical slots filled, orders of magnitude less selection
    work, and the batch plane's try_start fan-out collapsed by the
    completion log. ``batch_prof`` is reused from the main loop's yarn
    run; the rescan and event references are re-simulated."""
    _, _, rescan_sim = run("yarn", gb, frac, seed, shuffle="rescan",
                           net=net, racks=racks)
    _, _, event_sim = run("yarn", gb, frac, seed, shuffle="event",
                          net=net, racks=racks)
    rescan_prof = rescan_sim.shuffle.profile
    event_prof = event_sim.shuffle.profile
    print(f"\n=== shuffle substrate profile (same run, three engines, "
          f"net={net}) ===")
    print(f"{'engine':>8} {'slots':>7} {'notifies':>9} {'try_start':>10} "
          f"{'selection work':>16} {'slots/1k work':>14}")
    for mode, prof in (("rescan", rescan_prof), ("event", event_prof),
                       ("batch", batch_prof)):
        work = (f"{prof.deps_scanned} scanned" if mode == "rescan"
                else f"{prof.heap_pops} heap pops")
        print(f"{mode:>8} {prof.slots_filled:>7} {prof.notifies:>9} "
              f"{prof.try_calls:>10} {work:>16} "
              f"{prof.slots_per_kwork():>14.1f}")
    ratio = rescan_prof.selection_work \
        / max(1, event_prof.selection_work)
    same = (rescan_prof.slots_filled == event_prof.slots_filled
            == batch_prof.slots_filled
            and rescan_prof.notifies == event_prof.notifies
            == batch_prof.notifies)
    behaviour = ("identical fetch behaviour" if same
                 else ("fair model: per-engine recompute cadence shifts "
                       "fetch behaviour (expected, DESIGN.md §15.3)"
                       if net == "fair"
                       else "ENGINES DIVERGED (file a bug!)"))
    print(f"  → {behaviour} with {ratio:.0f}× less "
          f"candidate-selection work (O(1) pops vs O(n_maps) rescans); "
          f"batch applied {batch_prof.lane_records} lane records and "
          f"skipped {event_prof.try_calls - batch_prof.try_calls} "
          f"no-op try_starts")


def _print_assess_profile(profiles) -> None:
    """Per-backend assessment-tick profile: same scenario, same actions,
    different compute substrate (DESIGN.md §13)."""
    print("\n=== assessment-backend profile (same yarn run) ===")
    print(f"{'backend':>8} {'ticks':>7} {'assess wall':>12} "
          f"{'ticks/s':>9} {'actions':>8}")
    for name, sim in profiles:
        tps = sim.assess_ticks / max(sim.assess_wall, 1e-9)
        print(f"{name:>8} {sim.assess_ticks:>7} "
              f"{sim.assess_wall * 1e3:>10.1f}ms {tps:>9.0f} "
              f"{sim.actions_emitted:>8}")


def _demo_degraded_rack(gb: float, seed: int, net: str,
                        racks: int) -> None:
    """The paper's degraded-network scenario end-to-end: rack 0's
    uplink switch sickens to 2 % capacity mid-shuffle — no node ever
    dies, but every cross-rack fetch touching the rack crawls. Binocular
    speculation's glance sees the whole rack's fetch plane sag (ζ), not
    a single sick node (DESIGN.md §15.5)."""
    print(f"\n=== degraded-rack demo: {gb:g} GB terasort on {racks} "
          f"racks (net={net}), rack 0 uplink -> 2% at t=45s ===")
    for policy in ("yarn", "bino"):
        sim = Simulation(policy=policy, seed=seed, net=net, racks=racks)
        job = sim.submit(JobSpec("deg", "terasort", gb))
        base = Simulation(policy=policy, seed=seed, net=net, racks=racks)
        base.submit(JobSpec("deg", "terasort", gb))
        base_jct = base.run()[0].jct
        faults.rack_switch_degrade_at(sim, 0, 45.0, 0.02, duration=300.0)
        res = sim.run()[0]
        print(f"  {policy.upper():>5}: JCT {res.jct:7.0f}s "
              f"({res.jct / base_jct:4.1f}x vs healthy rack), "
              f"{res.n_fetch_failures} fetch failures, "
              f"{res.n_spec_attempts} speculative attempts, "
              f"0 nodes lost")


def _demo_sweep(n_scenarios: int, seed: int, net: str = "flat",
                racks: int = 0) -> None:
    """Batched multi-scenario sweep on a mid-run multi-job snapshot."""
    import dataclasses

    from repro.accel.sweep import BatchedSweep, scenario_grid
    from repro.sim.mapreduce import SimParams

    params = dataclasses.replace(SimParams(), sim_time_cap=80.0)
    sim = Simulation(policy="yarn", seed=seed, params=params, net=net,
                     racks=racks)
    for j in range(3):
        sim.submit(JobSpec(f"j{j}", "terasort", 2.0,
                           submit_time=float(3 * j)))
    sim.run()
    scenarios = scenario_grid(n_scenarios, len(sim.cluster.node_ids),
                              seed=seed,
                              n_racks=sim.cluster.net.n_racks)
    sweep = BatchedSweep(sim.arrays, sim.engine.now).prepare(scenarios)
    sweep.run_batched()  # warm the jit cache
    t0 = time.perf_counter()
    batched = sweep.run_batched()
    tb = time.perf_counter() - t0
    t0 = time.perf_counter()
    sweep.run_serial()
    ts = time.perf_counter() - t0
    print(f"\n=== batched sweep: {n_scenarios} fault scenarios, "
          f"one device step ===")
    for sc, verdict in zip(scenarios, batched):
        hits = int(verdict["spatial_hits"].sum())
        failed = int(verdict["failed"].sum())
        spec = int((verdict["late_victims"] >= 0).sum())
        print(f"  {sc.kind:>12}: spatial_hits={hits} failed_nodes={failed} "
              f"late_victims={spec} reaps={verdict['n_reap']}")
    print(f"  serial numpy {ts * 1e3:.1f}ms → batched {tb * 1e3:.1f}ms "
          f"({ts / max(tb, 1e-9):.1f}x)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--gb", type=float, default=1.0)
    ap.add_argument("--frac", type=float, default=0.5,
                    help="map progress at which the node crashes")
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--assess-backend", default="numpy",
                    choices=("numpy", "jax", "pallas"),
                    help="assessment-compute backend (DESIGN.md §13)")
    ap.add_argument("--policy", default=None, choices=("predictor",),
                    help="add a third policy column to the crash demo: "
                         "the learned PredictorPolicy (DESIGN.md §20); "
                         "requires --model")
    ap.add_argument("--model", default=None, metavar="CKPT_DIR",
                    help="trained predictor checkpoint directory "
                         "(make train-predictor -> artifacts/predictor/"
                         "ckpt); loads the calibrated threshold from its "
                         "metadata")
    ap.add_argument("--net", default="flat",
                    choices=("flat", "topo", "fair"),
                    help="network model (DESIGN.md §15): flat per-NIC "
                         "shares (seed-exact), rack-aware topo, or "
                         "batched ε-fair flows")
    ap.add_argument("--racks", type=int, default=0,
                    help="rack count for the topology-aware models "
                         "(default: 4 for topo, 1 for fair)")
    ap.add_argument("--sweep", type=int, default=0, metavar="N",
                    help="demo the batched sweep across N fault scenarios")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record the bino run with the flight recorder "
                         "and export a Chrome/Perfetto trace "
                         "(DESIGN.md §18; see examples/TRACES.md)")
    args = ap.parse_args()
    if args.policy == "predictor" and not args.model:
        ap.error("--policy predictor requires --model CKPT_DIR "
                 "(make train-predictor)")

    # fault-free baseline
    sim0 = Simulation(policy="yarn", seed=args.seed, net=args.net,
                      racks=args.racks)
    sim0.submit(JobSpec("demo", "terasort", args.gb))
    base = sim0.run()[0].jct

    print(f"=== {args.gb:g} GB terasort, node crash at "
          f"{args.frac:.0%} map progress (net={args.net}, "
          f"fault-free JCT {base:.0f}s) ===")
    yarn_sim = None
    recorder = None
    policies = ("yarn", "bino") + \
        (("predictor",) if args.policy == "predictor" else ())
    for policy in policies:
        obs = None
        if args.trace and policy == "bino":
            from repro.obs import TraceRecorder
            obs = recorder = TraceRecorder()
        model = args.model if policy == "predictor" else None
        res, timeline, sim = run(policy, args.gb, args.frac, args.seed,
                                 assess_backend=args.assess_backend,
                                 net=args.net, racks=args.racks, obs=obs,
                                 model=model)
        if policy == "yarn":
            yarn_sim = sim
        print(f"\n--- {policy.upper()} ---  JCT {res.jct:.0f}s "
              f"({res.jct / base:.1f}x slowdown), "
              f"{res.n_spec_attempts} speculative attempts")
        for t, line in timeline[:12]:
            print(f"  t={t:7.1f}s  {line}")
        if len(timeline) > 12:
            print(f"  ... {len(timeline) - 12} more events")

    _print_shuffle_profile(yarn_sim.shuffle.profile, args.gb, args.frac,
                           args.seed, net=args.net, racks=args.racks)
    profiles = [(args.assess_backend, yarn_sim)]
    if args.assess_backend != "numpy":
        _, _, ref = run("yarn", args.gb, args.frac, args.seed,
                        net=args.net, racks=args.racks)
        profiles.insert(0, ("numpy", ref))
    _print_assess_profile(profiles)
    n_racks = yarn_sim.cluster.net.n_racks
    if n_racks > 1:
        # cross-rack traffic needs a job bigger than one rack: pack-
        # first placement fills ~8 maps/node, so a job of `gb` GB spans
        # ~gb nodes — size it one node past the rack boundary
        per_rack = -(-len(yarn_sim.cluster.node_ids) // n_racks)
        _demo_degraded_rack(max(args.gb, per_rack + 1.0), args.seed,
                            args.net, n_racks)
    if args.sweep:
        _demo_sweep(args.sweep, args.seed, net=args.net, racks=args.racks)
    if recorder is not None:
        from repro.obs import scorecard, write_chrome_trace
        path = write_chrome_trace(recorder, args.trace,
                                  node_names=sim.cluster.node_ids)
        card = scorecard(recorder, policy="bino")
        print("\n=== flight recorder (bino run) ===")
        print(f"  {len(recorder)} records "
              f"({recorder.dropped} dropped), counts: "
              + ", ".join(f"{k}={v}"
                          for k, v in sorted(recorder.counts().items())))
        print(f"  scorecard: recall={card['recall']} "
              f"precision={card['precision']} ttd={card['ttd']} "
              f"wasted_backup_work={card['wasted_backup_work']}")
        print(f"  wrote {path} — open in https://ui.perfetto.dev "
              f"(examples/TRACES.md)")


if __name__ == "__main__":
    main()
