"""The paper in one terminal screen: a 1 GB Terasort job on a 20-node YARN
cluster, one node crash at 50 % map progress, under both speculation
policies — with the recovery timeline printed, plus a shuffle-substrate
profile comparing the event-driven engine against the seed's rescan path
(fetch slots filled per unit of candidate-selection work).

    PYTHONPATH=src python examples/cluster_sim.py
"""
from __future__ import annotations

import argparse

from repro.sim import JobSpec, Simulation, faults


def run(policy: str, gb: float, frac: float, seed: int,
        shuffle: str = "event"):
    sim = Simulation(policy=policy, seed=seed, shuffle=shuffle)
    job = sim.submit(JobSpec("demo", "terasort", gb))
    faults.crash_busiest_node_at_map_progress(sim, job, frac)

    timeline = []
    orig = Simulation._start_attempt
    def patched(self, req, node_id):
        if req.speculative or req.rollback or req.reason:
            timeline.append((self.engine.now, f"launch {req.task.task_id} "
                             f"on {node_id} ({req.reason or 'speculative'}"
                             f"{'+rollback' if req.rollback else ''})"))
        return orig(self, req, node_id)
    Simulation._start_attempt = patched
    orig_nl = Simulation.node_lost
    def pnl(self, node_id, by_policy=False):
        timeline.append((self.engine.now,
                         f"node {node_id} declared lost "
                         f"({'policy Eq.4' if by_policy else 'NM expiry 600s'})"))
        return orig_nl(self, node_id, by_policy=by_policy)
    Simulation.node_lost = pnl
    try:
        sim.run()
    finally:
        Simulation._start_attempt = orig
        Simulation.node_lost = orig_nl
    return job.result, timeline, sim.shuffle.profile


def _print_shuffle_profile(event_prof, gb: float, frac: float,
                           seed: int) -> None:
    """The substrate win, demoed: same crashed run under both engines —
    identical slots filled, orders of magnitude less selection work.
    ``event_prof`` is reused from the main loop's yarn run; only the
    rescan reference is re-simulated."""
    _, _, rescan_prof = run("yarn", gb, frac, seed, shuffle="rescan")
    print("\n=== shuffle substrate profile (same run, both engines) ===")
    print(f"{'engine':>8} {'slots':>7} {'notifies':>9} "
          f"{'selection work':>15} {'slots/1k work':>14}")
    for mode, prof in (("rescan", rescan_prof), ("event", event_prof)):
        work = (f"{prof.deps_scanned} scanned" if mode == "rescan"
                else f"{prof.heap_pops} heap pops")
        print(f"{mode:>8} {prof.slots_filled:>7} {prof.notifies:>9} "
              f"{work:>15} {prof.slots_per_kwork():>14.1f}")
    ratio = rescan_prof.selection_work \
        / max(1, event_prof.selection_work)
    same = (rescan_prof.slots_filled == event_prof.slots_filled
            and rescan_prof.notifies == event_prof.notifies)
    behaviour = ("identical fetch behaviour" if same
                 else "ENGINES DIVERGED (file a bug!)")
    print(f"  → {behaviour} with {ratio:.0f}× less "
          f"candidate-selection work (O(1) pops vs O(n_maps) rescans)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--gb", type=float, default=1.0)
    ap.add_argument("--frac", type=float, default=0.5,
                    help="map progress at which the node crashes")
    ap.add_argument("--seed", type=int, default=1)
    args = ap.parse_args()

    # fault-free baseline
    sim0 = Simulation(policy="yarn", seed=args.seed)
    sim0.submit(JobSpec("demo", "terasort", args.gb))
    base = sim0.run()[0].jct

    print(f"=== {args.gb:g} GB terasort, node crash at "
          f"{args.frac:.0%} map progress (fault-free JCT {base:.0f}s) ===")
    yarn_prof = None
    for policy in ("yarn", "bino"):
        res, timeline, prof = run(policy, args.gb, args.frac, args.seed)
        if policy == "yarn":
            yarn_prof = prof
        print(f"\n--- {policy.upper()} ---  JCT {res.jct:.0f}s "
              f"({res.jct / base:.1f}x slowdown), "
              f"{res.n_spec_attempts} speculative attempts")
        for t, line in timeline[:12]:
            print(f"  t={t:7.1f}s  {line}")
        if len(timeline) > 12:
            print(f"  ... {len(timeline) - 12} more events")

    _print_shuffle_profile(yarn_prof, args.gb, args.frac, args.seed)


if __name__ == "__main__":
    main()
