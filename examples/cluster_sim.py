"""The paper in one terminal screen: a 1 GB Terasort job on a 20-node YARN
cluster, one node crash at 50 % map progress, under both speculation
policies — with the recovery timeline printed.

    PYTHONPATH=src python examples/cluster_sim.py
"""
from __future__ import annotations

import argparse

from repro.core.types import AttemptState
from repro.sim import JobSpec, Simulation, faults


def run(policy: str, gb: float, frac: float, seed: int):
    sim = Simulation(policy=policy, seed=seed)
    job = sim.submit(JobSpec("demo", "terasort", gb))
    faults.crash_busiest_node_at_map_progress(sim, job, frac)

    timeline = []
    orig = Simulation._start_attempt
    def patched(self, req, node_id):
        if req.speculative or req.rollback or req.reason:
            timeline.append((self.engine.now, f"launch {req.task.task_id} "
                             f"on {node_id} ({req.reason or 'speculative'}"
                             f"{'+rollback' if req.rollback else ''})"))
        return orig(self, req, node_id)
    Simulation._start_attempt = patched
    orig_nl = Simulation.node_lost
    def pnl(self, node_id, by_policy=False):
        timeline.append((self.engine.now,
                         f"node {node_id} declared lost "
                         f"({'policy Eq.4' if by_policy else 'NM expiry 600s'})"))
        return orig_nl(self, node_id, by_policy=by_policy)
    Simulation.node_lost = pnl
    try:
        sim.run()
    finally:
        Simulation._start_attempt = orig
        Simulation.node_lost = orig_nl
    return job.result, timeline


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--gb", type=float, default=1.0)
    ap.add_argument("--frac", type=float, default=0.5,
                    help="map progress at which the node crashes")
    ap.add_argument("--seed", type=int, default=1)
    args = ap.parse_args()

    # fault-free baseline
    sim0 = Simulation(policy="yarn", seed=args.seed)
    sim0.submit(JobSpec("demo", "terasort", args.gb))
    base = sim0.run()[0].jct

    print(f"=== {args.gb:g} GB terasort, node crash at "
          f"{args.frac:.0%} map progress (fault-free JCT {base:.0f}s) ===")
    for policy in ("yarn", "bino"):
        res, timeline = run(policy, args.gb, args.frac, args.seed)
        print(f"\n--- {policy.upper()} ---  JCT {res.jct:.0f}s "
              f"({res.jct / base:.1f}x slowdown), "
              f"{res.n_spec_attempts} speculative attempts")
        for t, line in timeline[:12]:
            print(f"  t={t:7.1f}s  {line}")
        if len(timeline) > 12:
            print(f"  ... {len(timeline) - 12} more events")


if __name__ == "__main__":
    main()
