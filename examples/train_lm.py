"""End-to-end training driver with binocular-speculation fault recovery.

Trains a registry architecture over the thread-simulated multi-host
runtime: microbatch map tasks stream gradients to the coordinator, the
speculator (Bino or the gang-restart baseline) handles injected host
crashes/stragglers, checkpoints commit atomically, and a killed run
resumes from the newest checkpoint + data-pipeline state.

Small default so the demo runs in ~a minute on this CPU container:

    PYTHONPATH=src python examples/train_lm.py --steps 30 \
        --freeze-host h02@8 --slow-host h01@15x0.2 --recovery bino

Production-scale configs (--arch with --full) use the same code path; on a
real pod the host daemons become per-host processes and grad streaming
becomes reduce-scatter, but the control plane (this file's subject) is
unchanged.
"""
from __future__ import annotations

import argparse
import threading

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.runtime import RuntimeConfig, TrainerRuntime
from repro.train.loop import TrainConfig


def parse_faults(spec_list, kind):
    out = []
    for spec in spec_list or []:
        if kind == "freeze":        # h02@8
            host, step = spec.split("@")
            out.append((host, int(step), None))
        else:                        # h01@15x0.2
            host, rest = spec.split("@")
            step, factor = rest.split("x")
            out.append((host, int(step), float(factor)))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=ARCH_IDS)
    ap.add_argument("--full", action="store_true",
                    help="use the full production config (slow on CPU)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--hosts", type=int, default=4)
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--recovery", default="bino",
                    choices=["bino", "restart"])
    ap.add_argument("--freeze-host", action="append",
                    help="host@step, e.g. h02@8 (crash)")
    ap.add_argument("--slow-host", action="append",
                    help="host@stepxfactor, e.g. h01@15x0.2 (straggler)")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced_config(cfg)
    rt = RuntimeConfig(
        n_hosts=args.hosts, microbatches_per_shard=args.microbatches,
        recovery=args.recovery, compute_delay=0.02,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every)
    trainer = TrainerRuntime(cfg, TrainConfig(), rt,
                             seq_len=args.seq_len, per_shard_batch=2)

    freezes = parse_faults(args.freeze_host, "freeze")
    slows = parse_faults(args.slow_host, "slow")

    def on_step(step, tr):
        for host, s, _ in freezes:
            if s == step:
                print(f"  !! injecting crash of {host} during step {step}")
                threading.Timer(0.05, lambda h=host: tr.freeze_host(h)).start()
        for host, s, f in slows:
            if s == step:
                print(f"  !! slowing {host} by {f}x from step {step}")
                tr.slow_host(host, 1.0 / f)

    try:
        reports = trainer.run(args.steps, on_step=on_step)
        for r in reports:
            line = (f"step {r.step:4d}  loss {r.metrics.get('loss', float('nan')):7.3f}  "
                    f"wall {r.wall_s:6.2f}s  mb {r.mb_executed}/{r.mb_needed}")
            if r.restarts:
                line += f"  restarts={r.restarts}"
            for rec in r.recoveries:
                line += f"\n      recovery: {rec}"
            print(line)
        waste = sum(r.mb_executed - r.mb_needed for r in reports)
        total = sum(r.mb_needed for r in reports)
        print(f"\ndone: {args.steps} steps, {waste} wasted microbatch "
              f"executions / {total} needed "
              f"({100.0 * waste / max(total, 1):.1f}% overhead)")
    finally:
        trainer.shutdown()


if __name__ == "__main__":
    main()
